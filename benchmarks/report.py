"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs

DRY = Path("results/dryrun")
PERF = Path("results/perf")

MOVE_DOWN = {
    "memory": "shard the residual stream over `model` (sequence parallelism)"
              " so activation traffic scales with TP degree",
    "collective": "replace all-gather-based exchange with all-to-all /"
                  " overlap collectives with compute (microbatching)",
    "compute": "raise arithmetic intensity per chip (larger per-device"
               " batch or fewer, larger matmuls)",
}


def _load(arch, shape, mesh):
    p = DRY / f"{arch}__{shape}__{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else None


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | compile s | HLO GFLOPs/dev | HLO GB/dev |"
        " coll GB/dev | peak mem GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh in ("single", "multi"):
                d = _load(arch, shape, mesh)
                if not d:
                    continue
                lines.append(
                    f"| {arch} | {shape} | {d['mesh']} | {d['compile_s']} |"
                    f" {d['hlo_flops']/1e9:.0f} | {d['hlo_bytes']/1e9:.1f} |"
                    f" {d['coll_bytes']/1e9:.2f} |"
                    f" {d['peak_memory_bytes']/2**30:.1f} |"
                )
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck |"
        " useful/HLO | roofline frac | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        cfg = get_config(arch)
        app = applicable_shapes(cfg)
        for shape in SHAPES:
            if shape not in app:
                reason = (
                    "encoder-only, no decode"
                    if shape == "decode_32k"
                    else "quadratic attention at 500k"
                )
                lines.append(
                    f"| {arch} | {shape} | — | — | — | SKIPPED | — | — |"
                    f" {reason} |"
                )
                continue
            d = _load(arch, shape, "single")
            if not d:
                continue
            lines.append(
                f"| {arch} | {shape} | {d['t_compute']:.2e} |"
                f" {d['t_memory']:.2e} | {d['t_collective']:.2e} |"
                f" **{d['bottleneck']}** |"
                f" {d['useful_flops_ratio']:.3f} |"
                f" {d['roofline_fraction']:.4f} |"
                f" {MOVE_DOWN[d['bottleneck']]} |"
            )
    return "\n".join(lines)


def perf_rows() -> list:
    rows = []
    for p in sorted(PERF.glob("*.json")):
        d = json.loads(p.read_text())
        rows.append((p.stem, d))
    return rows


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())
