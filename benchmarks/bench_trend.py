"""Aggregate BENCH_sched.json artifacts into a per-policy events/sec trend.

Every CI run uploads one ``BENCH_sched.json`` (emitted by
``sched_scale --budget --json``: events/sec per policy, see
benchmarks/README.md); until now nothing aggregated the series — each
run was a lone point, and sustained regressions only surfaced as
repeated fail-soft warnings.  This tool turns a directory of downloaded
artifacts (e.g. ``gh run download``'s per-run subdirectories, or any
flat collection of ``BENCH_sched*.json`` files) into one table:

    python -m benchmarks.bench_trend ARTIFACT_DIR [more dirs/files...]

Artifacts are discovered recursively (``BENCH_sched*.json`` — the
committed ``benchmarks/BENCH_sched_baseline.json`` matches too, so
``make bench-trend`` over the repo root trends the baseline against a
fresh ``make bench-budget`` out of the box) and ordered by each
artifact's recorded ``generated_at`` run timestamp, falling back to
file mtime for artifacts predating the field: the trend reads left
(oldest) to right (latest).  (Pure mtime would mis-order downloaded
artifacts — ``gh run download`` stamps everything at download time.)

Output: a markdown table, one row per policy — every artifact's
events/sec, then ``best`` and ``latest/first`` (the trend headline:
< 1.00 means the newest run is slower than the oldest).  ``--json``
writes the same series machine-readably::

    {
      "schema": 1,
      "bench": "sched_trend",
      "artifacts": ["<label>", ...],            // oldest -> latest
      "events_per_sec": {"A-SRPT": [35689.2, ...], ...},  // null = absent
      "latest_vs_first": {"A-SRPT": 1.04, ...}
    }

Labels are paths relative to the common ancestor (artifact directories
are usually named per CI run, so the run id survives into the table).

CI wiring (the bench job): ``--summary "$GITHUB_STEP_SUMMARY"`` appends
the table to the run page, and ``--min-ratio 0.7`` turns the headline
into a gate — exit 1 when any policy's latest/first ratio drops below
the threshold (a sustained regression, as opposed to the single-run
fail-soft ``--check`` warnings).  Non-budget artifacts that stray into
the download directory (e.g. the fleet distribution JSON) are skipped
with a note, like corrupt ones.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

PATTERN = "BENCH_sched*.json"


def discover(paths: Sequence[str]) -> List[pathlib.Path]:
    """Artifact files from a mix of files/directories, mtime-ordered."""
    found: List[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            found.extend(p.rglob(PATTERN))
        elif p.is_file():
            found.append(p)
        else:
            raise FileNotFoundError(f"no such file or directory: {raw}")
    # de-dup (a dir arg may contain an explicitly-passed file)
    uniq = sorted(
        {f.resolve() for f in found},
        key=lambda f: (f.stat().st_mtime, str(f)),
    )
    return uniq


def _label(path: pathlib.Path, root: Optional[pathlib.Path]) -> str:
    if root is not None:
        try:
            rel = path.relative_to(root)
            return str(rel) if str(rel) != path.name else path.name
        except ValueError:
            pass
    return path.name


def _run_timestamp(f: pathlib.Path, data: Dict) -> float:
    """When the artifact's benchmark actually ran: the recorded
    ``generated_at`` (sched_scale budget mode stamps it), else the file
    mtime (meaningless after downloads/checkouts, but the only signal
    pre-field artifacts carry)."""
    stamp = data.get("generated_at")
    if isinstance(stamp, str):
        from datetime import datetime, timezone

        try:
            dt = datetime.fromisoformat(stamp)
        except ValueError:
            pass
        else:
            if dt.tzinfo is None:
                # naive stamps are taken as UTC so the ordering does not
                # depend on the consuming machine's timezone
                dt = dt.replace(tzinfo=timezone.utc)
            return dt.timestamp()
    return f.stat().st_mtime


def load_series(
    files: Sequence[pathlib.Path],
) -> Tuple[List[str], Dict[str, List[Optional[float]]]]:
    """(artifact labels, per-policy events/sec aligned to the labels),
    ordered by each artifact's run timestamp (see ``_run_timestamp``).

    Artifacts that fail to parse or lack the ``events_per_sec`` section
    are skipped with a note on stdout rather than aborting the trend —
    CI downloads can include partial/corrupt runs.
    """
    try:
        root = pathlib.Path(os.path.commonpath([f.parent for f in files]))
    except ValueError:
        root = None
    parsed: List[Tuple[float, str, pathlib.Path, Dict]] = []
    for f in files:
        try:
            with open(f) as fh:
                data = json.load(fh)
            eps = data["events_per_sec"]
        except (json.JSONDecodeError, KeyError, OSError) as exc:
            print(f"[trend] skipping {f}: {exc}")
            continue
        bench = data.get("bench")
        if bench not in (None, "sched_scale_budget"):
            # e.g. a fleet artifact (BENCH_fleet.json schema) swept into
            # the download dir — different bench, not a trend point
            print(f"[trend] skipping {f}: bench {bench!r} is not the "
                  f"budget series")
            continue
        if not isinstance(eps, dict) or not all(
            isinstance(v, (int, float)) for v in eps.values()
        ):
            print(f"[trend] skipping {f}: malformed events_per_sec")
            continue
        parsed.append((_run_timestamp(f, data), str(f), f, eps))
    parsed.sort(key=lambda e: (e[0], e[1]))
    labels: List[str] = []
    series: Dict[str, List[Optional[float]]] = {}
    for _ts, _key, f, eps in parsed:
        labels.append(_label(f, root))
        for policy in series:
            series[policy].append(None)
        for policy, value in eps.items():
            col = series.setdefault(policy, [None] * len(labels))
            col[-1] = float(value)
    return labels, series


def latest_vs_first(
    series: Dict[str, List[Optional[float]]],
) -> Dict[str, Optional[float]]:
    """Per-policy trend headline.  ``latest`` is strictly the newest
    artifact: a policy absent from it gets no ratio (a stale point must
    not masquerade as the current trend); ``first`` is the policy's
    earliest appearance."""
    out: Dict[str, Optional[float]] = {}
    for policy, vals in series.items():
        present = [v for v in vals if v is not None]
        out[policy] = (
            round(vals[-1] / present[0], 3)
            if vals and vals[-1] is not None
            and len(present) >= 2 and present[0] > 0
            else None
        )
    return out


def to_markdown(
    labels: Sequence[str], series: Dict[str, List[Optional[float]]]
) -> str:
    """Per-policy trend table (policies in first-appearance order)."""
    ratios = latest_vs_first(series)
    head = ["policy", *labels, "best", "latest/first"]
    lines = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
    ]
    for policy, vals in series.items():
        present = [v for v in vals if v is not None]
        best = f"{max(present):.0f}" if present else "-"
        ratio = ratios[policy]
        cells = [policy]
        cells += [f"{v:.0f}" if v is not None else "-" for v in vals]
        cells += [best, f"{ratio:.2f}" if ratio is not None else "-"]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)


def to_trend_json(
    labels: Sequence[str], series: Dict[str, List[Optional[float]]]
) -> Dict:
    return {
        "schema": 1,
        "bench": "sched_trend",
        "artifacts": list(labels),
        "events_per_sec": series,
        "latest_vs_first": latest_vs_first(series),
    }


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "paths", nargs="+",
        help=f"directories (scanned recursively for {PATTERN}) and/or "
             f"artifact files",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="also write the series as JSON to PATH",
    )
    ap.add_argument(
        "--summary", metavar="FILE", default=None,
        help="append the markdown table to FILE (CI: pass "
             "\"$GITHUB_STEP_SUMMARY\" so the trend renders on the run "
             "page)",
    )
    ap.add_argument(
        "--min-ratio", metavar="R", default=None, type=float,
        help="exit 1 when any policy's latest/first events-per-second "
             "ratio drops below R (the CI trend gate uses 0.7); policies "
             "without a ratio (single point, or absent from the latest "
             "artifact) are noted but never fail the gate",
    )
    args = ap.parse_args(argv)

    files = discover(args.paths)
    if not files:
        print(f"no {PATTERN} artifacts under {args.paths}")
        return 1
    labels, series = load_series(files)
    if not labels:
        print("no parseable artifacts")
        return 1
    table = to_markdown(labels, series)
    print(table)
    if args.summary:
        with open(args.summary, "a") as fh:
            fh.write("### sched_scale events/sec trend\n\n")
            fh.write(table)
            fh.write("\n")
        print(f"appended trend table to {args.summary}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(to_trend_json(labels, series), fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")
    if args.min_ratio is not None:
        ratios = latest_vs_first(series)
        slow = {
            p: r for p, r in ratios.items()
            if r is not None and r < args.min_ratio
        }
        for p in sorted(p for p, r in ratios.items() if r is None):
            print(f"[trend] {p}: no latest/first ratio (single point or "
                  f"absent from latest); gate skipped")
        if slow:
            for p, r in sorted(slow.items()):
                print(
                    f"::error::trend gate: {p} latest/first {r:.2f} < "
                    f"{args.min_ratio} — sustained events/sec regression"
                )
            return 1
        print(f"trend gate: all latest/first ratios >= {args.min_ratio}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
