"""Trace-scale scheduling benchmark: A-SRPT + baselines at 5k-100k jobs.

Regime ("placement stress at paper scale"): 64 servers x 8 GPUs (half the
paper's 2000-GPU simulation cluster), 80 % multi-GPU jobs up to 64 GPUs,
horizon scaled with the job count to keep the bursty moderate-load regime,
and A-SRPT running the refined (multi-start local-search) Heavy-Edge
mapping — the quality mode whose per-placement cost the placement cache is
designed to amortize.

Reported per row: wall seconds, events processed, events/sec, peak
pending-queue depth (policy-held jobs), total flow time.  At 20k jobs the
A-SRPT row is additionally run with ``placement_cache=False`` — the
exhaustive re-evaluation engine — and the cached/uncached events-per-sec
ratio is reported as ``cache_speedup_20k`` (the two engines produce
bit-identical schedules; tests/test_sched_cache.py holds that equivalence
under property testing).

The 100k-job sweep runs A-SRPT always; the five baselines join at 100k
only under ``--full`` (they are each ~minutes at that scale).

This is a *throughput* benchmark: the regime deliberately saturates the
cluster (peak queue depths in the thousands), where strict head-of-line
policies trade flow time for order fidelity.  Scheduling-quality
comparisons against the paper belong to fig6/fig7/fig8.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)

from .common import make_cluster

NUM_SERVERS = 64
SINGLE_GPU_FRAC = 0.2
MAX_GPUS_PER_JOB = 64
SECONDS_PER_JOB = 12.0  # horizon = n_jobs * this
SIZES = (5_000, 20_000, 100_000)
COMPARE_AT = 20_000  # cached vs uncached measurement point


def _trace(n_jobs: int, seed: int = 1) -> list:
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=n_jobs * SECONDS_PER_JOB,
            seed=seed,
            single_gpu_frac=SINGLE_GPU_FRAC,
            max_gpus_per_job=MAX_GPUS_PER_JOB,
            mean_iters=400,
            sigma_iters=1.6,
            session_spread=120.0,
        )
    )


def _asrpt(placement_cache: bool = True) -> ASRPTPolicy:
    return ASRPTPolicy(
        make_predictor("mean"),
        tau=2.0,
        refine_mapping=True,
        placement_cache=placement_cache,
    )


def _row(n_jobs: int, policy_name: str, res) -> Dict:
    return {
        "n_jobs": n_jobs,
        "policy": policy_name,
        "wall_s": round(res.wall_s, 3),
        "events": res.n_events,
        "events_per_sec": round(res.events_per_sec, 1),
        "peak_queue_depth": res.peak_queue_depth,
        "total_flow": f"{res.total_flow_time:.4e}",
    }


def sched_scale(full: bool = False) -> List[Dict]:
    cluster = make_cluster(num_servers=NUM_SERVERS)
    rows: List[Dict] = []
    for n in SIZES:
        jobs = _trace(n)
        res_c = simulate(jobs, cluster, _asrpt(), validate=False)

        if n == COMPARE_AT:
            # Best-of-3 per engine (symmetric), back to back: the cached
            # run is short enough that a single sample swings tens of
            # percent with host noise, and the ratio is the headline
            # number.
            for _ in range(2):
                r2 = simulate(jobs, cluster, _asrpt(), validate=False)
                if r2.wall_s < res_c.wall_s:
                    res_c = r2
            rows.append(_row(n, "A-SRPT", res_c))
            res_u = min(
                (
                    simulate(jobs, cluster, _asrpt(False), validate=False)
                    for _ in range(3)
                ),
                key=lambda r: r.wall_s,
            )
            row = _row(n, "A-SRPT (uncached)", res_u)
            row["cache_speedup_20k"] = round(
                res_c.events_per_sec / res_u.events_per_sec, 2
            )
            rows.append(row)
        else:
            rows.append(_row(n, "A-SRPT", res_c))

        if n < 100_000 or full:
            for name in BASELINES:
                pol = BASELINES[name](make_predictor("mean"))
                res = simulate(jobs, cluster, pol, validate=False)
                rows.append(_row(n, name, res))
    return rows
