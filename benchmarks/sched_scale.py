"""Trace-scale scheduling benchmark: A-SRPT + baselines at 5k-100k jobs.

Regime ("placement stress at paper scale"): 64 servers x 8 GPUs (half the
paper's 2000-GPU simulation cluster), 80 % multi-GPU jobs up to 64 GPUs,
horizon scaled with the job count to keep the bursty moderate-load regime,
and A-SRPT running the refined (multi-start local-search) Heavy-Edge
mapping — the quality mode whose per-placement cost the placement cache is
designed to amortize.

Reported per row: wall seconds, events processed, events/sec, peak
pending-queue depth (policy-held jobs), total flow time.  At 20k jobs the
A-SRPT row is additionally run with ``placement_cache=False`` — the
exhaustive re-evaluation engine on the retained pure-Python reference
pipeline (dict-walk Heavy-Edge, per-(server, stage) beta alpha) — and
the cached/uncached events-per-sec ratio is reported as
``cache_speedup_20k`` (the two engines produce bit-identical schedules;
tests/test_sched_cache.py and tests/test_vectorized.py hold that
equivalence under property testing).

The 100k-job sweep runs A-SRPT always; the five baselines join at 100k
only under ``--full`` (they are each ~minutes at that scale).

This is a *throughput* benchmark: the regime deliberately saturates the
cluster (peak queue depths in the thousands), where strict head-of-line
policies trade flow time for order fidelity.  Scheduling-quality
comparisons against the paper belong to fig6/fig7/fig8.

Variants:

* ``sched_scale_hetero`` — the same regime on a mixed-generation cluster
  (three server classes: 100 GbE 8-GPU, 10 GbE 8-GPU, 10 GbE 4-GPU), run
  twice per size: clean, and with a fault injection downing four big-GPU
  (100 GbE 8x) servers a quarter into the horizon.  The fault row reports
  ``flow_vs_clean`` — degraded-cluster recovery flow time relative to the
  clean run.
* ``--straggler`` / ``sched_scale_straggler`` — degradation scenario on
  the mixed-generation cluster: mid-trace slowdown events sampled by
  ``trace.straggler_events`` hit four big-GPU servers, and A-SRPT runs
  twice — finish-in-place vs migration-capable (checkpoint-restart off
  the degraded servers).  The migration row reports ``flow_vs_stay``
  (total flow time relative to finish-in-place; < 1.0 means migration
  wins) and the migration count.
* ``--elastic`` / ``sched_scale_elastic`` — elastic-capacity scenario
  (ServerJoin/ServerLeave events, see repro.core.scenario): four gen-a
  servers are absent from the start; the *static* rows ride out the
  trace on the reduced cluster, the *join* rows get the capacity back
  mid-trace and report ``flow_vs_static`` (< 1.0 = recovered flow
  time), under A-SRPT and a queue baseline.
* ``--scenario FILE`` — replay any saved ``Scenario`` JSON (the format
  ``tests/golden/scenario_straggler.json`` instantiates; see
  scenario.py) under ``--policy`` (default A-SRPT).  The row includes
  the schedule sha256, so replays double as cross-machine equivalence
  checks.
* ``--stream [N]`` / ``sched_scale_stream`` — bounded-memory replay: an
  ``N``-job (default 1M) synthetic trace is *generated, scheduled, and
  folded into aggregates lazily* — no jobs list, no records dict — and
  the row reports events/sec plus ``peak_rss_mb`` (getrusage max RSS,
  whole process).  ``--max-rss-mb`` turns the memory claim into an
  enforced exit code (the CI streaming-memory job runs 1M jobs under a
  ceiling).  See benchmarks/README.md for the bounded-memory guarantee.
* ``--trace FILE.csv`` — the same streaming replay over a real
  datacenter-style CSV trace (Philly/PAI columns; see
  repro.core.trace_ingest for the format and malformed-row policy).
* ``--guard`` — migration_queue_guard A/B at the straggler variant's
  20k-job scale: the unguarded migrate row vs the queue-aware race, with
  ``flow_vs_unguarded`` as the verdict column.
* ``--budget`` / ``sched_scale_budget`` — a CI-sized subset (one size,
  best-of-3 cold-start samples per policy) whose events/sec per policy is
  written to ``BENCH_sched.json`` for trend tracking; ``--check``
  compares against a committed baseline and *warns* (never fails) past
  the threshold, since shared CI runners swing tens of percent.
  ``--budget --straggler`` appends the straggler migration row to the
  trended set.
* ``--fleet [N]`` / ``sched_scale_fleet`` — Monte-Carlo robustness
  sweep: N seeded straggler+elastic+arrival-jitter perturbations of a
  base scenario run through the shared-cache fleet driver
  (repro.core.fleet).  ``--json`` writes the distribution stats +
  per-variant schedule sha256s; ``--check`` compares against the
  committed ``BENCH_fleet_baseline.json`` — digest mismatches at fixed
  seed always exit 1 (bit-identity gate), p95 flow-time regressions
  warn, or fail under ``--strict``.
* ``--fleet-ab [N]`` / ``sched_scale_fleet_ab`` — interleaved A/B of
  the fleet driver vs N independent sequential ``simulate()`` calls on
  the refined-mapping engine; asserts per-variant bit-identity and
  reports ``fleet_speedup`` (the ROADMAP 5a cold-placement
  amortization, measured).
* ``--predict`` / ``sched_scale_predict`` — prediction-error robustness
  sweep: the closed prediction loop (repro.core.prediction_loop) run
  once per error model — oracle, online random forest, zero-cold-start,
  lognormal noise at three sigmas, adversarial rankflip — on a
  recurrence-heavy trace, each row reporting ``flow_vs_oracle`` /
  ``p95_vs_oracle`` and the mid-flight re-estimation count.  ``--check``
  gates the forest's p95 ratio against an *absolute* 1.3x-oracle bound
  (always exit 1 past it) and warns on per-regime drift vs the
  committed ``BENCH_predict_baseline.json``.
* ``--serve`` / ``sched_scale_serve`` — SLO-aware serving co-schedule
  (ISSUE 9): a diurnal ~1M-request :class:`RequestStream` (engine-
  calibrated batch latency curve, repro.serve.latency) rides the event
  stream next to a moderate-load training trace on the mixed cluster,
  run twice — train-only and mixed — and reports the three serving
  metrics: ``slo_attainment``, ``p99_request_latency_s``, and
  ``train_interference`` (mixed/train-only total flow time).
  ``--check`` gates slo_attainment against an *absolute* floor (always
  exit 1 below it), hard-fails on a schedule-sha mismatch at the fixed
  seed, and warns on p99/interference drift vs the committed
  ``BENCH_serve_baseline.json``.
* ``--strict`` — promote ``--check`` warnings to exit 1 (CI gate mode;
  fail-soft stays the local default).
* ``--profile [N]`` — run the selected variant under cProfile and dump
  the top-N cumulative entries (hot-path triage without ad-hoc scripts).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import (
    ASRPTPolicy,
    ArrivalJitterPerturbation,
    BASELINES,
    ClusterSpec,
    ElasticPerturbation,
    RequestStream,
    Scenario,
    ServerClass,
    StragglerPerturbation,
    StreamTraceConfig,
    TraceConfig,
    elastic_events,
    generate_trace,
    make_prediction_model,
    make_predictor,
    mixed_cluster_spec,
    run_fleet,
    simulate,
    straggler_events,
    stream_trace_source,
    trace_jobs_source,
)

from .common import make_cluster

NUM_SERVERS = 64
SINGLE_GPU_FRAC = 0.2
MAX_GPUS_PER_JOB = 64
SECONDS_PER_JOB = 12.0  # horizon = n_jobs * this
SIZES = (5_000, 20_000, 100_000)
COMPARE_AT = 20_000  # cached vs uncached measurement point

# Mixed-generation variant: same total server count, three classes.  The
# first class is the "big GPU" generation the fault injection targets.
HETERO_CLASSES = (
    ServerClass(count=24, gpus_per_server=8, b_inter=12.5e9, name="gen-a"),
    ServerClass(count=24, gpus_per_server=8, b_inter=1.25e9, name="gen-b"),
    ServerClass(
        count=16, gpus_per_server=4, b_inter=1.25e9, b_intra=50e9,
        name="gen-c",
    ),
)
HETERO_SIZES = (20_000, 100_000)
FAULT_SERVERS = (0, 1, 2, 3)  # four gen-a servers
FAULT_AT_FRAC = 0.25  # of the trace horizon

BUDGET_SIZE = 5_000  # --budget: one size, single sample per policy

# Straggler scenario: four gen-a servers slow mid-trace (factors sampled
# in [0.25, 0.6]); no recovery inside the 20k run's window, so stretched
# jobs stay stretched unless migrated.  The checkpoint-restart penalty is
# the migration.py default (120 s) — small against the multiplied
# remaining time of a job slowed to a quarter speed.  The variant runs at
# *moderate* load (3x the throughput regime's horizon): migration's win
# comes from converting idle healthy capacity into useful work, which the
# deliberately-saturated throughput regime has none of — there every GPU
# is always busy, so moving a stretched job merely hands its degraded
# GPUs (and their slowdown) to the next queued job and pays the restart
# penalty on top (measurably: flow_vs_stay ~1.02 at full saturation).
STRAGGLER_SIZES = (20_000,)
STRAGGLER_N = 4
STRAGGLER_FACTORS = (0.25, 0.6)
STRAGGLER_WINDOW = (0.2, 0.5)  # event times, fraction of the horizon
STRAGGLER_SECONDS_PER_JOB = 3 * SECONDS_PER_JOB


def _trace(
    n_jobs: int, seed: int = 1, seconds_per_job: float = SECONDS_PER_JOB
) -> list:
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=n_jobs * seconds_per_job,
            seed=seed,
            single_gpu_frac=SINGLE_GPU_FRAC,
            max_gpus_per_job=MAX_GPUS_PER_JOB,
            mean_iters=400,
            sigma_iters=1.6,
            session_spread=120.0,
        )
    )


def _asrpt(placement_cache: bool = True, **kw) -> ASRPTPolicy:
    return ASRPTPolicy(
        make_predictor("mean"),
        tau=2.0,
        refine_mapping=True,
        placement_cache=placement_cache,
        **kw,
    )


def _straggler_degradations(n_jobs: int, seed: int = 2) -> list:
    """Mid-trace slowdowns on gen-a (ids 0..23 in HETERO_CLASSES) servers;
    no recovery — finish-in-place pays the full stretch."""
    return straggler_events(
        HETERO_CLASSES[0].count,
        n_jobs * STRAGGLER_SECONDS_PER_JOB,
        n_stragglers=STRAGGLER_N,
        seed=seed,
        factor_low=STRAGGLER_FACTORS[0],
        factor_high=STRAGGLER_FACTORS[1],
        start_frac=STRAGGLER_WINDOW,
        recover=False,
    )


def _row(n_jobs: int, policy_name: str, res) -> Dict:
    return {
        "n_jobs": n_jobs,
        "policy": policy_name,
        "wall_s": round(res.wall_s, 3),
        "events": res.n_events,
        "events_per_sec": round(res.events_per_sec, 1),
        "peak_queue_depth": res.peak_queue_depth,
        "total_flow": f"{res.total_flow_time:.4e}",
    }


def sched_scale(full: bool = False) -> List[Dict]:
    cluster = make_cluster(num_servers=NUM_SERVERS)
    rows: List[Dict] = []
    for n in SIZES:
        jobs = _trace(n)
        res_c = simulate(jobs, cluster, _asrpt(), validate=False)

        if n == COMPARE_AT:
            # Best-of-3 per engine (symmetric), back to back: the cached
            # run is short enough that a single sample swings tens of
            # percent with host noise, and the ratio is the headline
            # number.
            for _ in range(2):
                r2 = simulate(jobs, cluster, _asrpt(), validate=False)
                if r2.wall_s < res_c.wall_s:
                    res_c = r2
            rows.append(_row(n, "A-SRPT", res_c))
            res_u = min(
                (
                    simulate(jobs, cluster, _asrpt(False), validate=False)
                    for _ in range(3)
                ),
                key=lambda r: r.wall_s,
            )
            row = _row(n, "A-SRPT (uncached)", res_u)
            row["cache_speedup_20k"] = round(
                res_c.events_per_sec / res_u.events_per_sec, 2
            )
            rows.append(row)
        else:
            rows.append(_row(n, "A-SRPT", res_c))

        if n < 100_000 or full:
            for name in BASELINES:
                pol = BASELINES[name](make_predictor("mean"))
                res = simulate(jobs, cluster, pol, validate=False)
                rows.append(_row(n, name, res))
    return rows


def _hetero_cluster() -> ClusterSpec:
    return ClusterSpec.heterogeneous(HETERO_CLASSES, b_intra=300e9)


def sched_scale_hetero(full: bool = False) -> List[Dict]:
    """Mixed-generation cluster + degraded-cluster recovery flow time."""
    cluster = _hetero_cluster()
    sizes = HETERO_SIZES if full else HETERO_SIZES[:1]
    rows: List[Dict] = []
    for n in sizes:
        jobs = _trace(n)
        horizon = n * SECONDS_PER_JOB
        clean = simulate(jobs, cluster, _asrpt(), validate=False)
        row = _row(n, "A-SRPT (hetero)", clean)
        rows.append(row)
        faults = [(FAULT_AT_FRAC * horizon, m) for m in FAULT_SERVERS]
        degraded = simulate(
            jobs, cluster, _asrpt(), validate=False, faults=faults
        )
        drow = _row(n, "A-SRPT (hetero, 4 gen-a down)", degraded)
        drow["flow_vs_clean"] = round(
            degraded.total_flow_time / clean.total_flow_time, 3
        )
        rows.append(drow)
        if n <= 20_000:
            for name in ("SPJF", "WCS-SubTime"):
                pol = BASELINES[name](make_predictor("mean"))
                res = simulate(jobs, cluster, pol, validate=False)
                rows.append(_row(n, f"{name} (hetero)", res))
    return rows


def _peak_rss_mb() -> float:
    """Whole-process peak resident set, MB (ru_maxrss is KB on Linux)."""
    import resource
    import sys

    kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":  # bytes there
        kb /= 1024.0
    return round(kb / 1024.0, 1)


STREAM_JOBS_DEFAULT = 1_000_000


def sched_scale_stream(
    n_jobs: int = STREAM_JOBS_DEFAULT,
    trace_csv: Optional[str] = None,
    arrival_rate: Optional[float] = None,
) -> List[Dict]:
    """Bounded-memory streaming replay (--stream / --trace FILE.csv).

    The jobs source is lazy (``stream_trace`` chunks or a CSV line
    reader), the simulator feeds its arrival heap incrementally, and the
    result backend folds each completed record into running aggregates —
    so resident memory scales with the *live* job count (peak queue
    depth), not the trace length.  ``peak_rss_mb`` on the row is the
    measured whole-process ceiling; at the default half-utilization
    arrival rate a million jobs stay in the tens of MB.

    The policy is the cached A-SRPT engine without refine_mapping (the
    throughput configuration); the predictor is the O(1)-per-group
    running-mean.  With ``trace_csv`` the row replays the CSV instead of
    the synthetic stream (same cluster, same policy).
    """
    cluster = make_cluster(num_servers=NUM_SERVERS)
    if trace_csv is not None:
        src = trace_jobs_source(trace_csv)
        label = f"A-SRPT (stream, csv:{trace_csv})"
    else:
        cfg = StreamTraceConfig(
            n_jobs=n_jobs,
            **(
                {} if arrival_rate is None
                else {"arrival_rate": arrival_rate}
            ),
        )
        src = stream_trace_source(cfg)
        label = f"A-SRPT (stream, {n_jobs} synthetic)"
    pol = ASRPTPolicy(make_predictor("mean"), tau=2.0)
    res = simulate(src, cluster, pol, validate=False)
    assert res.records is None  # streaming backend engaged
    row = _row(res.n_jobs, label, res)
    row["peak_rss_mb"] = _peak_rss_mb()
    return [row]


def sched_scale_straggler(full: bool = False) -> List[Dict]:
    """Degradation scenario: stragglers on the mixed cluster, stay vs move.

    Two A-SRPT runs over identical jobs + degradation events: the
    finish-in-place engine (every stretched job completes on its degraded
    placement) and the migration-capable engine (checkpoint-restart onto
    fresh capacity when the predicted-time race says it wins).
    ``flow_vs_stay`` < 1.0 on the migrate row is the headline: reacting
    to partial degradation beats riding it out.
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    for n in STRAGGLER_SIZES:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        stay = simulate(
            jobs, cluster, _asrpt(), validate=False, degradations=deg
        )
        rows.append(_row(n, "A-SRPT (straggler, stay)", stay))
        move = simulate(
            jobs, cluster, _asrpt(migrate=True), validate=False,
            degradations=deg,
        )
        mrow = _row(n, "A-SRPT (straggler, migrate)", move)
        mrow["flow_vs_stay"] = round(
            move.total_flow_time / stay.total_flow_time, 3
        )
        mrow["n_migrations"] = move.n_migrations
        rows.append(mrow)
        if full:
            pol = BASELINES["WCS-SubTime"](make_predictor("mean"))
            res = simulate(
                jobs, cluster, pol, validate=False, degradations=deg
            )
            rows.append(_row(n, "WCS-SubTime (straggler, stay)", res))
    return rows


def sched_scale_guard(full: bool = False) -> List[Dict]:
    """migration_queue_guard A/B (--guard): the straggler recipe at 20k
    jobs, migration-capable A-SRPT with the guard off vs on.

    The guard races a queued job's predicted start against the migration
    candidate's restart (migration.py): it blocks a checkpoint-restart
    whose freed-capacity claim would merely displace queued work.
    ``flow_vs_unguarded`` < 1.0 on the guard row means the queue-aware
    race wins at scale and the default should flip (ROADMAP carry-over
    from PR 4; decided by this row, see asrpt.py).
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    for n in STRAGGLER_SIZES:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        off = simulate(
            jobs, cluster,
            _asrpt(migrate=True, migration_queue_guard=False),
            validate=False, degradations=deg,
        )
        orow = _row(n, "A-SRPT (straggler, migrate, guard off)", off)
        orow["n_migrations"] = off.n_migrations
        rows.append(orow)
        on = simulate(
            jobs, cluster,
            _asrpt(migrate=True, migration_queue_guard=True),
            validate=False, degradations=deg,
        )
        grow = _row(n, "A-SRPT (straggler, migrate, guard on)", on)
        grow["n_migrations"] = on.n_migrations
        grow["flow_vs_unguarded"] = round(
            on.total_flow_time / off.total_flow_time, 4
        )
        rows.append(grow)
    return rows


# Elastic-capacity scenario (--elastic): four gen-a servers are absent
# from the start (ServerLeave at t=0 — e.g. delayed delivery or a
# maintenance window) and join at JOIN_AT_FRAC of the horizon.  The
# static rows never get them back; flow_vs_static on the join rows is
# the recovered flow time.  Runs at the straggler variant's moderate
# load: the join's value is absorbing the backlog the reduced cluster
# accumulated, which full saturation would mask (the queue never drains
# either way there).
ELASTIC_SIZES = (20_000,)
ELASTIC_SERVERS = (0, 1, 2, 3)  # gen-a, the biggest-fastest class
JOIN_AT_FRAC = 0.4


def sched_scale_elastic(full: bool = False) -> List[Dict]:
    """Elastic capacity: ServerJoin/ServerLeave events end to end.

    Two scenarios over identical jobs on the mixed-generation cluster,
    each under A-SRPT and a queue baseline: *static* (four gen-a servers
    absent for the whole trace) vs *join* (they come online at 40 % of
    the horizon).  ``flow_vs_static`` < 1.0 on the join rows is the
    headline: mid-trace capacity is converted into recovered flow time,
    and the settled-policy wake on ServerJoin starts queued work the
    moment it lands.
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    sizes = ELASTIC_SIZES + ((100_000,) if full else ())
    for n in sizes:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        horizon = n * STRAGGLER_SECONDS_PER_JOB
        static_sc = Scenario(
            jobs=tuple(jobs), cluster=cluster,
            events=tuple(elastic_events(ELASTIC_SERVERS, join_at=None)),
            name=f"elastic-static-{n}",
        )
        join_sc = Scenario(
            jobs=tuple(jobs), cluster=cluster,
            events=tuple(
                elastic_events(
                    ELASTIC_SERVERS, join_at=JOIN_AT_FRAC * horizon
                )
            ),
            name=f"elastic-join-{n}",
        )
        policies = [
            ("A-SRPT", _asrpt),
            (
                "WCS-SubTime",
                lambda: BASELINES["WCS-SubTime"](make_predictor("mean")),
            ),
        ]
        for pname, mk in policies:
            static = simulate(static_sc, mk(), validate=False)
            rows.append(_row(n, f"{pname} (elastic, static)", static))
            joined = simulate(join_sc, mk(), validate=False)
            jrow = _row(n, f"{pname} (elastic, join@40%)", joined)
            jrow["flow_vs_static"] = round(
                joined.total_flow_time / static.total_flow_time, 3
            )
            rows.append(jrow)
    return rows


def sched_scale_scenario(
    path: str,
    policy: str = "A-SRPT",
    migration_penalty: Optional[float] = None,
) -> List[Dict]:
    """Replay a saved Scenario JSON under one policy (--scenario FILE).

    The row carries the schedule sha256 (``SimResult.schedule_digest``)
    so a replay on another machine doubles as a bit-identity check for
    the matmul-free engines.  Matching a committed digest requires the
    policy config the fixture was recorded with — the golden straggler
    fixture used ``--migration-penalty 20`` (see tests/test_golden.py,
    which pins that digest in-process; the CI scenario-schema step only
    checks the replay runs end to end).
    """
    sc = Scenario.load(path)
    mig_kw = (
        {} if migration_penalty is None
        else {"migration_penalty": migration_penalty}
    )
    if policy == "A-SRPT":
        pol: ASRPTPolicy = ASRPTPolicy(
            make_predictor("mean"), tau=2.0,
            migrate=bool(sc.events), **mig_kw,
        )
    elif policy in BASELINES:
        pol = BASELINES[policy](
            make_predictor("mean"), migrate=bool(sc.events), **mig_kw
        )
    else:
        raise ValueError(
            f"unknown policy {policy!r} (A-SRPT or one of "
            f"{sorted(BASELINES)})"
        )
    res = simulate(sc, pol)
    row = _row(len(sc.jobs), f"{policy} @{sc.name or path}", res)
    row["n_migrations"] = res.n_migrations
    row["sha256"] = res.schedule_digest()
    return [row]


BUDGET_SAMPLES = 3  # best-of per row; shared runners swing tens of percent


def sched_scale_budget(straggler: bool = False) -> List[Dict]:
    """CI budget mode: one 5k-job size, every policy, best-of-3 samples.

    Small enough for a shared runner (~1 min), large enough that
    events/sec is dominated by the scheduling engine rather than setup.
    Each row reports the fastest of ``BUDGET_SAMPLES`` back-to-back runs
    (fresh policy and caches per run — every sample is a cold start):
    single samples swung tens of percent with host noise, drowning the
    regression signal the trend tracking exists for; best-of-3 follows
    the 20k cached/uncached comparison's sampling in ``sched_scale``.

    ``straggler=True`` appends the migration-capable straggler row (same
    mixed cluster and event recipe as ``sched_scale_straggler``, scaled
    to the budget size) so CI trends the degradation path's events/sec
    alongside everything else.
    """
    n = BUDGET_SIZE
    jobs = _trace(n)
    cluster = make_cluster(num_servers=NUM_SERVERS)

    def best_of(mk_policy, clu, faults=None, degradations=None, trace=None):
        run_jobs = jobs if trace is None else trace
        return min(
            (
                simulate(run_jobs, clu, mk_policy(), validate=False,
                         faults=faults, degradations=degradations)
                for _ in range(BUDGET_SAMPLES)
            ),
            key=lambda r: r.wall_s,
        )

    rows = [_row(n, "A-SRPT", best_of(_asrpt, cluster))]
    for name in BASELINES:
        rows.append(
            _row(
                n, name,
                best_of(lambda: BASELINES[name](make_predictor("mean")),
                        cluster),
            )
        )
    het = _hetero_cluster()
    horizon = n * SECONDS_PER_JOB
    faults = [(FAULT_AT_FRAC * horizon, m) for m in FAULT_SERVERS]
    res = best_of(_asrpt, het, faults=faults)
    rows.append(_row(n, "A-SRPT (hetero, 4 gen-a down)", res))
    if straggler:
        # the straggler recipe is moderate-load (see STRAGGLER_SECONDS_PER
        # _JOB): its own trace, same budget size and sampling
        sjobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        res = best_of(
            lambda: _asrpt(migrate=True), het, degradations=deg,
            trace=sjobs,
        )
        srow = _row(n, "A-SRPT (straggler, migrate)", res)
        srow["n_migrations"] = res.n_migrations
        rows.append(srow)
    return rows


# ---------------------------------------------------------------------------
# Scenario fleets (--fleet): Monte-Carlo robustness sweeps as a CI gate
# ---------------------------------------------------------------------------

# CI fleet regime: a 16-server mixed-generation cluster and a 300-job
# trace at moderate load, perturbed per variant by straggler + elastic +
# arrival-jitter samplers (repro.core.scenario).  The policy is
# migration-capable A-SRPT *without* refine_mapping: matmul-free engines
# produce cross-machine-stable schedule sha256s (same argument as the
# golden fixtures), so the committed baseline's per-variant digests are
# a hard bit-identity gate, not a tolerance band.
FLEET_VARIANTS_DEFAULT = 64
FLEET_JOBS = 300
FLEET_NUM_SERVERS = 16
FLEET_SECONDS_PER_JOB = 3 * SECONDS_PER_JOB  # moderate load, like --straggler

# --fleet-ab: the shared-cache speedup measurement runs the *refined*
# mapping engine (where cold placements dominate, ROADMAP 5a) over an
# exploration-heavy trace: ``recur_zipf_a=8`` makes nearly every job a
# distinct model configuration (a hyperparameter-search-style workload),
# so each sequential variant pays the full cold-placement working set
# while the fleet arm pays it once.  Small job count keeps 2 rounds x
# 256 sequential variants tractable.
FLEET_AB_VARIANTS = 256
FLEET_AB_JOBS = 60
FLEET_AB_NUM_SERVERS = 32


def _fleet_ab_base() -> Scenario:
    cluster = mixed_cluster_spec(num_servers=FLEET_AB_NUM_SERVERS, seed=0)
    jobs = generate_trace(
        TraceConfig(
            n_jobs=FLEET_AB_JOBS,
            horizon=FLEET_AB_JOBS * 3 * SECONDS_PER_JOB,
            seed=1,
            single_gpu_frac=0.05,
            max_gpus_per_job=128,
            mean_iters=400,
            sigma_iters=1.6,
            session_spread=120.0,
            recur_zipf_a=8.0,  # ~all groups singletons: max config diversity
        )
    )
    return Scenario(
        jobs=tuple(jobs), cluster=cluster,
        name=f"fleet-ab-base-{FLEET_AB_JOBS}",
    )


def _fleet_base(n_jobs: int = FLEET_JOBS) -> Scenario:
    cluster = mixed_cluster_spec(num_servers=FLEET_NUM_SERVERS, seed=0)
    jobs = _trace(n_jobs, seconds_per_job=FLEET_SECONDS_PER_JOB)
    return Scenario(
        jobs=tuple(jobs), cluster=cluster, name=f"fleet-base-{n_jobs}"
    )


def _fleet_perturbations():
    return (
        StragglerPerturbation(n_stragglers=3),
        ElasticPerturbation(n_servers=2),
        ArrivalJitterPerturbation(sigma=60.0),
    )


def _fleet_policy() -> ASRPTPolicy:
    return ASRPTPolicy(
        make_predictor("mean"), tau=2.0, refine_mapping=False, migrate=True
    )


def sched_scale_fleet(
    n_variants: int = FLEET_VARIANTS_DEFAULT, seed: int = 0
) -> Tuple[List[Dict], "object"]:
    """Run the CI fleet regime; returns (summary rows, FleetResult)."""
    fr = run_fleet(
        _fleet_base(),
        _fleet_policy,
        _fleet_perturbations(),
        n_variants,
        seed=seed,
    )
    flow = fr.stats["total_flow_time"]
    mig = fr.stats["n_migrations"]
    row = {
        "bench": "fleet",
        "n_variants": n_variants,
        "seed": seed,
        "flow_mean": f"{flow['mean']:.4e}",
        "flow_p50": f"{flow['p50']:.4e}",
        "flow_p95": f"{flow['p95']:.4e}",
        "makespan_p95": round(fr.stats["makespan"]["p95"], 1),
        "migrations_mean": round(mig["mean"], 2),
        "wall_s": round(fr.wall_s, 3),
        "fleet_digest": fr.digest(),
    }
    return [row], fr


def fleet_to_bench_json(fleet) -> Dict:
    """``FleetResult.to_dict()`` + the run timestamp (see
    ``rows_to_bench_json`` for why ``generated_at`` matters)."""
    from datetime import datetime, timezone

    out = fleet.to_dict()
    out["generated_at"] = datetime.now(timezone.utc).isoformat(
        timespec="seconds"
    )
    return out


def check_fleet_regression(
    current: Dict, baseline: Dict, threshold: float = 0.30
) -> Tuple[List[str], List[str], List[str]]:
    """Compare a fleet run against the committed baseline.

    Returns ``(errors, warnings, notes)``:

    * **errors** — per-variant schedule-sha mismatches at the same
      ``(seed, n_variants)``.  Schedules are deterministic functions of
      the seed on the matmul-free engine, so a mismatch is a behavior
      change (or a broken determinism guarantee), never runner noise —
      callers should exit nonzero even without ``--strict``.
    * **warnings** — p95 total-flow-time more than ``threshold`` above
      the baseline (robustness regression; ``--strict`` promotes to a
      failure, local runs stay fail-soft).
    * **notes** — informational lines (improvements, skipped checks on a
      malformed or mismatched-regime baseline).
    """
    errors: List[str] = []
    warnings: List[str] = []
    notes: List[str] = []

    base_dig = baseline.get("digests")
    cur_dig = current.get("digests")
    same_regime = (
        baseline.get("seed") == current.get("seed")
        and baseline.get("n_variants") == current.get("n_variants")
    )
    if not isinstance(base_dig, list) or not base_dig:
        notes.append("baseline has no per-variant digests; sha check skipped")
    elif not same_regime:
        notes.append(
            "baseline regime (seed/n_variants) differs; sha check skipped "
            "— refresh BENCH_fleet_baseline.json"
        )
    else:
        mismatches = [
            i
            for i, (b, c) in enumerate(zip(base_dig, cur_dig or []))
            if b != c
        ]
        if len(base_dig) != len(cur_dig or []):
            errors.append(
                f"digest count mismatch: baseline {len(base_dig)} vs "
                f"current {len(cur_dig or [])}"
            )
        elif mismatches:
            head = ", ".join(f"#v{i}" for i in mismatches[:5])
            errors.append(
                f"{len(mismatches)}/{len(base_dig)} variant schedule "
                f"sha256s differ from baseline at fixed seed "
                f"(first: {head}) — determinism or behavior change"
            )
        else:
            notes.append(
                f"all {len(base_dig)} variant schedule digests match "
                f"baseline"
            )

    try:
        ref = float(baseline["stats"]["total_flow_time"]["p95"])
        now = float(current["stats"]["total_flow_time"]["p95"])
    except (KeyError, TypeError, ValueError):
        notes.append("baseline has no p95 flow-time stats; check skipped")
    else:
        if ref > 0:
            ratio = now / ref
            if ratio > 1.0 + threshold:
                warnings.append(
                    f"p95 total flow time {now:.4e} is {ratio - 1:.0%} "
                    f"above baseline {ref:.4e}"
                )
            else:
                notes.append(
                    f"p95 total flow time {now:.4e} vs baseline "
                    f"{ref:.4e} ({ratio - 1:+.1%})"
                )
    return errors, warnings, notes


def sched_scale_fleet_ab(
    n_variants: int = FLEET_AB_VARIANTS, seed: int = 0, rounds: int = 2
) -> List[Dict]:
    """Interleaved fleet-vs-sequential A/B (--fleet-ab).

    Both arms run ``rounds`` times in alternation (fleet, sequential,
    fleet, ...) so host drift hits them symmetrically; each arm reports
    its best wall time (the sampling convention of the 20k
    cached/uncached comparison).  The sequential arm is ``run_fleet``
    with ``share=False, prewarm=False`` — exactly ``n_variants``
    independent ``simulate()`` calls with fresh caches.  The row asserts
    per-variant bit-identity between the arms before reporting
    ``fleet_speedup``.
    """
    base = _fleet_ab_base()
    perts = _fleet_perturbations()

    def mk():
        return _asrpt(migrate=True)  # refine_mapping=True regime

    fleet_walls: List[float] = []
    seq_walls: List[float] = []
    fleet_digest = seq_digest = None
    prewarm: Dict[str, float] = {}
    for _ in range(rounds):
        fr = run_fleet(base, mk, perts, n_variants, seed=seed)
        fleet_walls.append(fr.wall_s)
        fleet_digest = fr.digest()
        prewarm = fr.prewarm
        sr = run_fleet(
            base, mk, perts, n_variants, seed=seed,
            share=False, prewarm=False,
        )
        seq_walls.append(sr.wall_s)
        seq_digest = sr.digest()
    if fleet_digest != seq_digest:
        raise AssertionError(
            "fleet and sequential arms disagree: "
            f"{fleet_digest} != {seq_digest}"
        )
    row = {
        "bench": "fleet_ab",
        "n_variants": n_variants,
        "n_jobs": FLEET_AB_JOBS,
        "seed": seed,
        "rounds": rounds,
        "fleet_wall_s": round(min(fleet_walls), 3),
        "sequential_wall_s": round(min(seq_walls), 3),
        "fleet_speedup": round(min(seq_walls) / min(fleet_walls), 2),
        "digests_identical": True,
        "prewarm": prewarm,
    }
    return [row]


# ---------------------------------------------------------------------------
# Prediction-error robustness (--predict): flow time vs oracle per error model
# ---------------------------------------------------------------------------

# CI predict regime: the fleet's 16-server mixed cluster, a
# recurrence-heavy trace (low Zipf exponent -> large recurring groups,
# 70 % internally-constant groups: the MLaaS pattern the online forest
# exists to exploit, paper Fig. 4) at moderate load, and matmul-free
# A-SRPT (refine_mapping=False) so the oracle row's schedule sha256 is
# cross-machine stable.  Every non-oracle regime runs the full closed
# loop: jobs are scheduled on *predicted* iterations only, the forest
# retrains online from completions, and under-predicted jobs re-estimate
# mid-flight with exponential backoff (prediction_loop.py).
PREDICT_JOBS = 2_000
PREDICT_NUM_SERVERS = 16
# Moderate load, but lighter than the straggler/fleet regime: flow time
# under queueing amplifies *any* misprediction super-linearly (at 3x
# per-job seconds even sigma=0.3 lognormal noise doubles total flow), so
# the gateable signal — can the online forest *learn its way back to
# oracle* from recurrence — needs a regime where queues form and drain
# rather than compound.
PREDICT_SECONDS_PER_JOB = 4.5 * SECONDS_PER_JOB
PREDICT_FOREST_GATE = 1.30  # forest p95 flow must stay <= 1.3x oracle

# (regime name, prediction-model factory kwargs).  lognormal sigmas span
# the paper's Fig. 10 error sweep; rankflip is the adversarial
# order-inverting model (small jobs predicted big and vice versa).
PREDICT_REGIMES: Tuple[Tuple[str, str, Dict], ...] = (
    ("oracle", "oracle", {}),
    ("forest", "forest", {"seed": 0, "retrain_every": 300,
                          "n_estimators": 25, "max_history": 20_000}),
    ("zero-cold-start", "zero", {}),
    ("lognormal-0.3", "lognormal", {"sigma": 0.3, "seed": 0}),
    ("lognormal-0.7", "lognormal", {"sigma": 0.7, "seed": 0}),
    ("lognormal-1.2", "lognormal", {"sigma": 1.2, "seed": 0}),
    ("rankflip", "rankflip", {"seed": 0}),
)


def _predict_trace(n_jobs: int) -> list:
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=n_jobs * PREDICT_SECONDS_PER_JOB,
            seed=3,
            single_gpu_frac=0.3,
            max_gpus_per_job=32,
            mean_iters=400,
            sigma_iters=1.6,
            recur_zipf_a=1.4,  # heavy recurrence: the forest has history
            constant_group_frac=0.7,
            # Mostly-spread arrivals: a recurrence is only *learnable* if
            # an earlier group member completed first, so sessions that
            # dump a whole group inside one job duration (the throughput
            # regimes' burst_frac=0.7, spread=120 s) would make the
            # forest's history useless by construction.
            burst_frac=0.1,
        )
    )


def sched_scale_predict(n_jobs: Optional[int] = None) -> List[Dict]:
    """Misprediction-resilience sweep (--predict).

    One run per error regime over identical jobs/cluster; the oracle row
    (perfect predictions, no re-estimation — byte-identical to the
    legacy engine) anchors ``flow_vs_oracle`` / ``p95_vs_oracle`` on
    every other row.  ``n_reestimates`` counts mid-flight backoff
    re-estimations: ~log2(n_iters) per job under zero-cold-start (the
    worst case), a handful per job under the forest once it has trained.
    """
    if n_jobs is None:  # read at call time so tests can shrink the regime
        n_jobs = PREDICT_JOBS
    cluster = mixed_cluster_spec(num_servers=PREDICT_NUM_SERVERS, seed=0)
    jobs = _predict_trace(n_jobs)
    rows: List[Dict] = []
    oracle_flow = oracle_p95 = None
    for regime, kind, kw in PREDICT_REGIMES:
        model = make_prediction_model(kind, **kw)
        pol = ASRPTPolicy(model, tau=2.0, refine_mapping=False)
        res = simulate(jobs, cluster, pol, validate=False)
        flow = res.total_flow_time
        p95 = res.flow_percentile(95.0)
        row = {
            "bench": "predict",
            "n_jobs": res.n_jobs,
            "regime": regime,
            "wall_s": round(res.wall_s, 3),
            "total_flow": f"{flow:.4e}",
            "p95_flow": f"{p95:.4e}",
            "n_reestimates": res.n_reestimates,
        }
        if regime == "oracle":
            oracle_flow, oracle_p95 = flow, p95
            row["sha256"] = res.schedule_digest()
        else:
            row["flow_vs_oracle"] = round(flow / oracle_flow, 4)
            row["p95_vs_oracle"] = round(p95 / oracle_p95, 4)
        rows.append(row)
    return rows


def predict_to_bench_json(rows: Sequence[Dict]) -> Dict:
    """Per-regime vs-oracle ratios (the gated metrics) + the row dump."""
    from datetime import datetime, timezone

    ratios = {}
    for r in rows:
        if r["regime"] == "oracle":
            continue
        ratios[r["regime"]] = {
            "flow_vs_oracle": r["flow_vs_oracle"],
            "p95_vs_oracle": r["p95_vs_oracle"],
            "n_reestimates": r["n_reestimates"],
        }
    return {
        "schema": 1,
        "bench": "sched_scale_predict",
        "generated_at": datetime.now(timezone.utc).isoformat(
            timespec="seconds"
        ),
        "n_jobs": rows[0]["n_jobs"] if rows else 0,
        "forest_gate": PREDICT_FOREST_GATE,
        "oracle_sha256": next(
            (r["sha256"] for r in rows if r["regime"] == "oracle"), None
        ),
        "ratios": ratios,
        "rows": list(rows),
    }


def check_predict_regression(
    current: Dict, baseline: Dict, threshold: float = 0.15
) -> Tuple[List[str], List[str], List[str]]:
    """Compare a predict run against the committed baseline.

    Returns ``(errors, warnings, notes)``:

    * **errors** — the absolute acceptance gate: the online forest's p95
      flow time exceeds ``PREDICT_FOREST_GATE`` x oracle on the
      recurrence-heavy trace (the ISSUE 8 bound).  Absolute, not
      relative to the baseline — a drifted baseline must not launder a
      broken prediction loop.  Callers exit nonzero even without
      ``--strict``.
    * **warnings** — a regime's ``p95_vs_oracle`` drifted more than
      ``threshold`` above the committed baseline ratio (robustness
      regression; ``--strict`` promotes to failure).  Flow-time *ratios*
      are deterministic on the matmul-free engine, so drift means a
      behavior change, but stays fail-soft to allow intentional
      re-baselining.
    * **notes** — informational (improvements, skipped checks).
    """
    errors: List[str] = []
    warnings: List[str] = []
    notes: List[str] = []

    cur = current.get("ratios", {}) or {}
    gate = float(current.get("forest_gate", PREDICT_FOREST_GATE))
    forest = cur.get("forest")
    if forest is None:
        errors.append("current run has no forest regime — gate unchecked")
    else:
        ratio = float(forest["p95_vs_oracle"])
        if ratio > gate:
            errors.append(
                f"online-forest p95 flow is {ratio:.3f}x oracle, above "
                f"the {gate:.2f}x acceptance gate — the prediction loop "
                f"is not misprediction-resilient on this trace"
            )
        else:
            notes.append(
                f"forest p95 flow {ratio:.3f}x oracle (gate {gate:.2f}x)"
            )

    base = baseline.get("ratios")
    if not isinstance(base, dict) or not base:
        notes.append("baseline has no per-regime ratios; drift check "
                     "skipped")
        return errors, warnings, notes
    if baseline.get("n_jobs") != current.get("n_jobs"):
        notes.append("baseline regime (n_jobs) differs; drift check "
                     "skipped — refresh BENCH_predict_baseline.json")
        return errors, warnings, notes
    for regime, ref in sorted(base.items()):
        now = cur.get(regime)
        if now is None:
            warnings.append(f"{regime}: missing from current run")
            continue
        try:
            ref_r = float(ref["p95_vs_oracle"])
            now_r = float(now["p95_vs_oracle"])
        except (KeyError, TypeError, ValueError):
            notes.append(f"{regime}: malformed baseline entry; skipped")
            continue
        if ref_r > 0 and now_r > ref_r * (1.0 + threshold):
            warnings.append(
                f"{regime}: p95_vs_oracle {now_r:.3f} is "
                f"{now_r / ref_r - 1:.0%} above baseline {ref_r:.3f}"
            )
        else:
            notes.append(
                f"{regime}: p95_vs_oracle {now_r:.3f} vs baseline "
                f"{ref_r:.3f}"
            )
    for regime in sorted(set(cur) - set(base)):
        notes.append(f"{regime}: new regime (no baseline)")
    return errors, warnings, notes


# ---------------------------------------------------------------------------
# Serving co-schedule (--serve): SLO attainment + interference vs baseline
# ---------------------------------------------------------------------------

# CI serve regime: the predict variant's 16-server mixed cluster under
# a denser training load (2x the throughput regime's per-job horizon:
# queues form and persist, so lost capacity shows up in flow time),
# plus one diurnal request stream at production rate — ~1M requests
# over the 2.7-hour horizon, mean 100 req/s swinging +-50% over one
# full sinusoid cycle.
# Replicas run the committed engine-calibrated latency curve
# (repro.serve.latency.DEFAULT_SERVE_MODEL): one replica sustains ~324
# req/s at max_batch=8, so the lane autoscales under the diurnal peak
# and hands capacity back off-peak.  Each replica pins a *full*
# big-generation server (8 GPUs, the paper-scale tensor-parallel
# footprint), so training measurably loses capacity while the stream is
# live — the interference metric carries real signal.  A-SRPT runs
# matmul-free (refine_mapping=False) so the schedule sha256 is
# cross-machine stable.
SERVE_JOBS = 400
SERVE_NUM_SERVERS = 16
SERVE_SECONDS_PER_JOB = 2 * SECONDS_PER_JOB
SERVE_RATE = 100.0  # mean requests/s; the sinusoid averages back to this
SERVE_SLO = 0.2  # per-request deadline, seconds
SERVE_GPUS = 8  # GPUs per serving replica: a full big-generation server
SERVE_MAX_REPLICAS = 4
SERVE_MAX_BATCH = 8
SERVE_SLO_GATE = 0.995  # slo_attainment below this floor always fails


def _serve_stream(horizon: float) -> RequestStream:
    return RequestStream(
        stream_id=0,
        rate=SERVE_RATE,
        duration=horizon,
        slo=SERVE_SLO,
        diurnal_amplitude=0.5,
        diurnal_period=horizon,  # one full diurnal cycle inside the run
        gpus=SERVE_GPUS,
        max_replicas=SERVE_MAX_REPLICAS,
        max_batch=SERVE_MAX_BATCH,
        seed=0,
    )


def sched_scale_serve(n_jobs: Optional[int] = None) -> List[Dict]:
    """SLO-aware serving co-schedule (--serve).

    Two runs over identical jobs/cluster: train-only (the interference
    denominator) and mixed (the same trace plus the request stream).
    Request latency aggregates ride the bounded estimators
    (SERVE_LAT_QUANTILES), so the p99 row carries the documented <= 10%
    reservoir bound at this scale; SLO attainment and flow times are
    exact.
    """
    if n_jobs is None:  # read at call time so tests can shrink the regime
        n_jobs = SERVE_JOBS
    cluster = mixed_cluster_spec(num_servers=SERVE_NUM_SERVERS, seed=0)
    horizon = n_jobs * SERVE_SECONDS_PER_JOB
    jobs = generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=horizon,
            seed=3,
            single_gpu_frac=0.3,
            max_gpus_per_job=32,
            mean_iters=400,
            sigma_iters=1.6,
        )
    )

    def pol():
        return ASRPTPolicy(
            make_predictor("mean"), tau=2.0, refine_mapping=False
        )

    base = simulate(
        Scenario(jobs=jobs, cluster=cluster), pol(), validate=False
    )
    mixed = simulate(
        Scenario(jobs=jobs, cluster=cluster,
                 request_streams=(_serve_stream(horizon),)),
        pol(), validate=False,
    )
    return [
        {
            "bench": "serve",
            "metric": "slo_attainment",
            "value": round(mixed.slo_attainment, 5),
            "n_requests": mixed.n_requests,
            "n_slo_met": mixed.n_slo_met,
            "slo_s": SERVE_SLO,
        },
        {
            "bench": "serve",
            "metric": "p99_request_latency_s",
            "value": round(mixed.request_latency_percentile(99.0), 5),
            "p50_request_latency_s": round(
                mixed.request_latency_percentile(50.0), 5
            ),
            "mean_request_latency_s": round(
                mixed.mean_request_latency, 5
            ),
        },
        {
            "bench": "serve",
            "metric": "train_interference",
            "value": round(
                mixed.total_flow_time / base.total_flow_time, 4
            ),
            "n_jobs": mixed.n_jobs,
            "n_preemptions": mixed.n_preemptions,
            "mixed_flow": f"{mixed.total_flow_time:.4e}",
            "train_only_flow": f"{base.total_flow_time:.4e}",
            "sha256": mixed.schedule_digest(),
            "train_sha256": base.schedule_digest(),
            "wall_s": round(base.wall_s + mixed.wall_s, 3),
        },
    ]


def serve_to_bench_json(rows: Sequence[Dict]) -> Dict:
    """The three gated serving metrics + the row dump."""
    from datetime import datetime, timezone

    by = {r["metric"]: r for r in rows}
    tail = by.get("train_interference", {})
    return {
        "schema": 1,
        "bench": "sched_scale_serve",
        "generated_at": datetime.now(timezone.utc).isoformat(
            timespec="seconds"
        ),
        "n_jobs": tail.get("n_jobs", 0),
        "n_requests": by.get("slo_attainment", {}).get("n_requests", 0),
        "slo_gate": SERVE_SLO_GATE,
        "metrics": {
            m: by[m]["value"]
            for m in (
                "slo_attainment",
                "p99_request_latency_s",
                "train_interference",
            )
            if m in by
        },
        "sha256": tail.get("sha256"),
        "rows": list(rows),
    }


def check_serve_regression(
    current: Dict, baseline: Dict, threshold: float = 0.15
) -> Tuple[List[str], List[str], List[str]]:
    """Compare a serve run against the committed baseline.

    Returns ``(errors, warnings, notes)``:

    * **errors** — the absolute acceptance gate: ``slo_attainment``
      below ``SERVE_SLO_GATE`` (a drifted baseline must not launder a
      broken serving lane), and mixed-run schedule-sha mismatches at
      the same regime (the co-schedule is a deterministic function of
      the seed on the matmul-free engine, so a mismatch is a behavior
      change, never runner noise).  Callers exit nonzero even without
      ``--strict``.
    * **warnings** — p99 request latency or training interference more
      than ``threshold`` above the committed baseline (``--strict``
      promotes to failure; fail-soft stays the local default to allow
      intentional re-baselining).
    * **notes** — informational (improvements, skipped checks).
    """
    errors: List[str] = []
    warnings: List[str] = []
    notes: List[str] = []

    cur = current.get("metrics", {}) or {}
    gate = float(current.get("slo_gate", SERVE_SLO_GATE))
    slo = cur.get("slo_attainment")
    if slo is None:
        errors.append("current run has no slo_attainment — gate unchecked")
    elif float(slo) < gate:
        errors.append(
            f"SLO attainment {float(slo):.4f} is below the {gate} "
            f"acceptance floor — the serving lane is missing deadlines"
        )
    else:
        notes.append(
            f"SLO attainment {float(slo):.4f} (floor {gate})"
        )

    same_regime = (
        baseline.get("n_jobs") == current.get("n_jobs")
        and baseline.get("n_requests") == current.get("n_requests")
    )
    base_sha = baseline.get("sha256")
    if not base_sha:
        notes.append("baseline has no schedule sha256; sha check skipped")
    elif not same_regime:
        notes.append(
            "baseline regime (n_jobs/n_requests) differs; sha check "
            "skipped — refresh BENCH_serve_baseline.json"
        )
    elif base_sha != current.get("sha256"):
        errors.append(
            f"mixed-run schedule sha256 {current.get('sha256')} differs "
            f"from baseline {base_sha} at the fixed seed — determinism "
            f"or co-scheduling behavior change"
        )
    else:
        notes.append("mixed-run schedule digest matches baseline")

    base = baseline.get("metrics")
    if not isinstance(base, dict) or not base:
        notes.append("baseline has no metrics; drift check skipped")
        return errors, warnings, notes
    if not same_regime:
        notes.append(
            "baseline regime differs; drift check skipped — refresh "
            "BENCH_serve_baseline.json"
        )
        return errors, warnings, notes
    for metric in ("p99_request_latency_s", "train_interference"):
        try:
            ref = float(base[metric])
            now = float(cur[metric])
        except (KeyError, TypeError, ValueError):
            notes.append(f"{metric}: missing/malformed entry; skipped")
            continue
        if ref > 0 and now > ref * (1.0 + threshold):
            warnings.append(
                f"{metric}: {now:.4f} is {now / ref - 1:.0%} above "
                f"baseline {ref:.4f}"
            )
        else:
            notes.append(f"{metric}: {now:.4f} vs baseline {ref:.4f}")
    return errors, warnings, notes


# ---------------------------------------------------------------------------
# BENCH_sched.json emission + fail-soft regression check (CI trend tracking)
# ---------------------------------------------------------------------------


def rows_to_bench_json(rows: Sequence[Dict]) -> Dict:
    """events/sec per policy (the trended metric) + the full row dump.

    ``generated_at`` records when the benchmark actually ran —
    ``bench_trend.py`` orders artifacts by it (file mtimes are
    meaningless after an artifact download or a fresh checkout).
    """
    from datetime import datetime, timezone

    return {
        "schema": 1,
        "bench": "sched_scale_budget",
        "generated_at": datetime.now(timezone.utc).isoformat(
            timespec="seconds"
        ),
        "events_per_sec": {
            r["policy"]: r["events_per_sec"] for r in rows
        },
        "rows": list(rows),
    }


def check_regression(
    current: Dict, baseline: Dict, threshold: float = 0.30
) -> Tuple[List[str], List[str]]:
    """Compare per-policy events/sec against the committed baseline.

    Returns (warnings, notes).  A policy slower than ``baseline * (1 -
    threshold)`` warns; missing/new policies and faster runs are notes.
    Fail-soft by design: callers print, they don't exit nonzero.
    """
    warnings: List[str] = []
    notes: List[str] = []
    base = baseline.get("events_per_sec", {})
    cur = current.get("events_per_sec", {})
    for policy, ref in sorted(base.items()):
        now = cur.get(policy)
        if now is None:
            warnings.append(f"{policy}: missing from current run")
            continue
        if ref <= 0:
            continue
        ratio = now / ref
        if ratio < 1.0 - threshold:
            warnings.append(
                f"{policy}: {now:.0f} events/s is {1 - ratio:.0%} below "
                f"baseline {ref:.0f}"
            )
        else:
            notes.append(f"{policy}: {now:.0f} vs baseline {ref:.0f} events/s")
    for policy in sorted(set(cur) - set(base)):
        notes.append(f"{policy}: new policy (no baseline)")
    return warnings, notes


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--budget", action="store_true",
        help="CI budget mode (5k jobs, single sample per policy)",
    )
    ap.add_argument(
        "--hetero", action="store_true",
        help="mixed-generation cluster + fault-injection variant",
    )
    ap.add_argument(
        "--straggler", action="store_true",
        help="degradation scenario: mid-trace slowdowns on the mixed "
             "cluster, A-SRPT finish-in-place vs migration-capable "
             "(with --budget: append the migrate row to the trended set)",
    )
    ap.add_argument(
        "--elastic", action="store_true",
        help="elastic-capacity scenario: four gen-a servers absent from "
             "the start, joining at 40%% of the horizon (flow_vs_static "
             "< 1 = recovered flow time), A-SRPT + WCS-SubTime",
    )
    ap.add_argument(
        "--stream", metavar="N", nargs="?", const=STREAM_JOBS_DEFAULT,
        default=None, type=int,
        help="bounded-memory streaming replay of an N-job (default 1M) "
             "synthetic trace; reports events/sec and peak_rss_mb",
    )
    ap.add_argument(
        "--trace", metavar="FILE.csv", default=None,
        help="streaming replay of a datacenter-style CSV trace "
             "(Philly/PAI columns; see repro.core.trace_ingest)",
    )
    ap.add_argument(
        "--arrival-rate", metavar="JOBS_PER_SEC", default=None, type=float,
        help="synthetic stream arrival rate (--stream only; default "
             "~half utilization of the 64x8 cluster)",
    )
    ap.add_argument(
        "--max-rss-mb", metavar="MB", default=None, type=float,
        help="fail (exit 1) if peak RSS exceeds this ceiling "
             "(--stream/--trace only; the CI streaming-memory job "
             "enforces the bounded-memory guarantee with it)",
    )
    ap.add_argument(
        "--guard", action="store_true",
        help="migration_queue_guard A/B at 20k-job straggler scale "
             "(flow_vs_unguarded < 1 = the queue-aware race wins)",
    )
    ap.add_argument(
        "--scenario", metavar="FILE", default=None,
        help="replay a saved Scenario JSON (repro.core.scenario schema; "
             "see tests/golden/scenario_straggler.json) and print the "
             "schedule sha256; migration is enabled when the scenario "
             "carries events",
    )
    ap.add_argument(
        "--policy", metavar="NAME", default="A-SRPT",
        help="policy for --scenario replays: A-SRPT (default) or a "
             "baseline name (SPJF, SPWF, WCS-Duration, WCS-Workload, "
             "WCS-SubTime)",
    )
    ap.add_argument(
        "--migration-penalty", metavar="SECONDS", default=None, type=float,
        help="checkpoint-restart penalty for --scenario replays "
             "(default: migration.py's 120 s; the golden straggler "
             "fixture was recorded with 20)",
    )
    ap.add_argument(
        "--fleet", metavar="N", nargs="?", const=FLEET_VARIANTS_DEFAULT,
        default=None, type=int,
        help="Monte-Carlo robustness sweep: N seeded "
             "straggler+elastic+jitter perturbations of a base scenario "
             "through the shared-cache fleet driver (default "
             f"{FLEET_VARIANTS_DEFAULT} variants); --json writes the "
             "BENCH_fleet.json distribution + per-variant sha256s, "
             "--check compares against the committed fleet baseline",
    )
    ap.add_argument(
        "--fleet-ab", metavar="N", nargs="?", const=FLEET_AB_VARIANTS,
        default=None, type=int,
        help="interleaved fleet-vs-sequential A/B at N variants (default "
             f"{FLEET_AB_VARIANTS}) on the refined-mapping engine: "
             "asserts per-variant bit-identity, reports fleet_speedup",
    )
    ap.add_argument(
        "--predict", action="store_true",
        help="prediction-error robustness sweep: one closed-loop run per "
             "error model (oracle / online forest / zero-cold-start / "
             "lognormal noise / rankflip) on a recurrence-heavy trace, "
             "reporting flow-time-vs-oracle ratios; --json writes "
             "BENCH_predict.json, --check gates the forest ratio against "
             f"the committed baseline (p95 > {PREDICT_FOREST_GATE}x "
             "oracle always fails)",
    )
    ap.add_argument(
        "--serve", action="store_true",
        help="SLO-aware serving co-schedule: a diurnal ~1M-request "
             "stream (engine-calibrated batch latency) next to the "
             "moderate-load training trace, run train-only + mixed; "
             "reports slo_attainment / p99 request latency / training "
             "interference; --json writes BENCH_serve.json, --check "
             "gates slo_attainment against the absolute "
             f"{SERVE_SLO_GATE} floor (always fails below it) and the "
             "schedule sha256 vs the committed baseline",
    )
    ap.add_argument(
        "--seed", metavar="SEED", default=0, type=int,
        help="fleet RNG seed (--fleet/--fleet-ab; variant i draws from "
             "default_rng([seed, i]))",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write BENCH_sched.json-style output to PATH (--budget only: "
             "the trend file keys events/sec by policy name, which is only "
             "unique for the single-size budget run), BENCH_fleet.json "
             "output (--fleet), or BENCH_predict.json output (--predict)",
    )
    ap.add_argument(
        "--check", metavar="BASELINE", default=None,
        help="fail-soft events/sec comparison vs a baseline JSON "
             "(--budget), fleet digest + p95 flow-time comparison "
             "(--fleet; sha mismatches always fail), or prediction-"
             "robustness ratios (--predict; the forest gate always "
             "fails)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 when --check finds regressions past the threshold "
             "(CI gate mode; the local default stays fail-soft because "
             "shared-runner throughput swings tens of percent). Fleet "
             "sha mismatches fail regardless of --strict.",
    )
    ap.add_argument(
        "--profile", metavar="N", nargs="?", const=25, default=None,
        type=int,
        help="run under cProfile and dump the top-N functions by "
             "cumulative time (default 25) — locates scheduling hot "
             "paths without ad-hoc scripts",
    )
    args = ap.parse_args(argv)

    fleet_mode = args.fleet is not None
    if (args.json or args.check) and not (
        args.budget or fleet_mode or args.predict or args.serve
    ):
        ap.error("--json/--check track the budget-mode, fleet, predict, "
                 "or serve series; add --budget, --fleet, --predict, or "
                 "--serve")
    if args.strict and not args.check:
        ap.error("--strict promotes --check warnings to failures; add "
                 "--check")
    if sum((args.hetero, args.straggler, args.elastic, args.guard)) > 1:
        ap.error("--hetero/--straggler/--elastic/--guard are separate "
                 "variants")
    if (fleet_mode or args.fleet_ab is not None) and (
        args.budget or args.hetero or args.straggler or args.elastic
        or args.guard or args.full or args.scenario or args.predict
        or args.serve
        or args.stream is not None or args.trace is not None
    ):
        ap.error("--fleet/--fleet-ab are their own variants; drop other "
                 "flags")
    if args.predict and (
        args.budget or args.hetero or args.straggler or args.elastic
        or args.guard or args.full or args.scenario or args.serve
        or args.stream is not None or args.trace is not None
    ):
        ap.error("--predict is its own variant; drop other flags")
    if args.serve and (
        args.budget or args.hetero or args.straggler or args.elastic
        or args.guard or args.full or args.scenario
        or args.stream is not None or args.trace is not None
    ):
        ap.error("--serve is its own variant; drop other flags")
    if fleet_mode and args.fleet_ab is not None:
        ap.error("--fleet runs the CI sweep; --fleet-ab the speedup A/B — "
                 "pick one")
    if args.seed and not (fleet_mode or args.fleet_ab is not None):
        ap.error("--seed applies to --fleet/--fleet-ab")
    streaming = args.stream is not None or args.trace is not None
    if args.stream is not None and args.trace is not None:
        ap.error("--stream generates synthetically; --trace replays a "
                 "CSV — pick one")
    if (args.max_rss_mb is not None or args.arrival_rate is not None) \
            and not streaming:
        ap.error("--max-rss-mb/--arrival-rate apply to --stream/--trace")
    if streaming and (args.budget or args.hetero or args.straggler
                      or args.elastic or args.guard or args.full
                      or args.scenario):
        ap.error("--stream/--trace is its own variant; drop other flags")
    if args.scenario is None and (
        args.policy != "A-SRPT" or args.migration_penalty is not None
    ):
        ap.error("--policy/--migration-penalty apply to --scenario replays")
    fleet_result: List = []  # run() closure hands the FleetResult out
    if fleet_mode:
        def run():
            rows, fr = sched_scale_fleet(args.fleet, seed=args.seed)
            fleet_result.append(fr)
            return rows
    elif args.fleet_ab is not None:
        run = lambda: sched_scale_fleet_ab(  # noqa: E731
            args.fleet_ab, seed=args.seed
        )
    elif args.scenario is not None:
        if args.budget or args.hetero or args.straggler or args.elastic:
            ap.error("--scenario replays one file; drop the variant flags")
        run = lambda: sched_scale_scenario(  # noqa: E731
            args.scenario, policy=args.policy,
            migration_penalty=args.migration_penalty,
        )
    elif args.predict:
        run = lambda: sched_scale_predict()  # noqa: E731
    elif args.serve:
        run = lambda: sched_scale_serve()  # noqa: E731
    elif args.budget:
        if args.full:
            ap.error("--budget is fixed-size; drop --full (or use "
                     "--hetero/--full for the big sweeps)")
        run = lambda: sched_scale_budget(  # noqa: E731
            straggler=args.straggler
        )
    elif streaming:
        run = lambda: sched_scale_stream(  # noqa: E731
            n_jobs=args.stream or STREAM_JOBS_DEFAULT,
            trace_csv=args.trace,
            arrival_rate=args.arrival_rate,
        )
    elif args.guard:
        run = lambda: sched_scale_guard(full=args.full)  # noqa: E731
    elif args.hetero:
        run = lambda: sched_scale_hetero(full=args.full)  # noqa: E731
    elif args.elastic:
        run = lambda: sched_scale_elastic(full=args.full)  # noqa: E731
    elif args.straggler:
        run = lambda: sched_scale_straggler(full=args.full)  # noqa: E731
    else:
        run = lambda: sched_scale(full=args.full)  # noqa: E731

    if args.profile is not None:
        import cProfile
        import pstats

        profiler = cProfile.Profile()
        profiler.enable()
        rows = run()
        profiler.disable()
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(
            args.profile
        )
    else:
        rows = run()

    for r in rows:
        print(json.dumps(r))
    if args.max_rss_mb is not None:
        peak = max(r.get("peak_rss_mb", 0.0) for r in rows)
        if peak > args.max_rss_mb:
            print(
                f"::error::peak RSS {peak} MB exceeds the "
                f"{args.max_rss_mb} MB ceiling — the bounded-memory "
                f"guarantee regressed"
            )
            return 1
        print(f"peak RSS {peak} MB <= {args.max_rss_mb} MB ceiling")
    bench = None
    if args.json or args.check:
        if fleet_mode:
            bench = fleet_to_bench_json(fleet_result[0])
        elif args.predict:
            bench = predict_to_bench_json(rows)
        elif args.serve:
            bench = serve_to_bench_json(rows)
        else:
            bench = rows_to_bench_json(rows)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(bench, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")
    if args.check:
        try:
            with open(args.check) as fh:
                baseline = json.load(fh)
        except FileNotFoundError:
            print(f"::warning::no baseline at {args.check}; skipping check")
            return 1 if args.strict else 0
        except ValueError:
            print(f"::warning::unreadable baseline at {args.check}; "
                  f"skipping check")
            return 1 if args.strict else 0
        if fleet_mode:
            errors, warnings, notes = check_fleet_regression(bench, baseline)
            for line in notes:
                print(f"[fleet] {line}")
            for line in warnings:
                print(f"::warning::fleet regression: {line}")
            for line in errors:
                print(f"::error::fleet bit-identity: {line}")
            if errors:
                return 1  # sha mismatches fail even without --strict
            if warnings and args.strict:
                return 1
        elif args.predict:
            errors, warnings, notes = check_predict_regression(
                bench, baseline
            )
            for line in notes:
                print(f"[predict] {line}")
            for line in warnings:
                print(f"::warning::predict regression: {line}")
            for line in errors:
                print(f"::error::predict gate: {line}")
            if errors:
                return 1  # the forest gate fails even without --strict
            if warnings and args.strict:
                return 1
        elif args.serve:
            errors, warnings, notes = check_serve_regression(
                bench, baseline
            )
            for line in notes:
                print(f"[serve] {line}")
            for line in warnings:
                print(f"::warning::serve regression: {line}")
            for line in errors:
                print(f"::error::serve gate: {line}")
            if errors:
                return 1  # the SLO floor fails even without --strict
            if warnings and args.strict:
                return 1
        else:
            warnings, notes = check_regression(bench, baseline)
            for line in notes:
                print(f"[bench] {line}")
            for line in warnings:
                # GitHub Actions annotation; fail-soft by default (shared
                # runners are noisy) — --strict turns these into exit 1
                print(f"::warning::sched_scale regression: {line}")
            if warnings and args.strict:
                return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
