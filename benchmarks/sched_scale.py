"""Trace-scale scheduling benchmark: A-SRPT + baselines at 5k-100k jobs.

Regime ("placement stress at paper scale"): 64 servers x 8 GPUs (half the
paper's 2000-GPU simulation cluster), 80 % multi-GPU jobs up to 64 GPUs,
horizon scaled with the job count to keep the bursty moderate-load regime,
and A-SRPT running the refined (multi-start local-search) Heavy-Edge
mapping — the quality mode whose per-placement cost the placement cache is
designed to amortize.

Reported per row: wall seconds, events processed, events/sec, peak
pending-queue depth (policy-held jobs), total flow time.  At 20k jobs the
A-SRPT row is additionally run with ``placement_cache=False`` — the
exhaustive re-evaluation engine on the retained pure-Python reference
pipeline (dict-walk Heavy-Edge, per-(server, stage) beta alpha) — and
the cached/uncached events-per-sec ratio is reported as
``cache_speedup_20k`` (the two engines produce bit-identical schedules;
tests/test_sched_cache.py and tests/test_vectorized.py hold that
equivalence under property testing).

The 100k-job sweep runs A-SRPT always; the five baselines join at 100k
only under ``--full`` (they are each ~minutes at that scale).

This is a *throughput* benchmark: the regime deliberately saturates the
cluster (peak queue depths in the thousands), where strict head-of-line
policies trade flow time for order fidelity.  Scheduling-quality
comparisons against the paper belong to fig6/fig7/fig8.

Variants:

* ``sched_scale_hetero`` — the same regime on a mixed-generation cluster
  (three server classes: 100 GbE 8-GPU, 10 GbE 8-GPU, 10 GbE 4-GPU), run
  twice per size: clean, and with a fault injection downing four big-GPU
  (100 GbE 8x) servers a quarter into the horizon.  The fault row reports
  ``flow_vs_clean`` — degraded-cluster recovery flow time relative to the
  clean run.
* ``--straggler`` / ``sched_scale_straggler`` — degradation scenario on
  the mixed-generation cluster: mid-trace slowdown events sampled by
  ``trace.straggler_events`` hit four big-GPU servers, and A-SRPT runs
  twice — finish-in-place vs migration-capable (checkpoint-restart off
  the degraded servers).  The migration row reports ``flow_vs_stay``
  (total flow time relative to finish-in-place; < 1.0 means migration
  wins) and the migration count.
* ``--elastic`` / ``sched_scale_elastic`` — elastic-capacity scenario
  (ServerJoin/ServerLeave events, see repro.core.scenario): four gen-a
  servers are absent from the start; the *static* rows ride out the
  trace on the reduced cluster, the *join* rows get the capacity back
  mid-trace and report ``flow_vs_static`` (< 1.0 = recovered flow
  time), under A-SRPT and a queue baseline.
* ``--scenario FILE`` — replay any saved ``Scenario`` JSON (the format
  ``tests/golden/scenario_straggler.json`` instantiates; see
  scenario.py) under ``--policy`` (default A-SRPT).  The row includes
  the schedule sha256, so replays double as cross-machine equivalence
  checks.
* ``--stream [N]`` / ``sched_scale_stream`` — bounded-memory replay: an
  ``N``-job (default 1M) synthetic trace is *generated, scheduled, and
  folded into aggregates lazily* — no jobs list, no records dict — and
  the row reports events/sec plus ``peak_rss_mb`` (getrusage max RSS,
  whole process).  ``--max-rss-mb`` turns the memory claim into an
  enforced exit code (the CI streaming-memory job runs 1M jobs under a
  ceiling).  See benchmarks/README.md for the bounded-memory guarantee.
* ``--trace FILE.csv`` — the same streaming replay over a real
  datacenter-style CSV trace (Philly/PAI columns; see
  repro.core.trace_ingest for the format and malformed-row policy).
* ``--guard`` — migration_queue_guard A/B at the straggler variant's
  20k-job scale: the unguarded migrate row vs the queue-aware race, with
  ``flow_vs_unguarded`` as the verdict column.
* ``--budget`` / ``sched_scale_budget`` — a CI-sized subset (one size,
  best-of-3 cold-start samples per policy) whose events/sec per policy is
  written to ``BENCH_sched.json`` for trend tracking; ``--check``
  compares against a committed baseline and *warns* (never fails) past
  the threshold, since shared CI runners swing tens of percent.
  ``--budget --straggler`` appends the straggler migration row to the
  trended set.
* ``--profile [N]`` — run the selected variant under cProfile and dump
  the top-N cumulative entries (hot-path triage without ad-hoc scripts).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    Scenario,
    ServerClass,
    StreamTraceConfig,
    TraceConfig,
    elastic_events,
    generate_trace,
    make_predictor,
    simulate,
    straggler_events,
    stream_trace_source,
    trace_jobs_source,
)

from .common import make_cluster

NUM_SERVERS = 64
SINGLE_GPU_FRAC = 0.2
MAX_GPUS_PER_JOB = 64
SECONDS_PER_JOB = 12.0  # horizon = n_jobs * this
SIZES = (5_000, 20_000, 100_000)
COMPARE_AT = 20_000  # cached vs uncached measurement point

# Mixed-generation variant: same total server count, three classes.  The
# first class is the "big GPU" generation the fault injection targets.
HETERO_CLASSES = (
    ServerClass(count=24, gpus_per_server=8, b_inter=12.5e9, name="gen-a"),
    ServerClass(count=24, gpus_per_server=8, b_inter=1.25e9, name="gen-b"),
    ServerClass(
        count=16, gpus_per_server=4, b_inter=1.25e9, b_intra=50e9,
        name="gen-c",
    ),
)
HETERO_SIZES = (20_000, 100_000)
FAULT_SERVERS = (0, 1, 2, 3)  # four gen-a servers
FAULT_AT_FRAC = 0.25  # of the trace horizon

BUDGET_SIZE = 5_000  # --budget: one size, single sample per policy

# Straggler scenario: four gen-a servers slow mid-trace (factors sampled
# in [0.25, 0.6]); no recovery inside the 20k run's window, so stretched
# jobs stay stretched unless migrated.  The checkpoint-restart penalty is
# the migration.py default (120 s) — small against the multiplied
# remaining time of a job slowed to a quarter speed.  The variant runs at
# *moderate* load (3x the throughput regime's horizon): migration's win
# comes from converting idle healthy capacity into useful work, which the
# deliberately-saturated throughput regime has none of — there every GPU
# is always busy, so moving a stretched job merely hands its degraded
# GPUs (and their slowdown) to the next queued job and pays the restart
# penalty on top (measurably: flow_vs_stay ~1.02 at full saturation).
STRAGGLER_SIZES = (20_000,)
STRAGGLER_N = 4
STRAGGLER_FACTORS = (0.25, 0.6)
STRAGGLER_WINDOW = (0.2, 0.5)  # event times, fraction of the horizon
STRAGGLER_SECONDS_PER_JOB = 3 * SECONDS_PER_JOB


def _trace(
    n_jobs: int, seed: int = 1, seconds_per_job: float = SECONDS_PER_JOB
) -> list:
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=n_jobs * seconds_per_job,
            seed=seed,
            single_gpu_frac=SINGLE_GPU_FRAC,
            max_gpus_per_job=MAX_GPUS_PER_JOB,
            mean_iters=400,
            sigma_iters=1.6,
            session_spread=120.0,
        )
    )


def _asrpt(placement_cache: bool = True, **kw) -> ASRPTPolicy:
    return ASRPTPolicy(
        make_predictor("mean"),
        tau=2.0,
        refine_mapping=True,
        placement_cache=placement_cache,
        **kw,
    )


def _straggler_degradations(n_jobs: int, seed: int = 2) -> list:
    """Mid-trace slowdowns on gen-a (ids 0..23 in HETERO_CLASSES) servers;
    no recovery — finish-in-place pays the full stretch."""
    return straggler_events(
        HETERO_CLASSES[0].count,
        n_jobs * STRAGGLER_SECONDS_PER_JOB,
        n_stragglers=STRAGGLER_N,
        seed=seed,
        factor_low=STRAGGLER_FACTORS[0],
        factor_high=STRAGGLER_FACTORS[1],
        start_frac=STRAGGLER_WINDOW,
        recover=False,
    )


def _row(n_jobs: int, policy_name: str, res) -> Dict:
    return {
        "n_jobs": n_jobs,
        "policy": policy_name,
        "wall_s": round(res.wall_s, 3),
        "events": res.n_events,
        "events_per_sec": round(res.events_per_sec, 1),
        "peak_queue_depth": res.peak_queue_depth,
        "total_flow": f"{res.total_flow_time:.4e}",
    }


def sched_scale(full: bool = False) -> List[Dict]:
    cluster = make_cluster(num_servers=NUM_SERVERS)
    rows: List[Dict] = []
    for n in SIZES:
        jobs = _trace(n)
        res_c = simulate(jobs, cluster, _asrpt(), validate=False)

        if n == COMPARE_AT:
            # Best-of-3 per engine (symmetric), back to back: the cached
            # run is short enough that a single sample swings tens of
            # percent with host noise, and the ratio is the headline
            # number.
            for _ in range(2):
                r2 = simulate(jobs, cluster, _asrpt(), validate=False)
                if r2.wall_s < res_c.wall_s:
                    res_c = r2
            rows.append(_row(n, "A-SRPT", res_c))
            res_u = min(
                (
                    simulate(jobs, cluster, _asrpt(False), validate=False)
                    for _ in range(3)
                ),
                key=lambda r: r.wall_s,
            )
            row = _row(n, "A-SRPT (uncached)", res_u)
            row["cache_speedup_20k"] = round(
                res_c.events_per_sec / res_u.events_per_sec, 2
            )
            rows.append(row)
        else:
            rows.append(_row(n, "A-SRPT", res_c))

        if n < 100_000 or full:
            for name in BASELINES:
                pol = BASELINES[name](make_predictor("mean"))
                res = simulate(jobs, cluster, pol, validate=False)
                rows.append(_row(n, name, res))
    return rows


def _hetero_cluster() -> ClusterSpec:
    return ClusterSpec.heterogeneous(HETERO_CLASSES, b_intra=300e9)


def sched_scale_hetero(full: bool = False) -> List[Dict]:
    """Mixed-generation cluster + degraded-cluster recovery flow time."""
    cluster = _hetero_cluster()
    sizes = HETERO_SIZES if full else HETERO_SIZES[:1]
    rows: List[Dict] = []
    for n in sizes:
        jobs = _trace(n)
        horizon = n * SECONDS_PER_JOB
        clean = simulate(jobs, cluster, _asrpt(), validate=False)
        row = _row(n, "A-SRPT (hetero)", clean)
        rows.append(row)
        faults = [(FAULT_AT_FRAC * horizon, m) for m in FAULT_SERVERS]
        degraded = simulate(
            jobs, cluster, _asrpt(), validate=False, faults=faults
        )
        drow = _row(n, "A-SRPT (hetero, 4 gen-a down)", degraded)
        drow["flow_vs_clean"] = round(
            degraded.total_flow_time / clean.total_flow_time, 3
        )
        rows.append(drow)
        if n <= 20_000:
            for name in ("SPJF", "WCS-SubTime"):
                pol = BASELINES[name](make_predictor("mean"))
                res = simulate(jobs, cluster, pol, validate=False)
                rows.append(_row(n, f"{name} (hetero)", res))
    return rows


def _peak_rss_mb() -> float:
    """Whole-process peak resident set, MB (ru_maxrss is KB on Linux)."""
    import resource
    import sys

    kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":  # bytes there
        kb /= 1024.0
    return round(kb / 1024.0, 1)


STREAM_JOBS_DEFAULT = 1_000_000


def sched_scale_stream(
    n_jobs: int = STREAM_JOBS_DEFAULT,
    trace_csv: Optional[str] = None,
    arrival_rate: Optional[float] = None,
) -> List[Dict]:
    """Bounded-memory streaming replay (--stream / --trace FILE.csv).

    The jobs source is lazy (``stream_trace`` chunks or a CSV line
    reader), the simulator feeds its arrival heap incrementally, and the
    result backend folds each completed record into running aggregates —
    so resident memory scales with the *live* job count (peak queue
    depth), not the trace length.  ``peak_rss_mb`` on the row is the
    measured whole-process ceiling; at the default half-utilization
    arrival rate a million jobs stay in the tens of MB.

    The policy is the cached A-SRPT engine without refine_mapping (the
    throughput configuration); the predictor is the O(1)-per-group
    running-mean.  With ``trace_csv`` the row replays the CSV instead of
    the synthetic stream (same cluster, same policy).
    """
    cluster = make_cluster(num_servers=NUM_SERVERS)
    if trace_csv is not None:
        src = trace_jobs_source(trace_csv)
        label = f"A-SRPT (stream, csv:{trace_csv})"
    else:
        cfg = StreamTraceConfig(
            n_jobs=n_jobs,
            **(
                {} if arrival_rate is None
                else {"arrival_rate": arrival_rate}
            ),
        )
        src = stream_trace_source(cfg)
        label = f"A-SRPT (stream, {n_jobs} synthetic)"
    pol = ASRPTPolicy(make_predictor("mean"), tau=2.0)
    res = simulate(src, cluster, pol, validate=False)
    assert res.records is None  # streaming backend engaged
    row = _row(res.n_jobs, label, res)
    row["peak_rss_mb"] = _peak_rss_mb()
    return [row]


def sched_scale_straggler(full: bool = False) -> List[Dict]:
    """Degradation scenario: stragglers on the mixed cluster, stay vs move.

    Two A-SRPT runs over identical jobs + degradation events: the
    finish-in-place engine (every stretched job completes on its degraded
    placement) and the migration-capable engine (checkpoint-restart onto
    fresh capacity when the predicted-time race says it wins).
    ``flow_vs_stay`` < 1.0 on the migrate row is the headline: reacting
    to partial degradation beats riding it out.
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    for n in STRAGGLER_SIZES:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        stay = simulate(
            jobs, cluster, _asrpt(), validate=False, degradations=deg
        )
        rows.append(_row(n, "A-SRPT (straggler, stay)", stay))
        move = simulate(
            jobs, cluster, _asrpt(migrate=True), validate=False,
            degradations=deg,
        )
        mrow = _row(n, "A-SRPT (straggler, migrate)", move)
        mrow["flow_vs_stay"] = round(
            move.total_flow_time / stay.total_flow_time, 3
        )
        mrow["n_migrations"] = move.n_migrations
        rows.append(mrow)
        if full:
            pol = BASELINES["WCS-SubTime"](make_predictor("mean"))
            res = simulate(
                jobs, cluster, pol, validate=False, degradations=deg
            )
            rows.append(_row(n, "WCS-SubTime (straggler, stay)", res))
    return rows


def sched_scale_guard(full: bool = False) -> List[Dict]:
    """migration_queue_guard A/B (--guard): the straggler recipe at 20k
    jobs, migration-capable A-SRPT with the guard off vs on.

    The guard races a queued job's predicted start against the migration
    candidate's restart (migration.py): it blocks a checkpoint-restart
    whose freed-capacity claim would merely displace queued work.
    ``flow_vs_unguarded`` < 1.0 on the guard row means the queue-aware
    race wins at scale and the default should flip (ROADMAP carry-over
    from PR 4; decided by this row, see asrpt.py).
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    for n in STRAGGLER_SIZES:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        off = simulate(
            jobs, cluster,
            _asrpt(migrate=True, migration_queue_guard=False),
            validate=False, degradations=deg,
        )
        orow = _row(n, "A-SRPT (straggler, migrate, guard off)", off)
        orow["n_migrations"] = off.n_migrations
        rows.append(orow)
        on = simulate(
            jobs, cluster,
            _asrpt(migrate=True, migration_queue_guard=True),
            validate=False, degradations=deg,
        )
        grow = _row(n, "A-SRPT (straggler, migrate, guard on)", on)
        grow["n_migrations"] = on.n_migrations
        grow["flow_vs_unguarded"] = round(
            on.total_flow_time / off.total_flow_time, 4
        )
        rows.append(grow)
    return rows


# Elastic-capacity scenario (--elastic): four gen-a servers are absent
# from the start (ServerLeave at t=0 — e.g. delayed delivery or a
# maintenance window) and join at JOIN_AT_FRAC of the horizon.  The
# static rows never get them back; flow_vs_static on the join rows is
# the recovered flow time.  Runs at the straggler variant's moderate
# load: the join's value is absorbing the backlog the reduced cluster
# accumulated, which full saturation would mask (the queue never drains
# either way there).
ELASTIC_SIZES = (20_000,)
ELASTIC_SERVERS = (0, 1, 2, 3)  # gen-a, the biggest-fastest class
JOIN_AT_FRAC = 0.4


def sched_scale_elastic(full: bool = False) -> List[Dict]:
    """Elastic capacity: ServerJoin/ServerLeave events end to end.

    Two scenarios over identical jobs on the mixed-generation cluster,
    each under A-SRPT and a queue baseline: *static* (four gen-a servers
    absent for the whole trace) vs *join* (they come online at 40 % of
    the horizon).  ``flow_vs_static`` < 1.0 on the join rows is the
    headline: mid-trace capacity is converted into recovered flow time,
    and the settled-policy wake on ServerJoin starts queued work the
    moment it lands.
    """
    cluster = _hetero_cluster()
    rows: List[Dict] = []
    sizes = ELASTIC_SIZES + ((100_000,) if full else ())
    for n in sizes:
        jobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        horizon = n * STRAGGLER_SECONDS_PER_JOB
        static_sc = Scenario(
            jobs=tuple(jobs), cluster=cluster,
            events=tuple(elastic_events(ELASTIC_SERVERS, join_at=None)),
            name=f"elastic-static-{n}",
        )
        join_sc = Scenario(
            jobs=tuple(jobs), cluster=cluster,
            events=tuple(
                elastic_events(
                    ELASTIC_SERVERS, join_at=JOIN_AT_FRAC * horizon
                )
            ),
            name=f"elastic-join-{n}",
        )
        policies = [
            ("A-SRPT", _asrpt),
            (
                "WCS-SubTime",
                lambda: BASELINES["WCS-SubTime"](make_predictor("mean")),
            ),
        ]
        for pname, mk in policies:
            static = simulate(static_sc, mk(), validate=False)
            rows.append(_row(n, f"{pname} (elastic, static)", static))
            joined = simulate(join_sc, mk(), validate=False)
            jrow = _row(n, f"{pname} (elastic, join@40%)", joined)
            jrow["flow_vs_static"] = round(
                joined.total_flow_time / static.total_flow_time, 3
            )
            rows.append(jrow)
    return rows


def sched_scale_scenario(
    path: str,
    policy: str = "A-SRPT",
    migration_penalty: Optional[float] = None,
) -> List[Dict]:
    """Replay a saved Scenario JSON under one policy (--scenario FILE).

    The row carries the schedule sha256 (``SimResult.schedule_digest``)
    so a replay on another machine doubles as a bit-identity check for
    the matmul-free engines.  Matching a committed digest requires the
    policy config the fixture was recorded with — the golden straggler
    fixture used ``--migration-penalty 20`` (see tests/test_golden.py,
    which pins that digest in-process; the CI scenario-schema step only
    checks the replay runs end to end).
    """
    sc = Scenario.load(path)
    mig_kw = (
        {} if migration_penalty is None
        else {"migration_penalty": migration_penalty}
    )
    if policy == "A-SRPT":
        pol: ASRPTPolicy = ASRPTPolicy(
            make_predictor("mean"), tau=2.0,
            migrate=bool(sc.events), **mig_kw,
        )
    elif policy in BASELINES:
        pol = BASELINES[policy](
            make_predictor("mean"), migrate=bool(sc.events), **mig_kw
        )
    else:
        raise ValueError(
            f"unknown policy {policy!r} (A-SRPT or one of "
            f"{sorted(BASELINES)})"
        )
    res = simulate(sc, pol)
    row = _row(len(sc.jobs), f"{policy} @{sc.name or path}", res)
    row["n_migrations"] = res.n_migrations
    row["sha256"] = res.schedule_digest()
    return [row]


BUDGET_SAMPLES = 3  # best-of per row; shared runners swing tens of percent


def sched_scale_budget(straggler: bool = False) -> List[Dict]:
    """CI budget mode: one 5k-job size, every policy, best-of-3 samples.

    Small enough for a shared runner (~1 min), large enough that
    events/sec is dominated by the scheduling engine rather than setup.
    Each row reports the fastest of ``BUDGET_SAMPLES`` back-to-back runs
    (fresh policy and caches per run — every sample is a cold start):
    single samples swung tens of percent with host noise, drowning the
    regression signal the trend tracking exists for; best-of-3 follows
    the 20k cached/uncached comparison's sampling in ``sched_scale``.

    ``straggler=True`` appends the migration-capable straggler row (same
    mixed cluster and event recipe as ``sched_scale_straggler``, scaled
    to the budget size) so CI trends the degradation path's events/sec
    alongside everything else.
    """
    n = BUDGET_SIZE
    jobs = _trace(n)
    cluster = make_cluster(num_servers=NUM_SERVERS)

    def best_of(mk_policy, clu, faults=None, degradations=None, trace=None):
        run_jobs = jobs if trace is None else trace
        return min(
            (
                simulate(run_jobs, clu, mk_policy(), validate=False,
                         faults=faults, degradations=degradations)
                for _ in range(BUDGET_SAMPLES)
            ),
            key=lambda r: r.wall_s,
        )

    rows = [_row(n, "A-SRPT", best_of(_asrpt, cluster))]
    for name in BASELINES:
        rows.append(
            _row(
                n, name,
                best_of(lambda: BASELINES[name](make_predictor("mean")),
                        cluster),
            )
        )
    het = _hetero_cluster()
    horizon = n * SECONDS_PER_JOB
    faults = [(FAULT_AT_FRAC * horizon, m) for m in FAULT_SERVERS]
    res = best_of(_asrpt, het, faults=faults)
    rows.append(_row(n, "A-SRPT (hetero, 4 gen-a down)", res))
    if straggler:
        # the straggler recipe is moderate-load (see STRAGGLER_SECONDS_PER
        # _JOB): its own trace, same budget size and sampling
        sjobs = _trace(n, seconds_per_job=STRAGGLER_SECONDS_PER_JOB)
        deg = _straggler_degradations(n)
        res = best_of(
            lambda: _asrpt(migrate=True), het, degradations=deg,
            trace=sjobs,
        )
        srow = _row(n, "A-SRPT (straggler, migrate)", res)
        srow["n_migrations"] = res.n_migrations
        rows.append(srow)
    return rows


# ---------------------------------------------------------------------------
# BENCH_sched.json emission + fail-soft regression check (CI trend tracking)
# ---------------------------------------------------------------------------


def rows_to_bench_json(rows: Sequence[Dict]) -> Dict:
    """events/sec per policy (the trended metric) + the full row dump.

    ``generated_at`` records when the benchmark actually ran —
    ``bench_trend.py`` orders artifacts by it (file mtimes are
    meaningless after an artifact download or a fresh checkout).
    """
    from datetime import datetime, timezone

    return {
        "schema": 1,
        "bench": "sched_scale_budget",
        "generated_at": datetime.now(timezone.utc).isoformat(
            timespec="seconds"
        ),
        "events_per_sec": {
            r["policy"]: r["events_per_sec"] for r in rows
        },
        "rows": list(rows),
    }


def check_regression(
    current: Dict, baseline: Dict, threshold: float = 0.30
) -> Tuple[List[str], List[str]]:
    """Compare per-policy events/sec against the committed baseline.

    Returns (warnings, notes).  A policy slower than ``baseline * (1 -
    threshold)`` warns; missing/new policies and faster runs are notes.
    Fail-soft by design: callers print, they don't exit nonzero.
    """
    warnings: List[str] = []
    notes: List[str] = []
    base = baseline.get("events_per_sec", {})
    cur = current.get("events_per_sec", {})
    for policy, ref in sorted(base.items()):
        now = cur.get(policy)
        if now is None:
            warnings.append(f"{policy}: missing from current run")
            continue
        if ref <= 0:
            continue
        ratio = now / ref
        if ratio < 1.0 - threshold:
            warnings.append(
                f"{policy}: {now:.0f} events/s is {1 - ratio:.0%} below "
                f"baseline {ref:.0f}"
            )
        else:
            notes.append(f"{policy}: {now:.0f} vs baseline {ref:.0f} events/s")
    for policy in sorted(set(cur) - set(base)):
        notes.append(f"{policy}: new policy (no baseline)")
    return warnings, notes


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--budget", action="store_true",
        help="CI budget mode (5k jobs, single sample per policy)",
    )
    ap.add_argument(
        "--hetero", action="store_true",
        help="mixed-generation cluster + fault-injection variant",
    )
    ap.add_argument(
        "--straggler", action="store_true",
        help="degradation scenario: mid-trace slowdowns on the mixed "
             "cluster, A-SRPT finish-in-place vs migration-capable "
             "(with --budget: append the migrate row to the trended set)",
    )
    ap.add_argument(
        "--elastic", action="store_true",
        help="elastic-capacity scenario: four gen-a servers absent from "
             "the start, joining at 40%% of the horizon (flow_vs_static "
             "< 1 = recovered flow time), A-SRPT + WCS-SubTime",
    )
    ap.add_argument(
        "--stream", metavar="N", nargs="?", const=STREAM_JOBS_DEFAULT,
        default=None, type=int,
        help="bounded-memory streaming replay of an N-job (default 1M) "
             "synthetic trace; reports events/sec and peak_rss_mb",
    )
    ap.add_argument(
        "--trace", metavar="FILE.csv", default=None,
        help="streaming replay of a datacenter-style CSV trace "
             "(Philly/PAI columns; see repro.core.trace_ingest)",
    )
    ap.add_argument(
        "--arrival-rate", metavar="JOBS_PER_SEC", default=None, type=float,
        help="synthetic stream arrival rate (--stream only; default "
             "~half utilization of the 64x8 cluster)",
    )
    ap.add_argument(
        "--max-rss-mb", metavar="MB", default=None, type=float,
        help="fail (exit 1) if peak RSS exceeds this ceiling "
             "(--stream/--trace only; the CI streaming-memory job "
             "enforces the bounded-memory guarantee with it)",
    )
    ap.add_argument(
        "--guard", action="store_true",
        help="migration_queue_guard A/B at 20k-job straggler scale "
             "(flow_vs_unguarded < 1 = the queue-aware race wins)",
    )
    ap.add_argument(
        "--scenario", metavar="FILE", default=None,
        help="replay a saved Scenario JSON (repro.core.scenario schema; "
             "see tests/golden/scenario_straggler.json) and print the "
             "schedule sha256; migration is enabled when the scenario "
             "carries events",
    )
    ap.add_argument(
        "--policy", metavar="NAME", default="A-SRPT",
        help="policy for --scenario replays: A-SRPT (default) or a "
             "baseline name (SPJF, SPWF, WCS-Duration, WCS-Workload, "
             "WCS-SubTime)",
    )
    ap.add_argument(
        "--migration-penalty", metavar="SECONDS", default=None, type=float,
        help="checkpoint-restart penalty for --scenario replays "
             "(default: migration.py's 120 s; the golden straggler "
             "fixture was recorded with 20)",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write BENCH_sched.json-style output to PATH (--budget only: "
             "the trend file keys events/sec by policy name, which is only "
             "unique for the single-size budget run)",
    )
    ap.add_argument(
        "--check", metavar="BASELINE", default=None,
        help="fail-soft events/sec comparison vs a baseline JSON "
             "(--budget only)",
    )
    ap.add_argument(
        "--profile", metavar="N", nargs="?", const=25, default=None,
        type=int,
        help="run under cProfile and dump the top-N functions by "
             "cumulative time (default 25) — locates scheduling hot "
             "paths without ad-hoc scripts",
    )
    args = ap.parse_args(argv)

    if (args.json or args.check) and not args.budget:
        ap.error("--json/--check track the budget-mode series; add --budget")
    if sum((args.hetero, args.straggler, args.elastic, args.guard)) > 1:
        ap.error("--hetero/--straggler/--elastic/--guard are separate "
                 "variants")
    streaming = args.stream is not None or args.trace is not None
    if args.stream is not None and args.trace is not None:
        ap.error("--stream generates synthetically; --trace replays a "
                 "CSV — pick one")
    if (args.max_rss_mb is not None or args.arrival_rate is not None) \
            and not streaming:
        ap.error("--max-rss-mb/--arrival-rate apply to --stream/--trace")
    if streaming and (args.budget or args.hetero or args.straggler
                      or args.elastic or args.guard or args.full
                      or args.scenario):
        ap.error("--stream/--trace is its own variant; drop other flags")
    if args.scenario is None and (
        args.policy != "A-SRPT" or args.migration_penalty is not None
    ):
        ap.error("--policy/--migration-penalty apply to --scenario replays")
    if args.scenario is not None:
        if args.budget or args.hetero or args.straggler or args.elastic:
            ap.error("--scenario replays one file; drop the variant flags")
        run = lambda: sched_scale_scenario(  # noqa: E731
            args.scenario, policy=args.policy,
            migration_penalty=args.migration_penalty,
        )
    elif args.budget:
        if args.full:
            ap.error("--budget is fixed-size; drop --full (or use "
                     "--hetero/--full for the big sweeps)")
        run = lambda: sched_scale_budget(  # noqa: E731
            straggler=args.straggler
        )
    elif streaming:
        run = lambda: sched_scale_stream(  # noqa: E731
            n_jobs=args.stream or STREAM_JOBS_DEFAULT,
            trace_csv=args.trace,
            arrival_rate=args.arrival_rate,
        )
    elif args.guard:
        run = lambda: sched_scale_guard(full=args.full)  # noqa: E731
    elif args.hetero:
        run = lambda: sched_scale_hetero(full=args.full)  # noqa: E731
    elif args.elastic:
        run = lambda: sched_scale_elastic(full=args.full)  # noqa: E731
    elif args.straggler:
        run = lambda: sched_scale_straggler(full=args.full)  # noqa: E731
    else:
        run = lambda: sched_scale(full=args.full)  # noqa: E731

    if args.profile is not None:
        import cProfile
        import pstats

        profiler = cProfile.Profile()
        profiler.enable()
        rows = run()
        profiler.disable()
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(
            args.profile
        )
    else:
        rows = run()

    for r in rows:
        print(json.dumps(r))
    if args.max_rss_mb is not None:
        peak = max(r.get("peak_rss_mb", 0.0) for r in rows)
        if peak > args.max_rss_mb:
            print(
                f"::error::peak RSS {peak} MB exceeds the "
                f"{args.max_rss_mb} MB ceiling — the bounded-memory "
                f"guarantee regressed"
            )
            return 1
        print(f"peak RSS {peak} MB <= {args.max_rss_mb} MB ceiling")
    bench = rows_to_bench_json(rows) if (args.json or args.check) else None
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(bench, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")
    if args.check:
        try:
            with open(args.check) as fh:
                baseline = json.load(fh)
        except FileNotFoundError:
            print(f"::warning::no baseline at {args.check}; skipping check")
            return 0
        warnings, notes = check_regression(bench, baseline)
        for line in notes:
            print(f"[bench] {line}")
        for line in warnings:
            # GitHub Actions annotation; fail-soft (shared runners are noisy)
            print(f"::warning::sched_scale regression: {line}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
