"""Roofline table assembly from the dry-run artifacts (§Roofline).

Reads results/dryrun/*.json produced by ``python -m repro.launch.dryrun
--all`` and prints, per (arch x shape) on the single-pod mesh: the three
roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the
roofline fraction.  Skipped cells are listed with their reasons.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs

DRYRUN_DIR = Path("results/dryrun")

SKIP_REASONS = {
    "long_500k": "full quadratic attention (no sub-quadratic path)",
    "decode_32k": "encoder-only: no autoregressive decode",
}


def load_cell(arch: str, shape: str, mesh: str) -> Optional[dict]:
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_rows(mesh: str = "single") -> List[dict]:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        app = applicable_shapes(cfg)
        for shape in SHAPES:
            if shape not in app:
                rows.append({
                    "bench": "roofline", "arch": arch, "shape": shape,
                    "mesh": mesh, "status": "SKIP",
                    "reason": SKIP_REASONS.get(shape, "n/a"),
                })
                continue
            d = load_cell(arch, shape, mesh)
            if d is None:
                rows.append({
                    "bench": "roofline", "arch": arch, "shape": shape,
                    "mesh": mesh, "status": "MISSING",
                })
                continue
            rows.append({
                "bench": "roofline", "arch": arch, "shape": shape,
                "mesh": mesh, "status": "ok" if d.get("ok") else "FAIL",
                "t_compute_s": f"{d['t_compute']:.3e}",
                "t_memory_s": f"{d['t_memory']:.3e}",
                "t_collective_s": f"{d['t_collective']:.3e}",
                "bottleneck": d["bottleneck"],
                "useful_flops_ratio": f"{d['useful_flops_ratio']:.3f}",
                "roofline_fraction": f"{d['roofline_fraction']:.4f}",
                "peak_mem_GiB_per_dev": f"{d['peak_memory_bytes']/2**30:.1f}",
                "compile_s": d.get("compile_s"),
            })
    return rows


def multi_pod_rows() -> List[dict]:
    """Compile-success proof of the 2x16x16 multi-pod mesh."""
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            d = load_cell(arch, shape, "multi")
            rows.append({
                "bench": "multipod_dryrun", "arch": arch, "shape": shape,
                "status": ("ok" if d and d.get("ok") else
                           "MISSING" if d is None else "FAIL"),
                "compile_s": d.get("compile_s") if d else None,
            })
    return rows
