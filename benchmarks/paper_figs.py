"""One benchmark per paper figure/table (Figs. 4-9, Table II).

Each function returns a list of CSV-able row dicts; benchmarks/run.py
aggregates them.  Sizes are the scaled-down regime of common.py; pass
full=True for larger runs.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (
    ASRPTPolicy,
    TraceConfig,
    build_job_graph,
    generate_trace,
    make_predictor,
    simulate,
)
import repro.core.heavy_edge as he
from repro.core import timing
from repro.core.ilp import exact_min_cut
from repro.core.job import ClusterSpec
from repro.core.profiles import make_job

from . import common


# ---------------------------------------------------------------------------
# Fig. 4: prediction-error distribution of the random-forest model
# ---------------------------------------------------------------------------


def fig4_prediction(full: bool = False) -> List[dict]:
    n = 12000 if full else 5000
    jobs = generate_trace(TraceConfig(n_jobs=n, seed=0))
    split = int(0.8 * len(jobs))
    pred = make_predictor("rf", seed=0)
    pred.retrain_every = 10**9
    # observe the first 80 % in arrival order, then one warm fit
    for j in jobs[:split]:
        pred.observe(j, j.n_iters)
    pred.warm_start()
    errs = np.array(
        [abs(pred.predict(j) - j.n_iters) for j in jobs[split:]]
    )
    rel = errs / np.array([j.n_iters for j in jobs[split:]])
    rows = [{
        "bench": "fig4_prediction",
        "frac_exact(<=1_iter)": float((errs <= 1).mean()),
        "frac_within_10pct": float((rel <= 0.10).mean()),
        "frac_within_50pct": float((rel <= 0.50).mean()),
        "mean_abs_err_iters": float(errs.mean()),
        "paper_claim": "~60% predicted exactly (Fig. 4)",
    }]
    return rows


# ---------------------------------------------------------------------------
# Fig. 5: testbed-scale comparison (75 jobs, 14 vGPUs, tau=0)
# ---------------------------------------------------------------------------


def fig5_testbed(full: bool = False) -> List[dict]:
    cluster = ClusterSpec(
        num_servers=2, gpus_per_server=7, b_inter=128e9 / 8, b_intra=128e9
    )  # MIG testbed: PCIe-limited uniform bandwidth
    seeds = (0, 1, 2)
    agg: dict = {}
    for seed in seeds:
        history, jobs = common.history_and_window(
            75, seed=seed, history_mult=8, max_gpus_per_job=8,
            mean_iters=300, session_spread=20.0,
            horizon=9 * 75 * 30.0,
        )
        res = common.run_policies(
            jobs, cluster, predictor="rf", tau=0.0, include_perfect=True,
            history=history,
        )
        for name, m in res.items():
            agg.setdefault(name, []).append(m)
    rows = []
    for name, ms in agg.items():
        rows.append({
            "bench": "fig5_testbed",
            "policy": name,
            "total_flow": float(np.mean([m["total_flow"] for m in ms])),
            "makespan": float(np.mean([m["makespan"] for m in ms])),
        })
    ours = next(r for r in rows if r["policy"] == "A-SRPT")
    perfect = next(r for r in rows if r["policy"] == "A-SRPT-Perfect")
    ours["gap_vs_perfect"] = ours["total_flow"] / perfect["total_flow"] - 1
    ours["paper_claim"] = "A-SRPT within ~7% of perfect; up to 44% better than baselines"
    return rows


# ---------------------------------------------------------------------------
# Fig. 6: total JCT vs number of jobs
# ---------------------------------------------------------------------------


def fig6_num_jobs(full: bool = False) -> List[dict]:
    cluster = common.make_cluster()
    sizes = (1500, 3000, 6000) if full else (600, 1200, 2400)
    rows = []
    for n in sizes:
        history, jobs = common.history_and_window(n, seed=1)
        res = common.run_policies(jobs, cluster, predictor="rf",
                                  history=history)
        imp = common.improvement_vs_best_baseline(res)
        for name, m in res.items():
            rows.append({
                "bench": "fig6_num_jobs", "n_jobs": n, "policy": name,
                "total_flow": m["total_flow"],
                "total_completion": m["total_completion"],
                "wall_s": round(m["wall_s"], 1),
            })
        rows[-1]["asrpt_flow_reduction_vs_best"] = round(imp["vs_best"], 3)
        rows[-1]["asrpt_flow_reduction_vs_worst"] = round(imp["vs_worst"], 3)
    rows[-1]["paper_claim"] = "31-91% total JCT reduction (Fig. 6)"
    return rows


# ---------------------------------------------------------------------------
# Fig. 7: varying percentage of single-GPU jobs
# ---------------------------------------------------------------------------


def fig7_single_gpu(full: bool = False) -> List[dict]:
    # The paper's own cluster width (250 servers x 8): Theorem 1's
    # competitive ratio carries a G/(G - g_max) factor, so a faithful
    # scale-down must keep g_max/G small; the horizon is normalized to a
    # constant offered load (see common.history_and_window).
    cluster = common.make_cluster(num_servers=250)
    n = 800 if full else 400
    rows = []
    for frac in (0.8, 0.4, 0.0):
        history, jobs = common.history_and_window(
            n, seed=2, single_gpu_frac=frac, max_gpus_per_job=32,
            cluster=cluster, target_load=0.30,
        )
        res = common.run_policies(jobs, cluster, predictor="rf",
                                  history=history)
        imp = common.improvement_vs_best_baseline(res)
        for name, m in res.items():
            rows.append({
                "bench": "fig7_single_gpu", "single_gpu_frac": frac,
                "policy": name, "total_flow": m["total_flow"],
            })
        rows[-1]["asrpt_flow_reduction_vs_best"] = round(imp["vs_best"], 3)
    rows[-1]["paper_claim"] = "16-57% reduction as single-GPU % drops (Fig. 7)"
    return rows


# ---------------------------------------------------------------------------
# Fig. 8: varying server NIC bandwidth (0% single-GPU jobs)
# ---------------------------------------------------------------------------


def fig8_bandwidth(full: bool = False) -> List[dict]:
    n = 800 if full else 400
    rows = []
    for gbps in (1, 10, 50):
        # paper setting: 250 servers x 8 GPUs, 0% single-GPU jobs
        cluster = common.make_cluster(
            num_servers=250, b_inter=gbps * 0.125e9
        )
        history, jobs = common.history_and_window(
            n, seed=3, single_gpu_frac=0.0, max_gpus_per_job=32,
            cluster=cluster, target_load=0.30,
        )
        res = common.run_policies(jobs, cluster, predictor="rf",
                                  history=history)
        imp = common.improvement_vs_best_baseline(res)
        for name, m in res.items():
            rows.append({
                "bench": "fig8_bandwidth", "nic_gbps": gbps,
                "policy": name, "total_flow": m["total_flow"],
            })
        rows[-1]["asrpt_flow_reduction_vs_best"] = round(imp["vs_best"], 3)
        rows[-1]["asrpt_flow_reduction_vs_worst"] = round(imp["vs_worst"], 3)
    rows[-1]["paper_claim"] = "up to 92% reduction at 1 Gbps (Fig. 8)"
    return rows


# ---------------------------------------------------------------------------
# Fig. 9: prediction-model ablation
# ---------------------------------------------------------------------------


def fig9_predictors(full: bool = False) -> List[dict]:
    cluster = common.make_cluster()
    n = 1500 if full else 800
    history, jobs = common.history_and_window(n, seed=4)
    rows = []
    flows = {}
    for kind in ("rf", "median", "mean", "perfect"):
        t0 = time.time()
        pred = common.warm_predictor(kind, history, seed=0)
        pol = ASRPTPolicy(pred, tau=2.0)
        res = simulate(jobs, cluster, pol)
        flows[kind] = res.total_flow_time
        # measure average prediction error for this predictor
        pred = common.warm_predictor(kind, history, seed=0)
        err = float(np.mean(
            [abs(pred.predict(j) - j.n_iters) for j in jobs]
        ))
        rows.append({
            "bench": "fig9_predictors", "predictor": kind,
            "total_flow": res.total_flow_time,
            "mean_abs_err": round(err, 1),
            "wall_s": round(time.time() - t0, 1),
        })
    rows[-1]["rf_gap_vs_perfect"] = round(
        flows["rf"] / flows["perfect"] - 1, 3
    )
    rows[-1]["paper_claim"] = "RF ~14% off perfect, beats mean/median (Fig. 9)"
    return rows


# ---------------------------------------------------------------------------
# Table II: Heavy-Edge vs exact ILP (PITT + placement computation time)
# ---------------------------------------------------------------------------


def table2_heavyedge_ilp(full: bool = False) -> List[dict]:
    cluster = ClusterSpec(
        num_servers=8, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )
    rng = np.random.default_rng(0)
    rows = []
    cases = {
        "vgg19": ("vgg19", 5, 20),     # config (4,4): 8 replicas
        "gpt_175b": ("gpt_175b", 3, 10),  # config (2,)*8: 16 replicas
    }
    for label, (model, cfg_idx, n_cases) in cases.items():
        if not full:
            n_cases = max(3, n_cases // 3)
        he_pitt, ilp_pitt, he_t, ilp_t = [], [], [], []
        for case in range(n_cases):
            job = make_job(0, model, cfg_idx, n_iters=100)
            g = build_job_graph(job)
            # random per-server availability covering g_i
            caps = []
            remaining = job.g
            for m in range(cluster.num_servers):
                if remaining <= 0:
                    break
                c = int(rng.integers(1, cluster.gpus_per_server + 1))
                c = min(c, remaining)
                caps.append((m, c))
                remaining -= c
            if remaining > 0:
                caps[-1] = (caps[-1][0], caps[-1][1] + remaining)
                caps = [(m, min(c, cluster.gpus_per_server)) for m, c in caps]
                if sum(c for _, c in caps) != job.g:
                    continue
            t0 = time.time()
            assign = he.heavy_edge(g, caps)
            he_t.append(time.time() - t0)
            placement = timing.placement_from_assignment(job, assign)
            he_pitt.append(timing.alpha(job, placement, cluster))
            t0 = time.time()
            try:
                opt_assign, _ = exact_min_cut(g, caps, node_limit=3_000_000)
                ilp_t.append(time.time() - t0)
                opt_placement = timing.placement_from_assignment(
                    job, opt_assign
                )
                ilp_pitt.append(timing.alpha(job, opt_placement, cluster))
            except RuntimeError:
                ilp_t.append(float("nan"))
                ilp_pitt.append(float("nan"))
        rows.append({
            "bench": "table2_heavyedge_ilp",
            "model": label,
            "heavy_edge_pitt_ms": round(1e3 * float(np.mean(he_pitt)), 2),
            "ilp_pitt_ms": round(1e3 * float(np.nanmean(ilp_pitt)), 2),
            "heavy_edge_pct_ms": round(1e3 * float(np.mean(he_t)), 3),
            "ilp_pct_ms": round(1e3 * float(np.nanmean(ilp_t)), 1),
            "pitt_gap": round(
                float(np.mean(he_pitt)) / float(np.nanmean(ilp_pitt)) - 1, 4
            ),
        })
    rows[-1]["paper_claim"] = "Heavy-Edge PITT within ~6% of ILP, >>1000x faster (Table II)"
    return rows
