"""Benchmark driver: one benchmark per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV summary lines (plus the detailed
per-row CSV blocks).  ``--full`` enlarges the simulated workloads.
"""
from __future__ import annotations

import argparse
import time


def _emit_rows(rows) -> None:
    if not rows:
        return
    keys: list = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    print()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated bench names "
             "(fig4..fig9,table2,sched_scale,sched_hetero,roofline)",
    )
    args = ap.parse_args()

    from . import paper_figs, roofline, sched_scale

    benches = {
        "fig4": paper_figs.fig4_prediction,
        "fig5": paper_figs.fig5_testbed,
        "fig6": paper_figs.fig6_num_jobs,
        "fig7": paper_figs.fig7_single_gpu,
        "fig8": paper_figs.fig8_bandwidth,
        "fig9": paper_figs.fig9_predictors,
        "table2": paper_figs.table2_heavyedge_ilp,
        "sched_scale": sched_scale.sched_scale,
        "sched_hetero": sched_scale.sched_scale_hetero,
        "sched_elastic": sched_scale.sched_scale_elastic,
    }
    selected = (
        args.only.split(",") if args.only else list(benches) + ["roofline"]
    )

    summary = []
    for name in selected:
        if name == "roofline":
            t0 = time.time()
            rows = roofline.roofline_rows("single")
            rows += roofline.multi_pod_rows()
            _emit_rows(rows)
            n_ok = sum(1 for r in rows if r.get("status") == "ok")
            summary.append((name, (time.time() - t0) * 1e6 / max(len(rows), 1),
                            f"cells_ok={n_ok}"))
            continue
        fn = benches[name]
        print(f"### {name} ###", flush=True)
        t0 = time.time()
        rows = fn(full=args.full)
        wall = time.time() - t0
        _emit_rows(rows)
        derived = ""
        for r in rows:
            for k in ("asrpt_flow_reduction_vs_best", "gap_vs_perfect",
                      "pitt_gap", "frac_exact(<=1_iter)", "rf_gap_vs_perfect",
                      "cache_speedup_20k", "flow_vs_clean"):
                if k in r and r[k] != "":
                    derived = f"{k}={r[k]}"
        summary.append((name, wall * 1e6 / max(len(rows), 1), derived))

    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
