"""Shared benchmark helpers: cluster/trace regimes + policy runner.

Scaling note (documented per DESIGN.md): the paper simulates 250 servers x
8 GPUs with 37.5k-150k jobs over two months.  On one CPU core we scale both
sides down ~25x (10 servers x 8 GPUs, 0.6k-4k jobs, horizon scaled to keep
the same bursty moderate-load regime: sessions of submissions at ~2 min
spacing, average load 0.15-0.4, transient congestion during bursts).
All policies see identical traces and the same Heavy-Edge mapper.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)

DEFAULT_CLUSTER = dict(
    num_servers=10, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
)
# bursty moderate-load regime (see EXPERIMENTS.md §regime)
SECONDS_PER_JOB = 86.4  # horizon = n_jobs * this  (1.5 days per 1500 jobs)


def make_cluster(**overrides) -> ClusterSpec:
    kw = dict(DEFAULT_CLUSTER)
    kw.update(overrides)
    return ClusterSpec(**kw)


def make_jobs(
    n_jobs: int,
    seed: int = 1,
    single_gpu_frac: float = 0.7,
    max_gpus: int = 32,
    horizon: Optional[float] = None,
) -> list:
    cfg = TraceConfig(
        n_jobs=n_jobs,
        horizon=horizon or n_jobs * SECONDS_PER_JOB,
        seed=seed,
        single_gpu_frac=single_gpu_frac,
        max_gpus_per_job=max_gpus,
        mean_iters=400,
        sigma_iters=1.6,
        session_spread=120.0,
    )
    return generate_trace(cfg)


def history_and_window(
    n_sched: int,
    seed: int = 1,
    history_mult: int = 4,
    cluster: Optional[ClusterSpec] = None,
    target_load: Optional[float] = None,
    **trace_kw,
) -> Tuple[list, list]:
    """Paper protocol (Sec. V-A.1-c): the predictor trains on the first 80 %
    of the trace; a consecutive window from the tail is scheduled.

    ``target_load``: normalize the horizon so the average offered load
    (sum g*n*alpha_min / (G*horizon)) is constant across configurations —
    the paper's 2000-GPU cluster never saturates even at 0 % single-GPU
    jobs or 1 Gbps NICs, so load, not job count, must be held fixed when
    sweeping those knobs on our 80-GPU scale-down.
    """
    total = (history_mult + 1) * n_sched
    kw = dict(horizon=total * SECONDS_PER_JOB, mean_iters=400,
              sigma_iters=1.6, session_spread=120.0)
    kw.update(trace_kw)
    jobs = generate_trace(TraceConfig(n_jobs=total, seed=seed, **kw))
    if target_load is not None and cluster is not None:
        from repro.core.heavy_edge import alpha_min_estimate

        work = sum(
            j.g * j.n_iters * alpha_min_estimate(j, cluster) for j in jobs
        )
        kw["horizon"] = work / (cluster.total_gpus * target_load)
        jobs = generate_trace(TraceConfig(n_jobs=total, seed=seed, **kw))
    split = len(jobs) - n_sched
    history, window = jobs[:split], jobs[split:]
    t0 = window[0].arrival
    window = [dataclasses.replace(j, arrival=j.arrival - t0) for j in window]
    return history, window


def warm_predictor(kind: str, history: list, seed: int = 0):
    """Observe the history once, then a single warm fit (no mid-sim refits:
    the scheduled windows span ~a day, the paper retrains daily).

    Scheduling benches use a 40-tree forest (the paper's 100-tree model is
    kept for the Fig. 4 prediction-quality measurement; ordering decisions
    are insensitive to the extra trees and the fit is ~3x faster).
    """
    kw = dict(n_estimators=40, n_bins=512) if kind == "rf" else {}
    pred = make_predictor(kind, seed=seed, **kw)
    if hasattr(pred, "retrain_every"):
        pred.retrain_every = 10**9
    for j in history:
        pred.observe(j, j.n_iters)
    if hasattr(pred, "warm_start"):
        pred.warm_start()
    return pred


def run_policies(
    jobs,
    cluster: ClusterSpec,
    policies: Optional[List[str]] = None,
    predictor: str = "rf",
    tau: float = 2.0,
    include_perfect: bool = False,
    history: Optional[list] = None,
) -> Dict[str, dict]:
    """Run A-SRPT + baselines on the same jobs; returns per-policy metrics."""
    names = policies or (["A-SRPT"] + list(BASELINES))
    base_pred = (
        warm_predictor(predictor, history) if history is not None else None
    )

    def fresh(kind: str):
        if kind == "perfect":
            return make_predictor("perfect")
        if base_pred is not None:
            return copy.deepcopy(base_pred)
        return make_predictor(predictor, seed=0)

    out: Dict[str, dict] = {}
    for name in names:
        t0 = time.time()
        if name == "A-SRPT":
            pol = ASRPTPolicy(fresh(predictor), tau=tau)
        elif name == "A-SRPT-Perfect":
            pol = ASRPTPolicy(make_predictor("perfect"), tau=tau)
        else:
            pol = BASELINES[name](fresh(predictor))
        res = simulate(jobs, cluster, pol)
        out[name] = {
            "total_flow": res.total_flow_time,
            "total_completion": res.total_completion_time,
            "makespan": res.makespan,
            "mean_jct": res.mean_jct,
            "wall_s": time.time() - t0,
        }
    if include_perfect and "A-SRPT-Perfect" not in names:
        t0 = time.time()
        res = simulate(
            jobs, cluster, ASRPTPolicy(make_predictor("perfect"), tau=tau)
        )
        out["A-SRPT-Perfect"] = {
            "total_flow": res.total_flow_time,
            "total_completion": res.total_completion_time,
            "makespan": res.makespan,
            "mean_jct": res.mean_jct,
            "wall_s": time.time() - t0,
        }
    return out


def improvement_vs_best_baseline(metrics: Dict[str, dict], key="total_flow"):
    baselines = {
        k: v[key] for k, v in metrics.items()
        if k not in ("A-SRPT", "A-SRPT-Perfect")
    }
    if not baselines or "A-SRPT" not in metrics:
        return None
    best = min(baselines.values())
    worst = max(baselines.values())
    ours = metrics["A-SRPT"][key]
    return {
        "vs_best": 1.0 - ours / best,
        "vs_worst": 1.0 - ours / worst,
    }
