# Developer entry points.  The container pins jax; `hypothesis` is an
# optional dev dependency — property tests fall back to seeded sampling
# when it is absent (tests/_hypothesis_fallback.py).

PYTHONPATH := src
export PYTHONPATH

.PHONY: test smoke bench-sched

test:
	python -m pytest -x -q

# Tier-1 + the headline scheduling figure: catches both correctness and
# perf regressions in the scheduling engine.
smoke: test
	python -m benchmarks.run --only fig6

# Trace-scale scheduling benchmark (5k/20k jobs; 100k with FULL=1).
bench-sched:
	python -m benchmarks.run --only sched_scale $(if $(FULL),--full,)
