# Developer entry points.  The container pins jax; `hypothesis` is an
# optional dev dependency — property tests fall back to seeded sampling
# when it is absent (tests/_hypothesis_fallback.py).

PYTHONPATH := src
export PYTHONPATH

.PHONY: test test-sched lint detlint smoke bench-sched bench-hetero \
	bench-straggler bench-elastic bench-stream bench-guard \
	bench-budget bench-trend bench-fleet bench-fleet-ab \
	bench-predict bench-serve ci

test:
	python -m pytest -x -q

# Pure-scheduling subset (no JAX compiles): seconds instead of the
# 15-20 min tier-1 — use while iterating on the scheduling engine.
test-sched:
	python -m pytest -m sched -x -q

# Correctness-focused ruff rules (see [tool.ruff] in pyproject.toml); CI
# installs ruff, locally we skip with a note when it's absent.  A lint
# *failure* still fails the target.
lint:
	@if python -c "import ruff" 2>/dev/null; then \
		python -m ruff check .; \
	else \
		echo "ruff not installed; skipping lint (CI runs it)"; \
	fi

# Determinism & invariant linter over the scheduling core (stdlib-only;
# what the CI detlint job runs).  Exit 1 on any unsuppressed finding or
# reason-less suppression; docs/DETERMINISM.md maps rule ids to the
# invariants they enforce.
detlint:
	python -m repro.analysis.detlint

# Tier-1 + the headline scheduling figure: catches both correctness and
# perf regressions in the scheduling engine.  Each step runs a bare
# command, so any failure propagates as a nonzero make exit.
smoke: test
	python -m benchmarks.run --only fig6

# Trace-scale scheduling benchmark (5k/20k jobs; 100k with FULL=1).
bench-sched:
	python -m benchmarks.run --only sched_scale $(if $(FULL),--full,)

# Mixed-generation cluster + fault-injection recovery variant.
bench-hetero:
	python -m benchmarks.sched_scale --hetero $(if $(FULL),--full,)

# Straggler (partial degradation) scenario: A-SRPT finish-in-place vs
# migration-capable on the mixed cluster (flow_vs_stay < 1 = migration
# wins).
bench-straggler:
	python -m benchmarks.sched_scale --straggler $(if $(FULL),--full,)

# Elastic-capacity scenario: four gen-a servers absent from the start
# join at 40% of the horizon (ServerJoin/ServerLeave events;
# flow_vs_static < 1 = recovered flow time).
bench-elastic:
	python -m benchmarks.sched_scale --elastic $(if $(FULL),--full,)

# Bounded-memory streaming replay: STREAM_JOBS (default 1M) synthetic
# jobs generated, scheduled, and aggregated lazily under an enforced
# peak-RSS ceiling (what the CI streaming-memory job runs).  Point
# TRACE at a CSV to replay a datacenter-style trace instead.
STREAM_JOBS ?= 1000000
STREAM_RSS_MB ?= 512
bench-stream:
	python -m benchmarks.sched_scale \
		$(if $(TRACE),--trace $(TRACE),--stream $(STREAM_JOBS)) \
		--max-rss-mb $(STREAM_RSS_MB)

# migration_queue_guard A/B at 20k-job straggler scale
# (flow_vs_unguarded < 1 = the queue-aware race wins).
bench-guard:
	python -m benchmarks.sched_scale --guard

# Aggregate BENCH_sched*.json artifacts (downloaded CI runs and/or the
# committed baseline) into a per-policy events/sec trend table.  Default
# scans the repo root, which picks up benchmarks/BENCH_sched_baseline.json
# plus any fresh BENCH_sched.json from `make bench-budget`; point
# TREND_DIR at a directory of downloaded artifacts for the full series.
TREND_DIR ?= .
bench-trend:
	python -m benchmarks.bench_trend $(TREND_DIR)

# CI budget mode: emits BENCH_sched.json (incl. the straggler migration
# row) and fail-soft-checks it against the committed baseline (refresh
# with: make bench-budget && cp BENCH_sched.json
# benchmarks/BENCH_sched_baseline.json).
bench-budget:
	python -m benchmarks.sched_scale --budget --straggler \
		--json BENCH_sched.json \
		--check benchmarks/BENCH_sched_baseline.json

# Monte-Carlo robustness sweep (what the CI fleet-robustness job runs,
# minus --strict: local runs stay fail-soft on the p95 flow-time check;
# per-variant schedule-sha mismatches still exit 1).  FLEET_N variants.
# Refresh the baseline with: make bench-fleet && cp BENCH_fleet.json
# benchmarks/BENCH_fleet_baseline.json.
FLEET_N ?= 64
bench-fleet:
	python -m benchmarks.sched_scale --fleet $(FLEET_N) \
		--json BENCH_fleet.json \
		--check benchmarks/BENCH_fleet_baseline.json

# Prediction-error robustness sweep (what the CI prediction-robustness
# job runs, minus --strict): one closed-loop run per error model, gated
# on the online forest's p95 flow staying <= 1.3x oracle (absolute —
# always exit 1 past it) plus fail-soft per-regime drift vs the
# committed baseline.  Refresh with: make bench-predict && cp
# BENCH_predict.json benchmarks/BENCH_predict_baseline.json.
bench-predict:
	python -m benchmarks.sched_scale --predict \
		--json BENCH_predict.json \
		--check benchmarks/BENCH_predict_baseline.json

# SLO-aware serving co-schedule (what the CI serve-slo job runs, minus
# --strict): a diurnal ~1M-request stream next to the dense training
# trace, gated on SLO attainment staying above the absolute floor
# (always exit 1 below it) and the mixed-run schedule sha256 matching
# the committed baseline; p99/interference drift is fail-soft locally.
# Refresh with: make bench-serve && cp BENCH_serve.json
# benchmarks/BENCH_serve_baseline.json.
bench-serve:
	python -m benchmarks.sched_scale --serve \
		--json BENCH_serve.json \
		--check benchmarks/BENCH_serve_baseline.json

# Interleaved fleet-vs-sequential A/B on the refined-mapping engine:
# asserts per-variant bit-identity and prints fleet_speedup (the
# shared-cache + batched-prewarm amortization, benchmarks/README.md).
bench-fleet-ab:
	python -m benchmarks.sched_scale --fleet-ab

# What CI runs: lint + detlint + tier-1 + budget benchmark + fleet +
# predict + serve gates.
ci: lint detlint test bench-budget bench-fleet bench-predict bench-serve
