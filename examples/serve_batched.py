"""Batched serving demo: prefill + decode over a request queue.

    PYTHONPATH=src python examples/serve_batched.py

Initializes a reduced qwen3-family model and serves a batch of prompts to
completion with greedy + temperature sampling, exercising the KV-cache
prefill/decode path that the dry-run lowers at 32k/500k scale.
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import reduced_config  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402


def main() -> None:
    cfg = reduced_config("qwen3-32b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=128, seed=0)

    requests = [
        Request(0, prompt=[5, 17, 42], max_new_tokens=16, temperature=0.0),
        Request(1, prompt=[7, 7, 7, 7], max_new_tokens=12, temperature=0.8),
        Request(2, prompt=[100], max_new_tokens=20, temperature=0.0),
    ]
    out = engine.generate(requests)
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: generated {len(toks)} tokens: {toks}")


if __name__ == "__main__":
    main()
