"""Fault-tolerance demo: scheduling around server failures + stragglers.

    PYTHONPATH=src python examples/scheduler_faults.py

A server dies mid-trace; the cluster controller marks it down, the
scheduler stops placing work there, and a straggling server is detected
from step-time telemetry and demoted.
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)
from repro.train.fault_tolerance import (  # noqa: E402
    HeartbeatMonitor,
    StragglerDetector,
    plan_elastic_mesh,
)


class FaultAwareASRPT(ASRPTPolicy):
    """A-SRPT + failure detection: server 3 dies at t=600 s."""

    def __init__(self, *a, fail_server=3, fail_at=600.0, **kw):
        super().__init__(*a, **kw)
        self.fail_server = fail_server
        self.fail_at = fail_at
        self.hb = HeartbeatMonitor(timeout=60.0)
        self._marked = False

    def plan_pass(self, t, cluster):
        for m in range(self.cluster_spec.num_servers):
            if not (m == self.fail_server and t >= self.fail_at):
                self.hb.beat(m, t)
        dead = self.hb.failed(now=t)  # overdue by > timeout at current time
        if not self._marked and dead:
            print(f"[t={t:8.1f}] heartbeat lost: servers {dead} -> marked down")
            for m in dead:
                cluster.mark_server_down(m)
            self._marked = True
        return super().plan_pass(t, cluster)


def main() -> None:
    cluster = ClusterSpec(
        num_servers=6, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = generate_trace(TraceConfig(
        n_jobs=200, horizon=3600.0, seed=2, max_gpus_per_job=16,
        mean_iters=100,
    ))
    pol = FaultAwareASRPT(make_predictor("rf", seed=0), tau=2.0)
    res = simulate(jobs, cluster, pol)
    # detection lags one heartbeat timeout behind the failure
    after = [r for r in res.records.values() if r.start >= 700.0]
    touched = sum(1 for r in after if 3 in r.servers)
    print(f"jobs started after failure: {len(after)}; placed on dead server: {touched}")
    assert touched == 0

    print("\nstraggler detection from step-time telemetry:")
    sd = StragglerDetector(threshold=1.5)
    rng = np.random.default_rng(0)
    for step in range(50):
        for host in range(6):
            base = 1.0 if host != 4 else 2.2  # host 4 is slow
            sd.record(host, base * rng.uniform(0.95, 1.05))
    print("  stragglers:", sd.stragglers())

    print("\nelastic mesh planning after losing 2 of 16 hosts (model=16):")
    print("  new (data, model) =", plan_elastic_mesh(14 * 16, 16))

    # Simulator-level fault injection on a mixed-generation cluster: the
    # big-GPU class loses a server at t=600s; capacity held by running
    # jobs is forfeited as they finish (never returns to `free`).
    from repro.core import mixed_cluster_spec

    print("\nsimulator-level fault injection (mixed-generation cluster):")
    het = mixed_cluster_spec(num_servers=6, seed=0, n_classes=2)
    res2 = simulate(
        jobs,
        het,
        FaultAwareASRPT(make_predictor("rf", seed=0), tau=2.0,
                        fail_at=float("inf")),  # policy side stays quiet
        faults=[(600.0, 0)],
    )
    after2 = [r for r in res2.records.values() if r.start >= 600.0]
    touched2 = sum(1 for r in after2 if 0 in r.servers)
    print(f"  classes: {[(c.name, c.count, c.gpus_per_server) for c in het.server_classes]}")
    print(f"  jobs started after failure: {len(after2)}; on dead server: {touched2}")
    assert touched2 == 0

    # Elastic capacity as a first-class Scenario (ISSUE 5): server 5 is
    # absent for the first half of the trace (ServerLeave at t=0) and
    # joins mid-run; the epoch bump wakes the settled policy, so queued
    # work starts on the new capacity the moment it lands.  The scenario
    # is one serializable object — `sc.to_json()` replays anywhere via
    # `benchmarks/sched_scale.py --scenario`.
    from repro.core import Scenario, ServerJoin, ServerLeave

    print("\nelastic capacity scenario (ServerLeave/ServerJoin events):")
    sc = Scenario(
        jobs=tuple(jobs),
        cluster=cluster,
        events=(ServerLeave(0.0, 5), ServerJoin(1800.0, 5)),
        name="elastic-demo",
    )
    res3 = simulate(sc, ASRPTPolicy(make_predictor("rf", seed=0), tau=2.0))
    on_joined = [r for r in res3.records.values() if 5 in r.servers]
    print(f"  jobs placed on the late-joining server: {len(on_joined)}"
          f" (earliest start t={min(r.start for r in on_joined):.0f}s)"
          if on_joined else "  joined capacity unused (idle tail)")
    assert all(r.start >= 1800.0 for r in on_joined)


if __name__ == "__main__":
    main()
