"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Builds a 12-layer, d_model=512 deepseek-family model (~110M params with
embeddings), trains it on the synthetic token stream with AdamW + cosine
schedule, async checkpoints every 50 steps, and prints the loss curve.
Crash-and-resume is exercised by launch/train.py's --fail-at flag.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import reduced_config  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # ~100M params: 12 layers x d_model 512 x ff 2048, 32k vocab
    cfg = reduced_config(
        "deepseek-7b",
        n_layers=12,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=32000,
    )
    from repro.models import Model, n_params
    import jax
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step
    from repro.train.data import DataLoader

    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    print(f"params: {n_params(state.params):,}")
    step_fn = jax.jit(
        make_train_step(
            model,
            AdamWConfig(lr_peak=3e-4, warmup_steps=30, total_steps=args.steps),
        ),
        donate_argnums=(0,),
    )
    loader = DataLoader(cfg, batch_size=8, seq_len=256, seed=0)
    import jax.numpy as jnp
    import time

    from repro.train import checkpoint

    writer = checkpoint.AsyncWriter(args.ckpt_dir, keep=2)
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.next().items()}
        state, metrics = step_fn(state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d}  loss {float(metrics['loss']):7.4f}  "
                f"lr {float(metrics['lr']):.2e}  "
                f"({(time.time()-t0)/(step+1)*1e3:.0f} ms/step)"
            )
        if (step + 1) % 50 == 0:
            writer.submit(step + 1, state, {"loader": loader.state()})
    writer.close()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
