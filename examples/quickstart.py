"""Quickstart: the paper's A-SRPT scheduler on a synthetic MLaaS trace.

    PYTHONPATH=src python examples/quickstart.py

Generates a bursty two-day trace, schedules it with A-SRPT (random-forest
iteration prediction + Heavy-Edge GPU mapping) and the five baselines from
the paper, and prints the total job completion / flow times.
"""
import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
    trace_stats,
)


def main() -> None:
    cluster = ClusterSpec(
        num_servers=10,  # 10 servers x 8 accelerators
        gpus_per_server=8,
        b_inter=1.25e9,  # 10 Gbps NIC
        b_intra=300e9,  # NVLink/ICI-class intra-server
    )
    jobs = generate_trace(
        TraceConfig(
            n_jobs=400,
            horizon=0.5 * 24 * 3600.0,
            seed=0,
            max_gpus_per_job=32,
            session_spread=120.0,
        )
    )
    print("trace:", trace_stats(jobs))

    rows = []
    pol = ASRPTPolicy(make_predictor("rf", seed=0), tau=2.0)
    res = simulate(jobs, cluster, pol)
    rows.append(("A-SRPT (ours)", res))
    for name, mk in BASELINES.items():
        res = simulate(jobs, cluster, mk(make_predictor("rf", seed=0)))
        rows.append((name, res))

    print(f"\n{'policy':16s} {'total flow':>14s} {'mean JCT':>10s} {'makespan':>10s}")
    for name, res in rows:
        print(
            f"{name:16s} {res.total_flow_time:14.3e} "
            f"{res.mean_jct:10.0f} {res.makespan:10.0f}"
        )
    print(
        "\nNOTE: A-SRPT's advantage is regime-dependent (see "
        "EXPERIMENTS.md §Regime);\nthe mechanism it exploits is isolated "
        "below."
    )

    # --- the core mechanism, deterministically --------------------------
    # Long 8-GPU jobs arrive first; short 1-GPU jobs trickle in afterwards.
    # Work-conserving baselines backfill the longs onto every free GPU and
    # the shorts then wait; A-SRPT's virtual machine releases the longs
    # gradually, keeping headroom.
    from repro.core.job import JobSpec, StageSpec

    def job(jid, k, iters, arrival, group):
        return JobSpec(
            job_id=jid,
            stages=(StageSpec(p_f=0.33, p_b=0.67, d_in=0, d_out=0,
                              h=1 * 1024**2, k=k),),
            n_iters=iters, arrival=arrival, group_id=group,
        )

    jobs2 = [job(i, 8, 1000, 0.0, 1) for i in range(10)]
    jobs2 += [job(100 + i, 1, 20, 10.0 + 5 * i, 2) for i in range(100)]
    print(f"\n{'policy':16s} {'total flow (mechanism demo)':>28s}")
    for name, pol in [
        ("A-SRPT (ours)", ASRPTPolicy(make_predictor("perfect"), tau=2.0)),
        ("WCS-SubTime", BASELINES["WCS-SubTime"](make_predictor("perfect"))),
    ]:
        res = simulate(jobs2, cluster, pol)
        print(f"{name:16s} {res.total_flow_time:28.3e}")


if __name__ == "__main__":
    main()
