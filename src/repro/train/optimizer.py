"""AdamW + schedules, from scratch (optax unavailable offline).

Optimizer state (m, v) is fp32 and inherits each parameter's sharding —
with the FSDP ('data'-axis) parameter sharding in parallel/sharding.py this
is ZeRO-style optimizer-state sharding for free.

Also: global-norm gradient clipping, cosine LR schedule with warmup, and
optional int8 gradient compression with error feedback for the slow
cross-pod (DCN) axis (a distributed-optimization feature; see
``compress_decompress`` and train_step.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray  # i32 scalar
    m: Params  # fp32, same tree as params
    v: Params  # fp32


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * cfg.lr_peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params: Params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    cfg: AdamWConfig,
    params: Params,
    grads: Params,
    state: AdamWState,
) -> Tuple[Params, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


# --------------------------------------------------------------------------
# int8 gradient compression with error feedback (for the DCN/pod axis)
# --------------------------------------------------------------------------


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(g)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress_with_feedback(
    grads: Params, error: Params
) -> Tuple[Params, Params]:
    """Quantize grads+error to int8 and back; return (grads_hat, new_error).

    Used on the pod (DCN) axis: compressing the cross-pod gradient exchange
    by 4x (int8 vs fp32) with error feedback keeps convergence unbiased.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress(target)
        ghat = decompress(q, s)
        return ghat.astype(g.dtype), target - ghat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    ghat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return ghat, new_e


def zeros_like_error(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
