"""train subpackage."""
