"""Train/serve step factories (pjit-ready, donated state)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    compress_decompress_with_feedback,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    error_feedback: Optional[Any] = None  # int8-compression residual (DCN)


def init_train_state(
    model: Model, key: jax.Array, compress_grads: bool = False
) -> TrainState:
    params = model.init(key)
    ef = None
    if compress_grads:
        from .optimizer import zeros_like_error

        ef = zeros_like_error(params)
    return TrainState(params=params, opt=adamw_init(params), error_feedback=ef)


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    num_microbatches: int = 1,
    compress_grads: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches > 1``: gradient accumulation via lax.scan — the
    per-microbatch backward overlaps with the previous microbatch's grad
    reduce-scatter (XLA schedules the collectives asynchronously), which is
    the standard compute/comm overlap trick at scale.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                B = x.shape[0]
                assert B % num_microbatches == 0
                return x.reshape(
                    (num_microbatches, B // num_microbatches) + x.shape[1:]
                )

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (l, m), g = grad_fn(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, m)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            grads, (losses, metricses) = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(
                lambda g: g / num_microbatches, grads
            )
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)

        ef = state.error_feedback
        if compress_grads and ef is not None:
            grads, ef = compress_decompress_with_feedback(grads, ef)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, ef), metrics

    return train_step


def make_serve_steps(model: Model):
    """(prefill_step, decode_step) for serving."""

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return prefill_step, decode_step
