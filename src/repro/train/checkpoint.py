"""Checkpointing: atomic, resumable, optionally asynchronous.

Layout (orbax unavailable offline; plain npz + json):

    <dir>/step_<N>/arrays.npz   — every pytree leaf, keyed by "/"-joined path
    <dir>/step_<N>/meta.json    — step, data-loader cursor, user metadata
    <dir>/step_<N>/.complete    — commit marker (atomicity)

Write protocol: serialize into ``step_<N>.tmp``, fsync, rename — a crash
mid-write never corrupts the latest complete checkpoint.  ``AsyncWriter``
moves serialization off the training thread (device->host copy happens
synchronously, the disk write does not), the standard trick for hiding
checkpoint latency at scale.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (str(p.idx) if hasattr(p, "idx") else str(p.name))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(
    ckpt_dir: str | Path,
    step: int,
    state: Any,
    extra_meta: Optional[dict] = None,
) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step}"
    tmp = ckpt_dir / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    np.savez(tmp / "arrays.npz", **flat)
    meta = {"step": int(step)}
    meta.update(extra_meta or {})
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / ".complete").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / ".complete").exists():
            try:
                steps.append(int(p.name.split("_", 1)[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | Path,
    state_template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, dict]:
    """Restore into the template's structure (optionally resharded).

    ``shardings``: pytree of NamedSharding — used to place restored leaves
    onto a (possibly different/elastically shrunk) mesh.
    """
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    path = ckpt_dir / f"step_{step}"
    arrays = np.load(path / "arrays.npz")
    meta = json.loads((path / "meta.json").read_text())

    paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else None
    )
    leaves: List[Any] = []
    for i, (path_keys, leaf) in enumerate(paths):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (str(p.idx) if hasattr(p, "idx") else str(p.name))
            for p in path_keys
        )
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}"
            )
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, leaves), meta


def prune(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(p.name.split("_", 1)[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and "." not in p.name.split("_", 1)[1]
    )
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


class AsyncWriter:
    """Background checkpoint writer (one in flight at a time)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: List[BaseException] = []

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_state, meta = item
            try:
                save(self.ckpt_dir, step, host_state, meta)
                prune(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next submit/close
                self._errors.append(e)

    def submit(self, step: int, state: Any, meta: Optional[dict] = None):
        if self._errors:
            raise self._errors.pop()
        # device->host copy now (cheap vs disk); disk write in background
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._q.put((step, host_state, meta))

    def close(self) -> None:
        self._q.put(None)
        self._worker.join()
        if self._errors:
            raise self._errors.pop()
