"""Fault tolerance: failure detection, elastic re-meshing, stragglers.

At 1000+-node scale three things go wrong continuously; each has a
dedicated mechanism here, and each feeds back into the A-SRPT scheduler
layer (a failed server is capacity the scheduler must stop counting):

* **node failure** — ``HeartbeatMonitor`` flags hosts whose heartbeat is
  overdue; ``plan_elastic_mesh`` shrinks the data axis to the surviving
  host count; ``elastic_restore`` re-places the last checkpoint onto the
  new mesh (ZeRO-sharded state re-shards transparently via device_put).
* **stragglers** — ``StragglerDetector`` keeps a per-host EWMA of step
  times and flags hosts slower than ``threshold x`` the median; the
  cluster scheduler then treats that server as reduced-capacity
  (``ClusterState.mark_server_down`` or fewer available GPUs).
* **checkpoint/restart** — see checkpoint.py; driven by launch/train.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class HeartbeatMonitor:
    def __init__(self, timeout: float = 60.0):
        self.timeout = timeout
        self._last: Dict[int, float] = {}

    def beat(self, host: int, t: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if t is None else t

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(
            h for h, t in self._last.items() if now - t > self.timeout
        )

    def healthy(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(
            h for h, t in self._last.items() if now - t <= self.timeout
        )


class StragglerDetector:
    """Per-host EWMA step times; flags hosts slower than median x threshold."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.5):
        self.alpha = alpha
        self.threshold = threshold
        self._ewma: Dict[int, float] = {}

    def record(self, host: int, step_time: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (
            step_time
            if prev is None
            else (1 - self.alpha) * prev + self.alpha * step_time
        )

    def stragglers(self) -> List[int]:
        if len(self._ewma) < 2:
            return []
        med = float(np.median(list(self._ewma.values())))
        return sorted(
            h for h, v in self._ewma.items() if v > self.threshold * med
        )


def plan_elastic_mesh(
    n_healthy_devices: int, model_axis: int
) -> Tuple[int, int]:
    """Largest (data, model) mesh that fits the surviving devices.

    The model axis is preserved (re-sharding TP state across a different
    model-axis size would change per-device layouts); the data axis shrinks
    — ZeRO/FSDP state re-shards along 'data' by construction.
    """
    if n_healthy_devices < model_axis:
        raise ValueError(
            f"cannot keep model axis {model_axis} with only "
            f"{n_healthy_devices} devices"
        )
    return (n_healthy_devices // model_axis, model_axis)


def elastic_restore(
    ckpt_dir,
    state_template,
    cfg,
    new_mesh,
):
    """Restore the latest checkpoint onto a (possibly smaller) mesh."""
    from ..parallel import sharding as sh
    from . import checkpoint

    p_sh = sh.param_shardings(cfg, state_template.params, new_mesh)
    state_sh = type(state_template)(
        params=p_sh,
        opt=type(state_template.opt)(
            step=sh.replicated(new_mesh),
            m=sh.param_shardings(cfg, state_template.opt.m, new_mesh),
            v=sh.param_shardings(cfg, state_template.opt.v, new_mesh),
        ),
        error_feedback=None,
    )
    state, meta = checkpoint.restore(
        ckpt_dir, state_template, shardings=state_sh
    )
    return state, meta, state_sh


@dataclass
class FailureEvent:
    step: int
    host: int
    kind: str = "crash"  # crash | straggle


@dataclass
class FaultInjector:
    """Deterministic failure schedule for tests/examples."""

    events: List[FailureEvent] = field(default_factory=list)

    def at(self, step: int) -> List[FailureEvent]:
        return [e for e in self.events if e.step == step]
