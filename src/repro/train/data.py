"""Deterministic synthetic data pipeline.

Token streams are generated with a counter-based hash (Philox via
``np.random.Generator`` keyed on (seed, step, shard)), so:

* any batch is reproducible from (seed, step) alone — checkpoints only
  need to store the step to resume bit-exactly;
* each data shard draws from a disjoint key-space — no host reads another
  host's slice (the real-cluster ingestion pattern).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..configs.base import ArchConfig


def make_batch(
    cfg: ArchConfig,
    batch_size: int,
    seq_len: int,
    step: int,
    seed: int = 0,
    shard: int = 0,
    n_shards: int = 1,
) -> Dict[str, np.ndarray]:
    """One global (or per-shard) batch for the given family."""
    assert batch_size % n_shards == 0
    b_local = batch_size // n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard])
    )
    V = cfg.vocab_size

    def tokens(b, s):
        return rng.integers(0, V, size=(b, s), dtype=np.int32)

    if cfg.family == "audio":
        frames = rng.normal(size=(b_local, seq_len, cfg.frontend_dim)).astype(
            np.float32
        )
        labels = tokens(b_local, seq_len)
        # mask ~8% of frames as prediction targets (HuBERT-style); others -1
        mask = rng.random((b_local, seq_len)) < 0.08
        labels = np.where(mask, labels, -1).astype(np.int32)
        return {"frames": frames, "labels": labels}

    if cfg.family == "vlm":
        Ti = cfg.vlm_img_tokens
        St = seq_len - Ti
        toks = tokens(b_local, St + 1)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "patch_embeds": rng.normal(
                size=(b_local, Ti, cfg.frontend_dim)
            ).astype(np.float32),
        }

    toks = tokens(b_local, seq_len + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


class DataLoader:
    """Stateful cursor over the synthetic stream (checkpointable)."""

    def __init__(
        self,
        cfg: ArchConfig,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
        start_step: int = 0,
    ):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed
        self.step = start_step

    def next(self) -> Dict[str, np.ndarray]:
        batch = make_batch(
            self.cfg, self.batch_size, self.seq_len, self.step, self.seed
        )
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])
