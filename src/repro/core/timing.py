"""Per-iteration training-time model: paper Eqs. (4)-(7).

A *placement* is ``{server_id: x}`` where ``x`` is an int vector of length
``S_i`` with ``x[s] = x_{i,s}^m`` = number of GPUs that server ``m``
contributes to stage ``s``.  Constraint (2): ``sum_m x[s] == k_{i,s}``.

Per-stage, per-server time  beta_{i,s}^m = comp + comm + AllReduce:

* comp (Eq. 4):     ``p_f + p_b``                  if ``x_s^m > 0``
* comm (Eq. 5):     inter-server traffic over the reserved NIC share
                    ``(x_s^m / g) * B_inter`` plus co-located traffic over
                    ``B_intra``;
* AllReduce (Eq. 6): ring/tree all-reduce moves ``2 (k-1)/k * h`` bytes per
  replica; bottleneck bandwidth is the stage's reserved NIC share when the
  replicas span servers, else ``B_intra``.  (The published Eq. (6) is
  typographically ambiguous about the ``1/k`` factor; we keep the NCCL
  ``2(k-1)/k`` data-size model consistently, as in the graph edge weights.)

alpha_i (Eq. 7) = max over (server, stage) of beta — the bottleneck stage of
the fully-pipelined (asynchronous) execution.

Degradation (straggler) support: a per-server *speed factor* ``f`` models a
partially-degraded server (thermally throttled GPUs, slowed NIC).  It
scales the server's effective compute throughput and both bandwidths by
``f`` at once, so every stage term evaluated on that server stretches by
exactly ``1/f`` — the whole ``beta`` is divided by ``f`` as the final
operation, identically on the scalar reference and the array engine (the
two stay bit-identical under degradation).  ``speeds`` mappings are
sparse: absent servers are at full speed.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from .job import ClusterSpec, JobSpec, ServerGeom

# ``geom``: (gpus_per_server, b_inter, b_intra) of the server whose stage
# vector is being timed.  ``None`` means the cluster-wide (homogeneous)
# values — the fast path every pre-heterogeneity formula reduces to.
Geoms = Mapping[int, ServerGeom]  # server id (or rank) -> geometry


def _stage_comm_time(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    nic_share: float | None = None,
    geom: Optional[ServerGeom] = None,
) -> float:
    """Eq. (5): inter-stage communication time of stage ``s`` on one server.

    ``x_m`` is this server's GPU vector; ``nic_share`` optionally overrides
    the reserved NIC bandwidth (used for the alpha_max bound); ``geom``
    supplies this server's (gpus, b_inter, b_intra) on heterogeneous
    clusters.
    """
    st = job.stages[s]
    x_s = int(x_m[s])
    if x_s == 0:
        return 0.0
    if geom is None:
        g, b_inter, b_intra = (
            cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
        )
    else:
        g, b_inter, b_intra = geom
    if nic_share is None:
        nic_share = (x_s / g) * b_inter

    inter_bytes = 0.0  # bytes crossing the NIC, per replica-pair fractioning
    intra_bytes = 0.0
    if s > 0:
        k_prev = job.stages[s - 1].k
        x_prev = int(x_m[s - 1])
        frac_remote = (k_prev - x_prev) / k_prev
        inter_bytes += 2.0 * st.d_in * frac_remote
        intra_bytes += 2.0 * st.d_in * (x_prev / k_prev)
    if s < job.num_stages - 1:
        k_next = job.stages[s + 1].k
        x_next = int(x_m[s + 1])
        frac_remote = (k_next - x_next) / k_next
        inter_bytes += 2.0 * st.d_out * frac_remote
        intra_bytes += 2.0 * st.d_out * (x_next / k_next)

    t = 0.0
    if inter_bytes > 0.0:
        # numerator carries x_s replicas' traffic; reserved share scales with
        # x_s too, so the ratio equals inter_bytes * g / B_inter (Eq. 5).
        t += inter_bytes * x_s / nic_share
    if intra_bytes > 0.0:
        t += intra_bytes / b_intra
    return t


def _stage_allreduce_time(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    nic_share: float | None = None,
    geom: Optional[ServerGeom] = None,
) -> float:
    """Eq. (6): intra-stage parameter synchronization time on one server."""
    st = job.stages[s]
    x_s = int(x_m[s])
    if x_s == 0 or st.k < 2 or st.h <= 0.0:
        return 0.0
    if geom is None:
        g, b_inter, b_intra = (
            cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
        )
    else:
        g, b_inter, b_intra = geom
    data = 2.0 * (st.k - 1) / st.k * st.h  # bytes per replica (RAR == TAR)
    if x_s == st.k:  # all replicas co-located: intra-server only
        return data / b_intra
    if nic_share is None:
        nic_share = (x_s / g) * b_inter
    return data * x_s / nic_share


def beta(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    geom: Optional[ServerGeom] = None,
    speed: float = 1.0,
) -> float:
    """beta_{i,s}^m: per-iteration time of stage ``s`` on one server.

    ``geom`` identifies the server's class geometry on heterogeneous
    clusters (``None`` = the homogeneous cluster-wide values).  ``speed``
    is the server's degradation factor: compute and bandwidths all scale
    by it, so the whole term is divided by it (a lone final division —
    ``speed == 1.0`` leaves the clean float chain untouched).
    """
    if int(x_m[s]) == 0:
        return 0.0
    st = job.stages[s]
    comp = st.p_f + st.p_b  # Eq. (4)
    b = (
        comp
        + _stage_comm_time(job, x_m, s, cluster, geom=geom)
        + _stage_allreduce_time(job, x_m, s, cluster, geom=geom)
    )
    if speed != 1.0:
        b = b / speed
    return b


def alpha_reference(
    job: JobSpec,
    placement: Mapping[int, np.ndarray],
    cluster: ClusterSpec,
    geoms: Optional[Geoms] = None,
    speeds: Optional[Mapping[int, float]] = None,
) -> float:
    """Pure-Python Eq. (7): max over (server, stage) of ``beta`` calls.

    Retained as the property-test reference for the array-native ``alpha``
    (tests/test_vectorized.py) and used by the reference engine
    (``heavy_edge.map_job(..., reference=True)``).  ``speeds``: sparse
    per-server degradation factors (keys match the placement's).
    """
    het = geoms is not None or cluster.is_heterogeneous
    best = 0.0
    for m, x_m in placement.items():
        x_m = np.asarray(x_m)
        if het:
            geom = geoms[m] if geoms is not None else cluster.server_geom(m)
        else:
            geom = None
        f = speeds.get(m, 1.0) if speeds else 1.0
        for s in range(job.num_stages):
            if x_m[s] > 0:
                b = beta(job, x_m, s, cluster, geom=geom, speed=f)
                if b > best:
                    best = b
    return best


class _ConfigArrays:
    """Per-stage profile vectors of one job config (keyed by config_key).

    Every quantity Eqs. (4)-(7) read per stage, precomputed with the exact
    arithmetic of the scalar reference (``two_d_in = 2.0 * d_in`` etc.) so
    the vectorized evaluation reproduces its floats bit for bit.
    """

    __slots__ = (
        "S", "comp", "two_d_in_tail", "two_d_out_head", "k_head", "k_tail",
        "k_i", "ar_data", "has_ar", "has_ar_any",
        "comp_l", "tdi_l", "tdo_l", "k_lf", "k_li", "ar_l", "har_l",
    )

    def __init__(self, job: JobSpec):
        stages = job.stages
        self.S = len(stages)
        self.comp = np.array([st.p_f + st.p_b for st in stages])
        two_d_in = np.array([2.0 * st.d_in for st in stages])
        two_d_out = np.array([2.0 * st.d_out for st in stages])
        self.k_i = np.array([st.k for st in stages], dtype=np.int64)
        k_f = self.k_i.astype(np.float64)
        # pre-sliced neighbor views (stage s reads k_{s-1} / k_{s+1})
        self.two_d_in_tail = two_d_in[1:]
        self.two_d_out_head = two_d_out[:-1]
        self.k_head = k_f[:-1]
        self.k_tail = k_f[1:]
        h = np.array([st.h for st in stages])
        self.ar_data = 2.0 * (self.k_i - 1) / self.k_i * h
        self.has_ar = (self.k_i >= 2) & (h > 0.0)
        self.has_ar_any = bool(self.has_ar.any())
        # Python-scalar mirrors for the small-placement path (identical
        # IEEE doubles: .tolist() is exact)
        self.comp_l = self.comp.tolist()
        self.tdi_l = two_d_in.tolist()
        self.tdo_l = two_d_out.tolist()
        self.k_lf = k_f.tolist()
        self.k_li = self.k_i.tolist()
        self.ar_l = self.ar_data.tolist()
        self.har_l = self.has_ar.tolist()


_CONFIG_ARRAYS: Dict[int, _ConfigArrays] = {}


def config_arrays(job: JobSpec) -> _ConfigArrays:
    key = job.config_key
    ca = _CONFIG_ARRAYS.get(key)
    if ca is None:
        ca = _CONFIG_ARRAYS[key] = _ConfigArrays(job)
    return ca


_SCALAR_CELLS = 64  # below this, Python scalars beat numpy dispatch


def _alpha_rows_scalar(ca, rows, g_l, bi_l, bx_l, f_l=None):
    """Scalar evaluation of ``alpha_matrix`` for a list of K x S int-list
    placements — the identical IEEE operation chain on Python floats, used
    when the whole batch is smaller than numpy's per-op dispatch cost.
    ``f_l``: optional per-server speed factors (divides each cell like the
    reference's final ``b / speed``)."""
    S = ca.S
    comp = ca.comp_l
    tdi, tdo = ca.tdi_l, ca.tdo_l
    kf, ki = ca.k_lf, ca.k_li
    ar_d, har = ca.ar_l, ca.har_l
    out = []
    for Xr in rows:
        best = 0.0
        for m, xm in enumerate(Xr):
            g_m, bi_m, bx_m = g_l[m], bi_l[m], bx_l[m]
            f_m = f_l[m] if f_l is not None else 1.0
            for s in range(S):
                x = xm[s]
                if x <= 0:
                    continue
                nic = (x / g_m) * bi_m
                if S > 1:
                    if s > 0:
                        kp = kf[s - 1]
                        xp = xm[s - 1]
                        inter = tdi[s] * ((kp - xp) / kp)
                        intra = tdi[s] * (xp / kp)
                    else:
                        inter = 0.0
                        intra = 0.0
                    if s < S - 1:
                        kn = kf[s + 1]
                        xn = xm[s + 1]
                        inter = inter + tdo[s] * ((kn - xn) / kn)
                        intra = intra + tdo[s] * (xn / kn)
                    core = comp[s] + (inter * x / nic + intra / bx_m)
                else:
                    core = comp[s]
                if har[s]:
                    if x == ki[s]:
                        core = core + ar_d[s] / bx_m
                    else:
                        core = core + ar_d[s] * x / nic
                if f_m != 1.0:
                    core = core / f_m
                if core > best:
                    best = core
        out.append(best)
    return out


def alpha_matrix(job: JobSpec, X: np.ndarray, g, b_inter, b_intra, speed=None):
    """Eqs. (4)-(7) for whole placements as one (servers x stages) array
    expression.

    ``X``: int GPU matrix, shape ``(K, S)`` or batched ``(B, K, S)`` (the
    refine path evaluates every candidate placement in one call).
    ``g``/``b_inter``/``b_intra``: scalars on homogeneous clusters, or
    per-server ``(K, 1)`` arrays carrying each rank's class geometry.
    ``speed``: optional ``(K, 1)`` per-server degradation factors — each
    server's beta row is divided by its factor as the final op, mirroring
    the reference's ``b / speed``.
    Returns a float for 2-D ``X``, else a ``(B,)`` array of alphas.

    Bit-identical to ``alpha_reference``: every elementwise op mirrors the
    scalar chain (same association order), masked terms reproduce the
    ``if bytes > 0`` skips, and the final max equals the loop's running max.
    """
    ca = config_arrays(job)
    if X.size == 0:
        return 0.0 if X.ndim == 2 else np.zeros(X.shape[0])
    if X.size <= _SCALAR_CELLS:
        K = X.shape[-2]
        if isinstance(g, np.ndarray):
            g_l = g.ravel().tolist()
            bi_l = b_inter.ravel().tolist()
            bx_l = b_intra.ravel().tolist()
        else:
            g_l = [g] * K
            bi_l = [b_inter] * K
            bx_l = [b_intra] * K
        f_l = speed.ravel().tolist() if speed is not None else None
        if X.ndim == 2:
            return _alpha_rows_scalar(
                ca, [X.tolist()], g_l, bi_l, bx_l, f_l
            )[0]
        return np.array(
            _alpha_rows_scalar(ca, X.tolist(), g_l, bi_l, bx_l, f_l)
        )
    Xf = X.astype(np.float64)
    pos = X > 0
    S = ca.S
    nic = np.where(pos, (Xf / g) * b_inter, 1.0)  # 1.0: masked, avoids 0/0
    if S > 1:
        inter = np.zeros(Xf.shape)
        intra = np.zeros(Xf.shape)
        xp = Xf[..., :-1]
        kp = ca.k_head
        inter[..., 1:] = ca.two_d_in_tail * ((kp - xp) / kp)
        intra[..., 1:] = ca.two_d_in_tail * (xp / kp)
        xn = Xf[..., 1:]
        kn = ca.k_tail
        inter[..., :-1] += ca.two_d_out_head * ((kn - xn) / kn)
        intra[..., :-1] += ca.two_d_out_head * (xn / kn)
        # zero-byte terms contribute exact zeros, matching the reference's
        # ``if bytes > 0`` skips without the branch
        comm = inter * Xf / nic + intra / b_intra
    else:
        comm = None  # single stage: no pipeline neighbors, Eq. (5) is 0
    if ca.has_ar_any:
        ar = np.where(
            ca.has_ar & pos,
            np.where(X == ca.k_i, ca.ar_data / b_intra, ca.ar_data * Xf / nic),
            0.0,
        )
        core = (ca.comp + comm) + ar if comm is not None else ca.comp + ar
    else:
        core = ca.comp + comm if comm is not None else ca.comp
    beta_ = np.where(pos, core, 0.0)
    if speed is not None:
        # per-server stretch: same final division as the scalar chain
        # (masked zeros stay exact zeros — factors are > 0)
        beta_ = beta_ / speed
    if X.ndim == 2:
        return float(beta_.max())
    return beta_.reshape(X.shape[0], -1).max(axis=1)


def _geom_columns(placement_keys, cluster: ClusterSpec, geoms: Optional[Geoms]):
    """(g, b_inter, b_intra) broadcast columns for a list of server keys."""
    if geoms is not None:
        geo = [geoms[m] for m in placement_keys]
    else:
        geo = [cluster.server_geom(m) for m in placement_keys]
    g = np.array([t[0] for t in geo], dtype=np.float64)[:, None]
    bi = np.array([t[1] for t in geo])[:, None]
    bx = np.array([t[2] for t in geo])[:, None]
    return g, bi, bx


def alpha(
    job: JobSpec,
    placement: Mapping[int, np.ndarray],
    cluster: ClusterSpec,
    geoms: Optional[Geoms] = None,
    speeds: Optional[Mapping[int, float]] = None,
) -> float:
    """Eq. (7): alpha_i = max over (server, stage) of beta_{i,s}^m.

    Array-native: evaluates the whole placement through ``alpha_matrix``
    (bit-identical to ``alpha_reference``, property-tested).  ``geoms``
    overrides the per-server geometry lookup (used by the canonical
    rank-relabeled mapping, whose placement keys are ranks, not physical
    server ids); without it heterogeneous specs resolve each key through
    ``cluster.server_geom``, homogeneous specs use the cluster scalars.
    ``speeds``: sparse per-server degradation factors (see module doc);
    an empty/None mapping is the clean fast path.
    """
    if not placement:
        return 0.0
    ms = list(placement)
    # int() in the reference truncates toward zero; astype matches for the
    # non-negative vectors every caller passes
    X = np.array([np.asarray(placement[m]) for m in ms]).astype(np.int64)
    if geoms is not None or cluster.is_heterogeneous:
        g, bi, bx = _geom_columns(ms, cluster, geoms)
    else:
        g, bi, bx = cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
    f_col = None
    if speeds:
        get = speeds.get
        fs = [get(m, 1.0) for m in ms]
        if any(f != 1.0 for f in fs):
            f_col = np.array(fs)[:, None]
    return alpha_matrix(job, X, g, bi, bx, speed=f_col)


def validate_placement(
    job: JobSpec, placement: Mapping[int, np.ndarray]
) -> None:
    """Check constraint (2): every stage fully allocated."""
    total = np.zeros(job.num_stages, dtype=np.int64)
    for x_m in placement.values():
        x = np.asarray(x_m)
        if np.any(x < 0):
            raise ValueError("negative GPU allocation")
        total += x
    expected = np.array([st.k for st in job.stages])
    if not np.array_equal(total, expected):
        raise ValueError(
            f"placement allocates {total.tolist()} GPUs per stage, "
            f"job requires {expected.tolist()}"
        )


def alpha_max(
    job: JobSpec, cluster: ClusterSpec, nic_share: Optional[float] = None
) -> float:
    """Worst-case per-iteration time (paper Sec. III-B).

    The job is hypothetically spread over ``g_i`` servers, one replica each,
    with NIC share fixed at ``(1/g) * B_inter``.  On a heterogeneous
    cluster the bound takes the worst reserved share over the server
    classes (slowest NIC relative to its per-server GPU count), keeping
    alpha_max an upper bound for every feasible placement.

    ``nic_share`` overrides the reserved-share computation — the
    degradation-aware admission bounds (simulator.AlphaCache) evaluate
    the spread bound per server class, then stretch it by that class's
    straggler factor (a degraded server slows compute and NIC alike, so
    the whole per-stage time divides by the factor).
    """
    if nic_share is None:
        if cluster.is_heterogeneous:
            nic_share = min(
                b_inter / g for g, b_inter, _b_intra in
                (
                    cluster.class_geom(c)
                    for c in range(len(cluster.server_classes))
                )
            )
        else:
            nic_share = cluster.b_inter / cluster.gpus_per_server
    worst = 0.0
    for s, st in enumerate(job.stages):
        x_m = np.zeros(job.num_stages, dtype=np.int64)
        x_m[s] = 1  # lone replica of stage s on its own server
        comp = st.p_f + st.p_b
        comm = _stage_comm_time(job, x_m, s, cluster, nic_share=nic_share)
        ar = _stage_allreduce_time(job, x_m, s, cluster, nic_share=nic_share)
        worst = max(worst, comp + comm + ar)
    return worst


def placement_from_assignment(
    job: JobSpec, assignment: Mapping[tuple, int]
) -> Dict[int, np.ndarray]:
    """Convert a vertex->server assignment into x_{i,s}^m vectors."""
    placement: Dict[int, np.ndarray] = {}
    for (s, _r), m in assignment.items():
        if m not in placement:
            placement[m] = np.zeros(job.num_stages, dtype=np.int64)
        placement[m][s] += 1
    return placement


def servers_touched(placement: Mapping[int, np.ndarray]) -> Sequence[int]:
    return [m for m, x in placement.items() if np.asarray(x).sum() > 0]


def alpha_sync(
    job: JobSpec,
    placement: Mapping[int, np.ndarray],
    cluster: ClusterSpec,
    n_microbatches: int = 4,
) -> float:
    """Synchronous (GPipe-style) per-iteration time variant (paper Sec.
    III-B remark, following the analytic model of [20]).

    With m micro-batches and S stages, the pipeline fills/drains:
        T = (m + S - 1)/m * beta_bottleneck(comp+comm) + AllReduce
    where AllReduce is paid once per iteration at the sync barrier.
    """
    S = job.num_stages
    het = cluster.is_heterogeneous
    bottleneck = 0.0
    ar = 0.0
    for m, x_m in placement.items():
        x_m = np.asarray(x_m)
        geom = cluster.server_geom(m) if het else None
        for s in range(S):
            if x_m[s] == 0:
                continue
            st = job.stages[s]
            comp = st.p_f + st.p_b
            comm = _stage_comm_time(job, x_m, s, cluster, geom=geom)
            bottleneck = max(bottleneck, comp + comm)
            ar = max(ar, _stage_allreduce_time(job, x_m, s, cluster, geom=geom))
    m = max(1, n_microbatches)
    return (m + S - 1) / m * bottleneck + ar
