"""Per-iteration training-time model: paper Eqs. (4)-(7).

A *placement* is ``{server_id: x}`` where ``x`` is an int vector of length
``S_i`` with ``x[s] = x_{i,s}^m`` = number of GPUs that server ``m``
contributes to stage ``s``.  Constraint (2): ``sum_m x[s] == k_{i,s}``.

Per-stage, per-server time  beta_{i,s}^m = comp + comm + AllReduce:

* comp (Eq. 4):     ``p_f + p_b``                  if ``x_s^m > 0``
* comm (Eq. 5):     inter-server traffic over the reserved NIC share
                    ``(x_s^m / g) * B_inter`` plus co-located traffic over
                    ``B_intra``;
* AllReduce (Eq. 6): ring/tree all-reduce moves ``2 (k-1)/k * h`` bytes per
  replica; bottleneck bandwidth is the stage's reserved NIC share when the
  replicas span servers, else ``B_intra``.  (The published Eq. (6) is
  typographically ambiguous about the ``1/k`` factor; we keep the NCCL
  ``2(k-1)/k`` data-size model consistently, as in the graph edge weights.)

alpha_i (Eq. 7) = max over (server, stage) of beta — the bottleneck stage of
the fully-pipelined (asynchronous) execution.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from .job import ClusterSpec, JobSpec, ServerGeom

# ``geom``: (gpus_per_server, b_inter, b_intra) of the server whose stage
# vector is being timed.  ``None`` means the cluster-wide (homogeneous)
# values — the fast path every pre-heterogeneity formula reduces to.
Geoms = Mapping[int, ServerGeom]  # server id (or rank) -> geometry


def _stage_comm_time(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    nic_share: float | None = None,
    geom: Optional[ServerGeom] = None,
) -> float:
    """Eq. (5): inter-stage communication time of stage ``s`` on one server.

    ``x_m`` is this server's GPU vector; ``nic_share`` optionally overrides
    the reserved NIC bandwidth (used for the alpha_max bound); ``geom``
    supplies this server's (gpus, b_inter, b_intra) on heterogeneous
    clusters.
    """
    st = job.stages[s]
    x_s = int(x_m[s])
    if x_s == 0:
        return 0.0
    if geom is None:
        g, b_inter, b_intra = (
            cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
        )
    else:
        g, b_inter, b_intra = geom
    if nic_share is None:
        nic_share = (x_s / g) * b_inter

    inter_bytes = 0.0  # bytes crossing the NIC, per replica-pair fractioning
    intra_bytes = 0.0
    if s > 0:
        k_prev = job.stages[s - 1].k
        x_prev = int(x_m[s - 1])
        frac_remote = (k_prev - x_prev) / k_prev
        inter_bytes += 2.0 * st.d_in * frac_remote
        intra_bytes += 2.0 * st.d_in * (x_prev / k_prev)
    if s < job.num_stages - 1:
        k_next = job.stages[s + 1].k
        x_next = int(x_m[s + 1])
        frac_remote = (k_next - x_next) / k_next
        inter_bytes += 2.0 * st.d_out * frac_remote
        intra_bytes += 2.0 * st.d_out * (x_next / k_next)

    t = 0.0
    if inter_bytes > 0.0:
        # numerator carries x_s replicas' traffic; reserved share scales with
        # x_s too, so the ratio equals inter_bytes * g / B_inter (Eq. 5).
        t += inter_bytes * x_s / nic_share
    if intra_bytes > 0.0:
        t += intra_bytes / b_intra
    return t


def _stage_allreduce_time(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    nic_share: float | None = None,
    geom: Optional[ServerGeom] = None,
) -> float:
    """Eq. (6): intra-stage parameter synchronization time on one server."""
    st = job.stages[s]
    x_s = int(x_m[s])
    if x_s == 0 or st.k < 2 or st.h <= 0.0:
        return 0.0
    if geom is None:
        g, b_inter, b_intra = (
            cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
        )
    else:
        g, b_inter, b_intra = geom
    data = 2.0 * (st.k - 1) / st.k * st.h  # bytes per replica (RAR == TAR)
    if x_s == st.k:  # all replicas co-located: intra-server only
        return data / b_intra
    if nic_share is None:
        nic_share = (x_s / g) * b_inter
    return data * x_s / nic_share


def beta(
    job: JobSpec,
    x_m: np.ndarray,
    s: int,
    cluster: ClusterSpec,
    geom: Optional[ServerGeom] = None,
) -> float:
    """beta_{i,s}^m: per-iteration time of stage ``s`` on one server.

    ``geom`` identifies the server's class geometry on heterogeneous
    clusters (``None`` = the homogeneous cluster-wide values).
    """
    if int(x_m[s]) == 0:
        return 0.0
    st = job.stages[s]
    comp = st.p_f + st.p_b  # Eq. (4)
    return (
        comp
        + _stage_comm_time(job, x_m, s, cluster, geom=geom)
        + _stage_allreduce_time(job, x_m, s, cluster, geom=geom)
    )


def alpha(
    job: JobSpec,
    placement: Mapping[int, np.ndarray],
    cluster: ClusterSpec,
    geoms: Optional[Geoms] = None,
) -> float:
    """Eq. (7): alpha_i = max over (server, stage) of beta_{i,s}^m.

    ``geoms`` overrides the per-server geometry lookup (used by the
    canonical rank-relabeled mapping, whose placement keys are ranks, not
    physical server ids).  Without it, heterogeneous specs resolve each
    placement key through ``cluster.server_geom``; homogeneous specs take
    the unchanged fast path.
    """
    het = geoms is not None or cluster.is_heterogeneous
    best = 0.0
    for m, x_m in placement.items():
        x_m = np.asarray(x_m)
        if het:
            geom = geoms[m] if geoms is not None else cluster.server_geom(m)
        else:
            geom = None
        for s in range(job.num_stages):
            if x_m[s] > 0:
                b = beta(job, x_m, s, cluster, geom=geom)
                if b > best:
                    best = b
    return best


def validate_placement(
    job: JobSpec, placement: Mapping[int, np.ndarray]
) -> None:
    """Check constraint (2): every stage fully allocated."""
    total = np.zeros(job.num_stages, dtype=np.int64)
    for x_m in placement.values():
        x = np.asarray(x_m)
        if np.any(x < 0):
            raise ValueError("negative GPU allocation")
        total += x
    expected = np.array([st.k for st in job.stages])
    if not np.array_equal(total, expected):
        raise ValueError(
            f"placement allocates {total.tolist()} GPUs per stage, "
            f"job requires {expected.tolist()}"
        )


def alpha_max(job: JobSpec, cluster: ClusterSpec) -> float:
    """Worst-case per-iteration time (paper Sec. III-B).

    The job is hypothetically spread over ``g_i`` servers, one replica each,
    with NIC share fixed at ``(1/g) * B_inter``.  On a heterogeneous
    cluster the bound takes the worst reserved share over the server
    classes (slowest NIC relative to its per-server GPU count), keeping
    alpha_max an upper bound for every feasible placement.
    """
    if cluster.is_heterogeneous:
        nic_share = min(
            b_inter / g for g, b_inter, _b_intra in
            (cluster.class_geom(c) for c in range(len(cluster.server_classes)))
        )
    else:
        nic_share = cluster.b_inter / cluster.gpus_per_server
    worst = 0.0
    for s, st in enumerate(job.stages):
        x_m = np.zeros(job.num_stages, dtype=np.int64)
        x_m[s] = 1  # lone replica of stage s on its own server
        comp = st.p_f + st.p_b
        comm = _stage_comm_time(job, x_m, s, cluster, nic_share=nic_share)
        ar = _stage_allreduce_time(job, x_m, s, cluster, nic_share=nic_share)
        worst = max(worst, comp + comm + ar)
    return worst


def placement_from_assignment(
    job: JobSpec, assignment: Mapping[tuple, int]
) -> Dict[int, np.ndarray]:
    """Convert a vertex->server assignment into x_{i,s}^m vectors."""
    placement: Dict[int, np.ndarray] = {}
    for (s, _r), m in assignment.items():
        if m not in placement:
            placement[m] = np.zeros(job.num_stages, dtype=np.int64)
        placement[m][s] += 1
    return placement


def servers_touched(placement: Mapping[int, np.ndarray]) -> Sequence[int]:
    return [m for m, x in placement.items() if np.asarray(x).sum() > 0]


def alpha_sync(
    job: JobSpec,
    placement: Mapping[int, np.ndarray],
    cluster: ClusterSpec,
    n_microbatches: int = 4,
) -> float:
    """Synchronous (GPipe-style) per-iteration time variant (paper Sec.
    III-B remark, following the analytic model of [20]).

    With m micro-batches and S stages, the pipeline fills/drains:
        T = (m + S - 1)/m * beta_bottleneck(comp+comm) + AllReduce
    where AllReduce is paid once per iteration at the sync barrier.
    """
    S = job.num_stages
    het = cluster.is_heterogeneous
    bottleneck = 0.0
    ar = 0.0
    for m, x_m in placement.items():
        x_m = np.asarray(x_m)
        geom = cluster.server_geom(m) if het else None
        for s in range(S):
            if x_m[s] == 0:
                continue
            st = job.stages[s]
            comp = st.p_f + st.p_b
            comm = _stage_comm_time(job, x_m, s, cluster, geom=geom)
            bottleneck = max(bottleneck, comp + comm)
            ar = max(ar, _stage_allreduce_time(job, x_m, s, cluster, geom=geom))
    m = max(1, n_microbatches)
    return (m + S - 1) / m * bottleneck + ar
