"""Mutable cluster state for the schedulers/simulator.

Change-tracking for incremental schedulers (see asrpt.py): every mutation
bumps ``epoch``.  While ``epoch`` is unchanged a policy may reuse any
decision that is a pure function of the free-capacity state; nothing
weaker is sound — in particular "only releases can improve a placement"
does NOT hold, because Heavy-Edge is greedy and shrinking capacities can
reshuffle the selected capacity vector into one the greedy maps better.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Mapping, Optional

import numpy as np

from .job import ClusterSpec


class ClusterState:
    """Tracks free GPUs per server and per-job allocations.

    Alongside the ``free`` dict the state maintains ``free_buckets`` —
    server ids grouped by free-GPU count, ascending ids within a bucket
    (the exact structure ``heavy_edge.select_servers`` builds per call) —
    so per-event server selection walks the buckets directly instead of
    re-sorting all servers.  Buckets update in O(servers touched) per
    allocate/release; ascending-id order is preserved by ``bisect.insort``
    and matches dict-iteration order (ids are inserted 0..M-1 and never
    removed), keeping bucket-based selection bit-identical.
    """

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        if spec.is_heterogeneous:
            # per-server capacity follows the server's class
            self._cap: Dict[int, int] = {
                m: spec.server_gpus(m) for m in range(spec.num_servers)
            }
        else:
            self._cap = {
                m: spec.gpus_per_server for m in range(spec.num_servers)
            }
        self.free: Dict[int, int] = dict(self._cap)
        self.free_buckets: List[List[int]] = [
            [] for _ in range(spec.gpus_per_server + 1)
        ]
        for m in range(spec.num_servers):  # ascending ids per bucket
            self.free_buckets[self.free[m]].append(m)
        self._job_alloc: Dict[int, Dict[int, int]] = {}
        self.total_free: int = spec.total_gpus
        self._down: set = set()
        self.epoch: int = 0

    def _move_bucket(self, m: int, old: int, new: int) -> None:
        if old > 0:
            self.free_buckets[old].remove(m)
        if new > 0:
            bisect.insort(self.free_buckets[new], m)

    def can_fit(self, g_needed: int) -> bool:
        return self.total_free >= g_needed

    def allocate(
        self,
        job_id: int,
        placement: Mapping[int, np.ndarray],
        counts: Optional[Dict[int, int]] = None,
    ) -> None:
        """Reserve GPUs for ``placement``.

        ``counts`` optionally supplies the per-server GPU totals (callers
        that selected capacities already know them; ownership transfers to
        the cluster state — don't mutate it afterwards); otherwise they
        are summed from the placement vectors.
        """
        free = self.free
        if counts is not None:
            per_server = counts
        else:
            per_server = {
                m: int(x.sum()) if isinstance(x, np.ndarray)
                else int(np.asarray(x).sum())
                for m, x in placement.items()
            }
        for m, n in per_server.items():
            if n > free.get(m, 0):
                raise ValueError(
                    f"server {m} has {free.get(m, 0)} free GPUs, "
                    f"job {job_id} wants {n}"
                )
        total = 0
        for m, n in per_server.items():
            old = free[m]
            free[m] = old - n
            self._move_bucket(m, old, old - n)
            total += n
        self.total_free -= total
        self._job_alloc[job_id] = per_server
        self.epoch += 1

    def release(self, job_id: int) -> None:
        cap = self._cap
        down = self._down
        total = 0
        for m, n in self._job_alloc.pop(job_id).items():
            if m in down:
                continue  # capacity on a failed server never returns
            old = self.free[m]
            self.free[m] = old + n
            self._move_bucket(m, old, old + n)
            total += n
            if self.free[m] > cap[m]:
                raise AssertionError(f"server {m} over-freed")
        self.total_free += total
        self.epoch += 1

    def mark_server_down(self, server_id: int) -> None:
        """Fault-tolerance hook: a failed server contributes no capacity.

        Free GPUs are removed immediately; GPUs still held by running jobs
        are forfeited as those jobs release (they never rejoin ``free``).
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if server_id in self._down:
            return
        self._down.add(server_id)
        old = self.free[server_id]
        self.total_free -= old
        self.free[server_id] = 0
        self._move_bucket(server_id, old, 0)
        self.epoch += 1

    @property
    def downed_servers(self) -> frozenset:
        return frozenset(self._down)

    def snapshot_free(self) -> Dict[int, int]:
        return dict(self.free)
