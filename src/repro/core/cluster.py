"""Mutable cluster state for the schedulers/simulator."""
from __future__ import annotations

from typing import Dict, Mapping

import numpy as np

from .job import ClusterSpec


class ClusterState:
    """Tracks free GPUs per server and per-job allocations."""

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.free: Dict[int, int] = {
            m: spec.gpus_per_server for m in range(spec.num_servers)
        }
        self._job_alloc: Dict[int, Dict[int, int]] = {}

    @property
    def total_free(self) -> int:
        return sum(self.free.values())

    def can_fit(self, g_needed: int) -> bool:
        return self.total_free >= g_needed

    def allocate(self, job_id: int, placement: Mapping[int, np.ndarray]) -> None:
        per_server = {
            m: int(np.asarray(x).sum()) for m, x in placement.items()
        }
        for m, n in per_server.items():
            if n > self.free.get(m, 0):
                raise ValueError(
                    f"server {m} has {self.free.get(m, 0)} free GPUs, "
                    f"job {job_id} wants {n}"
                )
        for m, n in per_server.items():
            self.free[m] -= n
        self._job_alloc[job_id] = per_server

    def release(self, job_id: int) -> None:
        for m, n in self._job_alloc.pop(job_id).items():
            self.free[m] += n
            if self.free[m] > self.spec.gpus_per_server:
                raise AssertionError(f"server {m} over-freed")

    def mark_server_down(self, server_id: int) -> None:
        """Fault-tolerance hook: a failed server contributes no capacity."""
        self.free[server_id] = 0

    def snapshot_free(self) -> Dict[int, int]:
        return dict(self.free)
