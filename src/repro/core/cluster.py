"""Mutable cluster state for the schedulers/simulator.

Change-tracking for incremental schedulers (see asrpt.py): every mutation
bumps ``epoch``.  While ``epoch`` is unchanged a policy may reuse any
decision that is a pure function of the free-capacity state; nothing
weaker is sound — in particular "only releases can improve a placement"
does NOT hold, because Heavy-Edge is greedy and shrinking capacities can
reshuffle the selected capacity vector into one the greedy maps better.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import numpy as np

from .job import ClusterSpec


class ClusterState:
    """Tracks free GPUs per server and per-job allocations."""

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        if spec.is_heterogeneous:
            # per-server capacity follows the server's class
            self._cap: Dict[int, int] = {
                m: spec.server_gpus(m) for m in range(spec.num_servers)
            }
        else:
            self._cap = {
                m: spec.gpus_per_server for m in range(spec.num_servers)
            }
        self.free: Dict[int, int] = dict(self._cap)
        self._job_alloc: Dict[int, Dict[int, int]] = {}
        self._total_free: int = spec.total_gpus
        self._down: set = set()
        self.epoch: int = 0

    @property
    def total_free(self) -> int:
        return self._total_free

    def can_fit(self, g_needed: int) -> bool:
        return self._total_free >= g_needed

    def allocate(
        self,
        job_id: int,
        placement: Mapping[int, np.ndarray],
        counts: Optional[Mapping[int, int]] = None,
    ) -> None:
        """Reserve GPUs for ``placement``.

        ``counts`` optionally supplies the per-server GPU totals (callers
        that selected capacities already know them); otherwise they are
        summed from the placement vectors.
        """
        free = self.free
        if counts is not None:
            per_server = dict(counts)
        else:
            per_server = {
                m: int(x.sum()) if isinstance(x, np.ndarray)
                else int(np.asarray(x).sum())
                for m, x in placement.items()
            }
        for m, n in per_server.items():
            if n > free.get(m, 0):
                raise ValueError(
                    f"server {m} has {free.get(m, 0)} free GPUs, "
                    f"job {job_id} wants {n}"
                )
        total = 0
        for m, n in per_server.items():
            free[m] -= n
            total += n
        self._total_free -= total
        self._job_alloc[job_id] = per_server
        self.epoch += 1

    def release(self, job_id: int) -> None:
        cap = self._cap
        down = self._down
        total = 0
        for m, n in self._job_alloc.pop(job_id).items():
            if m in down:
                continue  # capacity on a failed server never returns
            self.free[m] += n
            total += n
            if self.free[m] > cap[m]:
                raise AssertionError(f"server {m} over-freed")
        self._total_free += total
        self.epoch += 1

    def mark_server_down(self, server_id: int) -> None:
        """Fault-tolerance hook: a failed server contributes no capacity.

        Free GPUs are removed immediately; GPUs still held by running jobs
        are forfeited as those jobs release (they never rejoin ``free``).
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if server_id in self._down:
            return
        self._down.add(server_id)
        self._total_free -= self.free[server_id]
        self.free[server_id] = 0
        self.epoch += 1

    @property
    def downed_servers(self) -> frozenset:
        return frozenset(self._down)

    def snapshot_free(self) -> Dict[int, int]:
        return dict(self.free)
