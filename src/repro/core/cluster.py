"""Mutable cluster state for the schedulers/simulator.

Change-tracking for incremental schedulers (see asrpt.py): every mutation
bumps ``epoch``.  While ``epoch`` is unchanged a policy may reuse any
decision that is a pure function of the free-capacity state; nothing
weaker is sound — in particular "only releases can improve a placement"
does NOT hold, because Heavy-Edge is greedy and shrinking capacities can
reshuffle the selected capacity vector into one the greedy maps better.

Degradation (straggler) state: ``set_server_speed`` records a per-server
speed factor in (0, 1] ∪ (1, ∞) that scales the server's *effective*
compute and NIC bandwidth — every stage term evaluated on that server
stretches by ``1/factor`` (see timing.py).  GPU *counts* are unaffected:
a half-speed server still holds its GPUs, they just run slower.  A
factor of exactly ``0.0`` is a full failure and degrades to
``mark_server_down`` (the PR-2 fault path).  Speed changes bump
``epoch`` (placement decisions depend on them) and a separate
``speed_version`` so policies can cheaply detect "speeds changed while
caps stayed equal".

Elastic capacity (ServerJoin/ServerLeave scenario events, scenario.py):
``drain_server`` starts a *graceful* leave — free capacity is withdrawn
immediately (no new allocations) but the server keeps computing, so
running jobs are unaffected (and remain migratable off it, unlike a
dead server whose checkpoint state is lost); ``finish_drain`` ends the
window (the server is then down for good).  ``activate_server``
resurrects any inactive slot — a drained, left, or failed server —
restoring its class capacity minus GPUs still held by running jobs.  A
server rejoining from *down* starts clean at speed 1.0 (replacement
hardware); one rejoining from a cancelled *drain* keeps its speed
factor (it never stopped).  Three disjoint server states follow:
active, draining (no free caps, still computing), down (no free caps,
not computing); ``_inactive`` is draining ∪ down — the one set the
release/allocation paths consult.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .job import ClusterSpec, build_bw_ranks


class ClusterState:
    """Tracks free GPUs per server and per-job allocations.

    Alongside the ``free`` dict the state maintains ``free_buckets`` —
    server ids grouped by free-GPU count, ascending ids within a bucket
    (the exact structure ``heavy_edge.select_servers`` builds per call) —
    so per-event server selection walks the buckets directly instead of
    re-sorting all servers.  Buckets update in O(servers touched) per
    allocate/release; ascending-id order is preserved by ``bisect.insort``
    and matches dict-iteration order (ids are inserted 0..M-1 and never
    removed), keeping bucket-based selection bit-identical.
    """

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        if spec.is_heterogeneous:
            # per-server capacity follows the server's class
            self._cap: Dict[int, int] = {
                m: spec.server_gpus(m) for m in range(spec.num_servers)
            }
        else:
            self._cap = {
                m: spec.gpus_per_server for m in range(spec.num_servers)
            }
        self.free: Dict[int, int] = dict(self._cap)
        self.free_buckets: List[List[int]] = [
            [] for _ in range(spec.gpus_per_server + 1)
        ]
        for m in range(spec.num_servers):  # ascending ids per bucket
            self.free_buckets[self.free[m]].append(m)
        self._job_alloc: Dict[int, Dict[int, int]] = {}
        self.total_free: int = spec.total_gpus
        self._down: set = set()
        self._draining: set = set()
        self._inactive: set = set()  # _down | _draining, maintained inline
        self.epoch: int = 0
        # sparse straggler state: only servers with factor != 1.0 appear
        self._speed: Dict[int, float] = {}
        self.speed_version: int = 0
        self._bw_ranks: Optional[Tuple[tuple, tuple]] = None

    def _move_bucket(self, m: int, old: int, new: int) -> None:
        if old > 0:
            self.free_buckets[old].remove(m)
        if new > 0:
            bisect.insort(self.free_buckets[new], m)

    def can_fit(self, g_needed: int) -> bool:
        return self.total_free >= g_needed

    def allocate(
        self,
        job_id: int,
        placement: Mapping[int, np.ndarray],
        counts: Optional[Dict[int, int]] = None,
    ) -> None:
        """Reserve GPUs for ``placement``.

        ``counts`` optionally supplies the per-server GPU totals (callers
        that selected capacities already know them; ownership transfers to
        the cluster state — don't mutate it afterwards); otherwise they
        are summed from the placement vectors.
        """
        free = self.free
        if counts is not None:
            per_server = counts
        else:
            per_server = {
                m: int(x.sum()) if isinstance(x, np.ndarray)
                else int(np.asarray(x).sum())
                for m, x in placement.items()
            }
        for m, n in per_server.items():
            if n > free.get(m, 0):
                raise ValueError(
                    f"server {m} has {free.get(m, 0)} free GPUs, "
                    f"job {job_id} wants {n}"
                )
        total = 0
        for m, n in per_server.items():
            old = free[m]
            free[m] = old - n
            self._move_bucket(m, old, old - n)
            total += n
        self.total_free -= total
        self._job_alloc[job_id] = per_server
        self.epoch += 1

    def release(self, job_id: int) -> None:
        cap = self._cap
        gone = self._inactive
        total = 0
        for m, n in self._job_alloc.pop(job_id).items():
            if m in gone:
                continue  # capacity on a failed/leaving server never returns
            old = self.free[m]
            self.free[m] = old + n
            self._move_bucket(m, old, old + n)
            total += n
            if self.free[m] > cap[m]:
                raise AssertionError(f"server {m} over-freed")
        self.total_free += total
        self.epoch += 1

    def mark_server_down(self, server_id: int) -> None:
        """Fault-tolerance hook: a failed server contributes no capacity.

        Free GPUs are removed immediately; GPUs still held by running jobs
        are forfeited as those jobs release (they never rejoin ``free``).
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if server_id in self._down:
            return
        self._draining.discard(server_id)  # a drain overtaken by failure
        self._down.add(server_id)
        self._inactive.add(server_id)
        if self._speed.pop(server_id, None) is not None:
            # a dead straggler is just dead: its speed no longer matters,
            # and dropping it lets a now-clean cluster take the fast path
            self._bw_ranks = None
            self.speed_version += 1
        old = self.free[server_id]
        self.total_free -= old
        self.free[server_id] = 0
        self._move_bucket(server_id, old, 0)
        self.epoch += 1

    def drain_server(self, server_id: int) -> bool:
        """Elastic hook: begin a graceful leave (``ServerLeave``).

        Free capacity is withdrawn at once (no new allocations land
        here) and GPUs held by running jobs are forfeited as they
        release — exactly the ``mark_server_down`` capacity semantics —
        but the server *keeps computing*: running jobs are neither
        re-timed nor stranded, and the simulator offers them to
        ``plan_migrations`` while the drain window is open.  Returns
        True when state changed (False for an already-inactive server).
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if server_id in self._inactive:
            return False  # already down or draining
        self._draining.add(server_id)
        self._inactive.add(server_id)
        old = self.free[server_id]
        self.total_free -= old
        self.free[server_id] = 0
        self._move_bucket(server_id, old, 0)
        self.epoch += 1
        return True

    def finish_drain(self, server_id: int) -> bool:
        """Close a drain window: the server is now gone for good.

        Capacity effects all happened at ``drain_server``; this only
        flips draining -> down (jobs still on it finish in place and can
        no longer checkpoint-restart — their state leaves with the
        server) and drops the speed entry like ``mark_server_down``
        does.  No epoch bump: free capacity is unchanged.
        """
        if server_id not in self._draining:
            return False
        self._draining.discard(server_id)
        self._down.add(server_id)
        if self._speed.pop(server_id, None) is not None:
            self._bw_ranks = None
            self.speed_version += 1
        return True

    def activate_server(self, server_id: int) -> bool:
        """Elastic hook: an inactive server slot comes online
        (``ServerJoin``) with its class capacity minus GPUs still held
        by running jobs (those return to ``free`` as the jobs release,
        now that the server is active again).  Resurrects drained, left,
        *and* failed slots — a join on a downed slot models replacement
        hardware arriving at the same spec position (clean, speed 1.0).
        Returns True when state changed (False if already active — a
        no-op join triggers no scheduling pass).
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if server_id not in self._inactive:
            return False
        self._down.discard(server_id)
        self._draining.discard(server_id)
        self._inactive.discard(server_id)
        held = 0
        for alloc in self._job_alloc.values():
            held += alloc.get(server_id, 0)
        new_free = self._cap[server_id] - held
        old = self.free[server_id]  # 0 while inactive
        self.free[server_id] = new_free
        self._move_bucket(server_id, old, new_free)
        self.total_free += new_free - old
        self.epoch += 1
        return True

    def set_server_speed(self, server_id: int, factor: float) -> bool:
        """Degradation hook: scale a server's effective speed by ``factor``.

        ``factor == 1.0`` restores full speed (recovery), ``factor == 0.0``
        is a full failure and takes the ``mark_server_down`` path verbatim.
        Returns True when the state actually changed — a repeated event
        with the server's current factor is a no-op (no epoch bump), so
        all-1.0 degradation schedules stay bit-identical to clean runs.
        """
        if server_id not in self.free:
            raise ValueError(
                f"unknown server {server_id} "
                f"(cluster has {self.spec.num_servers})"
            )
        if factor < 0.0:
            raise ValueError(f"speed factor must be >= 0, got {factor}")
        if factor == 0.0:
            if server_id in self._down:
                return False
            self.mark_server_down(server_id)
            return True
        if server_id in self._down:
            return False  # dead servers don't recover (restart = new server)
        if factor == self._speed.get(server_id, 1.0):
            return False
        if factor == 1.0:
            del self._speed[server_id]
        else:
            self._speed[server_id] = factor
        self._bw_ranks = None
        self.speed_version += 1
        self.epoch += 1
        return True

    @property
    def has_degraded(self) -> bool:
        return bool(self._speed)

    @property
    def speed_factors(self) -> Dict[int, float]:
        """Sparse {server_id: factor} map (only factors != 1.0); treat as
        read-only — the timing layer takes it as the ``speeds`` mapping."""
        return self._speed

    def speed_of(self, server_id: int) -> float:
        return self._speed.get(server_id, 1.0)

    def speeds_for(
        self, caps: Sequence[Tuple[int, int]]
    ) -> Optional[Tuple[float, ...]]:
        """Per-slot factors aligned with a ``select_servers`` capacity
        vector, or None when no server is degraded (the clean fast path —
        callers skip speed threading entirely)."""
        sp = self._speed
        if not sp:
            return None
        get = sp.get
        return tuple(get(m, 1.0) for m, _c in caps)

    @property
    def effective_bw_ranks(self) -> Optional[Tuple[tuple, tuple]]:
        """(descending, ascending) effective-bandwidth rank tuples for the
        ``select_servers`` tiebreak, where effective bandwidth is the
        class NIC bandwidth times the server's speed factor.  None while
        no server is degraded — callers then fall back to the static
        ``ClusterSpec.bw_order_ranks`` (heterogeneous) or no tiebreak
        (homogeneous), keeping clean schedules byte-identical.
        """
        if not self._speed:
            return None
        ranks = self._bw_ranks
        if ranks is None:
            spec = self.spec
            sp = self._speed
            ranks = self._bw_ranks = build_bw_ranks(
                [
                    spec.server_geom(m)[1] * sp.get(m, 1.0)
                    for m in range(spec.num_servers)
                ]
            )
        return ranks

    @property
    def downed_servers(self) -> frozenset:
        return frozenset(self._down)

    @property
    def draining_servers(self) -> frozenset:
        return frozenset(self._draining)

    def snapshot_free(self) -> Dict[int, int]:
        return dict(self.free)
