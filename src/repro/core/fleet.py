"""Scenario fleets: batched Monte-Carlo robustness sweeps (ISSUE 7).

The paper's competitive-ratio claim is a statement about *distributions*
of adversarial conditions; a single deterministic benchmark row cannot
exercise it.  ``run_fleet`` generates N seeded variants of a base
:class:`~repro.core.scenario.Scenario` (straggler / elastic / fault /
arrival-jitter perturbation samplers layered on the PR-5 event stream)
and runs them through a shared-state driver instead of N sequential
``simulate()`` calls:

* **Shared caches.**  Every variant's policy is built with
  ``Policy.fleet_shared`` pointing at one :class:`FleetShared`, so all
  variants share one ``PlacementCache`` per refine flag (entries are
  pure functions of ``(cluster spec, config, capacity shape, classes,
  speeds)`` — cache purity is exactly what the in-run memoization
  already relies on, property-tested cached == uncached) and one pool
  of clean ``AlphaCache`` bounds.  Degraded alpha bounds depend on live
  per-variant cluster state and stay per policy instance, as does every
  queue / virtual-machine / allocation structure.

* **Batched cold refine.**  With ``prewarm=True`` and a refine-mapping
  policy, a cheap greedy *scout* run of the base scenario first records
  the realistic ``(config, shape)`` working set (the ~600 cold
  placements that floor A-SRPT throughput, ROADMAP 5a), then
  ``PlacementCache.warm`` computes all of them up front — the refine
  stage grouped across shapes and variants into one array program per
  ``(config, slot-count, bandwidth-pattern)`` group instead of one
  three-seed program per miss.  Warmed entries are bit-identical to
  what on-demand misses would compute, so fleet schedules equal the
  sequential path's byte for byte (pinned on all 10 golden scenarios).

Determinism: variant i draws from ``numpy.random.default_rng([seed,
i])``, so the whole :class:`FleetResult` — per-variant schedule sha256s
included — is a pure function of ``(base, policy factory,
perturbations, n_variants, seed)``.
"""
from __future__ import annotations

import hashlib
import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .job import ClusterSpec
from .scenario import Perturbation, Scenario, perturb_scenario
from .simulator import AlphaCache, Policy, simulate


class FleetShared:
    """Cross-variant cache pool handed to ``Policy.fleet_shared``.

    Hands out one :class:`~repro.core.heavy_edge.PlacementCache` per
    refine flag (shared instance: DenseGraph pool, seed store, and LRU
    amortize across the fleet) and per-policy ``AlphaCache`` instances
    whose *clean* bound dicts alias one shared pool.  Degraded bounds
    share too (the PR-7 limitation, closed in ISSUE 8): the memo is
    content-addressed by the straggler multiset + job config rather
    than the live cluster's ``(epoch, speed_version)`` counters — those
    only gate each instance's private scan — so variants hitting the
    same degradation state reuse each other's folds.  A spec other than
    the fleet's gets private caches (no sharing).
    """

    def __init__(self, cluster_spec: ClusterSpec):
        self.spec = cluster_spec
        self._pcaches: Dict[bool, object] = {}
        self._alpha_clean: Dict[int, Tuple[float, float]] = {}
        self._alpha_class: Dict[Tuple[int, int], float] = {}
        self._alpha_deg: Dict[tuple, Tuple[float, float]] = {}

    def placement_cache(self, cluster_spec: ClusterSpec, refine=False):
        from .heavy_edge import PlacementCache

        if cluster_spec != self.spec:
            return PlacementCache(cluster_spec, refine=refine)
        key = bool(refine)
        pc = self._pcaches.get(key)
        if pc is None:
            pc = self._pcaches[key] = PlacementCache(
                cluster_spec, refine=refine
            )
        return pc

    def alpha_cache(self, cluster_spec: ClusterSpec) -> AlphaCache:
        ac = AlphaCache(cluster_spec)
        if cluster_spec == self.spec:
            ac._cache = self._alpha_clean
            ac._class_amax = self._alpha_class
            ac._deg_cache = self._alpha_deg
        return ac


class _ScoutShared:
    """Provider for the prewarm scout: shared alpha pool (warms it for
    the fleet), throwaway greedy placement cache with a miss log."""

    def __init__(self, shared: FleetShared, log: list):
        self._shared = shared
        self._log = log

    def alpha_cache(self, cluster_spec):
        return self._shared.alpha_cache(cluster_spec)

    def placement_cache(self, cluster_spec, refine=False):
        from .heavy_edge import PlacementCache

        return PlacementCache(cluster_spec, refine=refine,
                              key_log=self._log)


def fleet_variants(
    base: Scenario,
    perturbations: Sequence[Perturbation],
    n_variants: int,
    seed: int = 0,
) -> Iterator[Tuple[int, Scenario]]:
    """Yield ``(index, variant)`` pairs; variant i is drawn from its own
    ``default_rng([seed, i])``, so any subset replays identically."""
    for i in range(n_variants):
        rng = np.random.default_rng([seed, i])
        yield i, perturb_scenario(
            base, perturbations, rng,
            name=f"{base.name or 'fleet'}#v{i}",
        )


@dataclass(frozen=True)
class VariantResult:
    """One variant's schedule summary (digest = ``schedule_digest()``)."""

    index: int
    name: str
    digest: str
    total_flow_time: float
    makespan: float
    mean_jct: float
    n_migrations: int
    n_events: int
    wall_s: float


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Linear interpolation between closest ranks (numpy's default), on
    plain floats so the digest is numpy-version independent."""
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    pos = (n - 1) * (q / 100.0)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return sorted_vals[lo]
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


def _dist(vals: List[float]) -> Dict[str, float]:
    s = sorted(vals)
    return {
        "mean": math.fsum(s) / len(s),
        "p50": _percentile(s, 50.0),
        "p95": _percentile(s, 95.0),
        "min": s[0],
        "max": s[-1],
    }


@dataclass(frozen=True)
class FleetResult:
    """Distribution stats + per-variant rows for one fleet run."""

    variants: Tuple[VariantResult, ...]
    seed: int
    stats: Dict[str, Dict[str, float]]
    prewarm: Dict[str, float]
    wall_s: float

    def digest(self) -> str:
        """Bit-identity fingerprint of the whole fleet: per-variant
        schedule digests and exact metric floats, in variant order."""
        h = hashlib.sha256()
        for v in self.variants:
            h.update(
                f"{v.index}:{v.digest}:{v.total_flow_time!r}:"
                f"{v.makespan!r}:{v.n_migrations}\n".encode()
            )
        return h.hexdigest()

    def to_dict(self) -> dict:
        return {
            "schema": 1,
            "bench": "sched_scale_fleet",
            "n_variants": len(self.variants),
            "seed": self.seed,
            "stats": self.stats,
            "digests": [v.digest for v in self.variants],
            "fleet_digest": self.digest(),
            "prewarm": self.prewarm,
            "wall_s": self.wall_s,
        }


def run_fleet(
    base: Scenario,
    policy_factory: Callable[[], Policy],
    perturbations: Sequence[Perturbation],
    n_variants: int,
    seed: int = 0,
    share: bool = True,
    prewarm: bool = True,
    validate: bool = False,
    progress: Optional[Callable[[int, VariantResult], None]] = None,
) -> FleetResult:
    """Run ``n_variants`` seeded perturbations of ``base`` and fold the
    results into a :class:`FleetResult`.

    ``share=False, prewarm=False`` is the sequential control arm: fresh
    policy *and* fresh caches per variant, exactly N independent
    ``simulate()`` calls (what the ``--fleet-ab`` benchmark compares
    against).  Schedules are identical either way — sharing only moves
    cache warmup, never results.

    ``policy_factory`` must return a fresh policy per call (per-run
    queue/predictor state is never shared; only caches are).
    """
    base = base.materialize()
    # DET003-allowlisted ([tool.detlint] run_fleet): every perf_counter
    # in this function (fleet total, prewarm, per-variant) feeds a
    # wall_s field on FleetResult/VariantResult/prewarm_stats — timing
    # telemetry for the --fleet-ab speedup table.  Variant schedules and
    # digests are produced by simulate() before the subtraction, so
    # wall-clock jitter can never reach them.
    t_fleet = time.perf_counter()
    shared = FleetShared(base.cluster) if share else None
    prewarm_stats: Dict[str, float] = {}
    if share and prewarm:
        probe = policy_factory()
        if getattr(probe, "refine_mapping", False) and getattr(
            probe, "placement_cache", True
        ):
            # Scout: the same policy config with refine off explores
            # nearly the same (config, shape) working set at a fraction
            # of the cost; its misses become the warm work-list.  Warmed
            # entries are pure functions of their key, so a mispredicted
            # key is wasted work, never a wrong schedule.
            t0 = time.perf_counter()
            probe.refine_mapping = False
            log: list = []
            probe.fleet_shared = _ScoutShared(shared, log)
            simulate(base, probe, validate=False)
            warmed, groups = shared.placement_cache(
                base.cluster, refine=True
            ).warm(log)
            prewarm_stats = {
                "keys": float(len(log)),
                "warmed": float(warmed),
                "refine_batches": float(groups),
                "wall_s": time.perf_counter() - t0,
            }
    rows: List[VariantResult] = []
    for i, variant in fleet_variants(base, perturbations, n_variants, seed):
        pol = policy_factory()
        if shared is not None:
            pol.fleet_shared = shared
        # Policy-level perturbations (e.g. PredictionNoisePerturbation)
        # draw from their own substream — [seed, i, 1], disjoint from the
        # event sampler's [seed, i] — so adding one never shifts the
        # event draws (or digests) of event/job perturbations.
        prng = np.random.default_rng([seed, i, 1])
        for p in perturbations:
            p.perturb_policy(pol, base, prng)
        t0 = time.perf_counter()
        res = simulate(variant, pol, validate=validate)
        row = VariantResult(
            index=i,
            name=variant.name,
            digest=res.schedule_digest(),
            total_flow_time=res.total_flow_time,
            makespan=res.makespan,
            mean_jct=res.mean_jct,
            n_migrations=res.n_migrations,
            n_events=res.n_events,
            wall_s=time.perf_counter() - t0,
        )
        rows.append(row)
        if progress is not None:
            progress(i, row)
    stats = {
        "total_flow_time": _dist([r.total_flow_time for r in rows]),
        "makespan": _dist([r.makespan for r in rows]),
        "mean_jct": _dist([r.mean_jct for r in rows]),
        "n_migrations": _dist([float(r.n_migrations) for r in rows]),
    }
    return FleetResult(
        variants=tuple(rows),
        seed=seed,
        stats=stats,
        prewarm=prewarm_stats,
        wall_s=time.perf_counter() - t_fleet,
    )
