"""First-class scenarios: one composable, serializable cluster-event stream.

The paper's A-SRPT is an *online* algorithm — its value is reacting to an
arbitrary event stream.  Before this module the simulator grew one ad-hoc
keyword per scenario kind (``faults=`` in PR 2, ``degradations=`` in
PR 4); every new scenario (elastic capacity, maintenance drains, serving
bursts) would have added another.  A :class:`Scenario` instead bundles

* the workload (``jobs`` — a time-ordered tuple of :class:`JobSpec`, or
  a lazy :class:`JobStream` for bounded-memory million-job replays),
* the cluster it runs on (a :class:`ClusterSpec`), and
* a single time-ordered timeline of typed :class:`ClusterEvent` s,

so adding a scenario kind means adding an *event type*, not a simulator
parameter.  ``simulate(scenario, policy)`` is the one entry point
(simulator.py); the legacy ``simulate(jobs, spec, faults=...,
degradations=...)`` signature survives as a thin shim that builds a
``Scenario`` and is property-tested bit-identical (tests/test_scenario.py).

Event types
-----------

``Fault(t, server)``
    Full failure: free capacity vanishes at ``t``; GPUs held by running
    jobs are forfeited as those jobs release; running jobs finish in
    place (the PR-2 path).  Identical to ``Degradation(t, server, 0.0)``.

``Degradation(t, server, factor)``
    Straggler event: the server's effective compute/NIC speed is scaled
    by ``factor`` (PR 4).  ``factor`` in (0, 1) slows, 1.0 recovers,
    > 1.0 boosts, exactly 0.0 is a ``Fault``.

``ServerLeave(t, server, drain_timeout)``
    Elastic capacity: the server begins leaving at ``t``.  No new
    allocations from ``t`` on; capacity is forfeited as running jobs
    release.  ``drain_timeout`` is the graceful-drain window: while it
    is open, jobs still running on the server are offered to
    ``Policy.plan_migrations`` (checkpoint-restart off the leaving
    server); at ``t + drain_timeout`` the server is gone for good
    (remaining jobs finish in place, PR-2 style).  ``drain_timeout=0``
    degrades to the ``Fault`` path verbatim (property-tested);
    ``float("inf")`` keeps the drain window open forever.

``ServerJoin(t, server)``
    Elastic capacity: server ``server`` (a spec slot that previously
    left, failed, or never came up) comes online at ``t`` with its
    class capacity.  The epoch bump wakes settled policies so queued
    work starts immediately.  A server absent *from the start* is
    expressed as ``ServerLeave(0.0, m)`` — the spec stays the full
    universe of slots.

Canonical event order (the tie-break bugfix)
--------------------------------------------

Same-timestamp events used to apply in input-sequence order (faults
before degradations, each list in caller order) — an accident of the
legacy keywords.  ``Scenario`` instead stores its timeline canonically
sorted by ``(t, server, kind, magnitude)`` with kind ranked

    ServerJoin < Degradation < ServerLeave < Fault

so at one instant, per server: capacity arrives first, speed changes
apply next, and removals win the instant (a fault overrides a
same-instant degradation).  Ties within a kind order by magnitude
(``factor`` / ``drain_timeout``) ascending.  The order is deterministic
for any input permutation — schedules no longer depend on how the
caller happened to interleave event lists (tests/test_scenario.py pins
this).

JSON schema (versions 1 and 2)
------------------------------

``Scenario.to_json()`` / ``Scenario.from_json()`` round-trip the whole
scenario; ``Scenario.from_json(s.to_json()) == s`` and a round-tripped
scenario replays a byte-identical schedule (property-tested).  Version 2
(ISSUE 9) adds one optional section, ``"request_streams"`` — serving
workloads (see :class:`RequestStream`), each tagged ``"kind":
"request-stream"`` with the same strict unknown-field/unknown-kind
deserialization as events.  A scenario without request streams still
serializes as version 1 with no ``"request_streams"`` key, so every
pre-serving document — the golden fixtures included — round-trips byte
for byte; ``from_dict`` reads both versions and rejects
``request_streams`` under a version-1 declaration.  Layout::

    {
      "schema": 1,
      "name": "<free-form label>",
      "cluster": {
        "num_servers": 8, "gpus_per_server": 4,
        "b_inter": 1.25e9, "b_intra": 3e11,
        // heterogeneous specs instead carry the class list:
        "server_classes": [
          {"count": 3, "gpus_per_server": 8, "b_inter": 1.25e10,
           "b_intra": 0.0, "name": "gen-a"}, ...
        ]
      },
      "jobs": [ <job>, ... ],      // time-ordered
      "events": [ <event>, ... ]   // canonical order (see above)
    }

A ``<job>`` is the frozen-trace format ``tests/golden/trace.json``
already uses (that file is a documented instance of the ``jobs`` array)::

    {"job_id": 0, "n_iters": 37, "arrival": 12.5, "group_id": 3,
     "user_id": 7, "allreduce": "rar", "model_name": "qwen3_32b",
     "stages": [[p_f, p_b, d_in, d_out, h, k], ...]}

An ``<event>`` carries its kind tag plus the per-kind fields::

    {"kind": "fault", "t": 600.0, "server": 0}
    {"kind": "degradation", "t": 400.0, "server": 1, "factor": 0.25}
    {"kind": "leave", "t": 900.0, "server": 2, "drain_timeout": 120.0}
    {"kind": "join",  "t": 1200.0, "server": 2}

``drain_timeout`` serializes ``float("inf")`` as JSON ``null`` (strict
JSON has no Infinity).  Unknown kinds or fields fail ``from_dict`` with
a ``ValueError`` naming the offender — the schema is versioned via the
top-level ``"schema"`` integer, bumped on incompatible change.

CLI: ``python -m repro.core.scenario validate FILE`` checks a scenario
file against the schema; ``validate-jobs FILE`` checks a bare jobs
array (e.g. ``tests/golden/trace.json``).  CI runs both plus an
end-to-end replay via ``benchmarks/sched_scale.py --scenario``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass
from typing import (
    Callable,
    Dict,
    Iterable,
    Iterator,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Type,
    Union,
)

from .job import ClusterSpec, JobSpec, ServerClass, StageSpec
from ..serve.latency import DEFAULT_SERVE_MODEL

# Version 2 added the optional "request_streams" section (serving
# workloads, ISSUE 9).  ``to_dict`` still emits version 1 for scenarios
# without request streams — version-1 documents (all golden fixtures,
# every pre-serving scenario file) round-trip byte-identical — and
# ``from_dict`` reads both.
SCENARIO_SCHEMA_VERSION = 2
_READABLE_SCHEMAS = (1, 2)

# Frozen-trace job layout (tests/golden/trace.json is an instance).
_STAGE_FIELDS = ("p_f", "p_b", "d_in", "d_out", "h", "k")
_JOB_FIELDS = (
    "job_id", "n_iters", "arrival", "group_id", "user_id", "allreduce",
    "model_name",
)
_CLASS_FIELDS = ("count", "gpus_per_server", "b_inter", "b_intra", "name")


# ---------------------------------------------------------------------------
# Typed cluster events
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterEvent:
    """One timed change to the cluster, applied just before the scheduling
    pass at ``t`` (all same-timestamp events drain first — simulator.py)."""

    t: float
    server: int

    def __post_init__(self) -> None:
        # `not (x >= 0)` rejects NaN as well as negatives: json.load
        # happily parses NaN/Infinity, and a NaN time would silently
        # corrupt the simulator's event-heap ordering
        if not (self.t >= 0.0 and math.isfinite(self.t)):
            raise ValueError(f"event time must be finite >= 0, got {self.t}")
        if self.server < 0:
            raise ValueError(f"server id must be >= 0, got {self.server}")


@dataclass(frozen=True)
class Fault(ClusterEvent):
    """Full server failure (== ``Degradation(factor=0.0)``); PR-2 path."""


@dataclass(frozen=True)
class Degradation(ClusterEvent):
    """Speed change: effective compute/NIC scale by ``factor`` (PR 4)."""

    factor: float = 1.0

    def __post_init__(self) -> None:
        super().__post_init__()
        if not (self.factor >= 0.0 and math.isfinite(self.factor)):
            raise ValueError(
                f"speed factor must be finite >= 0, got {self.factor}"
            )


@dataclass(frozen=True)
class ServerJoin(ClusterEvent):
    """Elastic capacity: the server slot comes online with class caps."""


@dataclass(frozen=True)
class ServerLeave(ClusterEvent):
    """Elastic capacity: graceful drain; ``drain_timeout=0`` == ``Fault``."""

    drain_timeout: float = 0.0

    def __post_init__(self) -> None:
        super().__post_init__()
        # inf is legal (open-ended window); NaN and negatives are not
        if not self.drain_timeout >= 0.0:
            raise ValueError(
                f"drain_timeout must be >= 0, got {self.drain_timeout}"
            )


# Canonical same-timestamp order (see module docstring): joins first,
# then speed changes, removals win the instant.
_KIND_RANK: Dict[type, int] = {
    ServerJoin: 0,
    Degradation: 1,
    ServerLeave: 2,
    Fault: 3,
}
_KIND_TAG: Dict[type, str] = {
    Fault: "fault",
    Degradation: "degradation",
    ServerJoin: "join",
    ServerLeave: "leave",
}
_TAG_KIND: Dict[str, type] = {v: k for k, v in _KIND_TAG.items()}


def event_sort_key(ev: ClusterEvent) -> Tuple[float, int, int, float]:
    """Total order over events: ``(t, server, kind rank, magnitude)``.

    Custom :class:`ClusterEvent` subclasses (policy-defined events that
    reach ``Policy.on_event`` without engine-side state changes) rank
    after the built-ins at one ``(t, server)``.
    """
    kind = type(ev)
    if kind is Degradation:
        mag = ev.factor
    elif kind is ServerLeave:
        mag = ev.drain_timeout
    else:
        mag = 0.0
    return (ev.t, ev.server, _KIND_RANK.get(kind, len(_KIND_RANK)), mag)


def event_to_dict(ev: ClusterEvent) -> dict:
    kind = type(ev)
    tag = _KIND_TAG.get(kind)
    if tag is None:
        raise ValueError(
            f"only built-in event kinds serialize (schema "
            f"{SCENARIO_SCHEMA_VERSION}); {kind.__name__} is "
            f"policy-defined — keep such scenarios in-process"
        )
    d: dict = {"kind": tag, "t": ev.t, "server": ev.server}
    if kind is Degradation:
        d["factor"] = ev.factor
    elif kind is ServerLeave:
        # strict JSON has no Infinity: an open-ended drain window is null
        d["drain_timeout"] = (
            None if ev.drain_timeout == float("inf") else ev.drain_timeout
        )
    return d


# Per-kind fields beyond the common (kind, t, server) — from_dict rejects
# anything else, so a typo'd field (e.g. "drain_timout") fails loudly
# instead of silently taking the default.
_KIND_EXTRA_FIELDS: Dict[str, frozenset] = {
    "fault": frozenset(),
    "degradation": frozenset({"factor"}),
    "join": frozenset(),
    "leave": frozenset({"drain_timeout"}),
}


def event_from_dict(d: Mapping) -> ClusterEvent:
    try:
        tag = d["kind"]
    except KeyError:
        raise ValueError(f"event missing 'kind': {d!r}") from None
    kind: Optional[Type[ClusterEvent]] = _TAG_KIND.get(tag)
    if kind is None:
        raise ValueError(
            f"unknown event kind {tag!r} (schema {SCENARIO_SCHEMA_VERSION} "
            f"knows {sorted(_TAG_KIND)})"
        )
    unknown = set(d) - {"kind", "t", "server"} - _KIND_EXTRA_FIELDS[tag]
    if unknown:
        raise ValueError(
            f"event {tag!r} has unknown field(s) {sorted(unknown)}: {d!r}"
        )
    try:
        t, server = float(d["t"]), int(d["server"])
    except KeyError as exc:
        raise ValueError(f"event {tag!r} missing field {exc}") from None
    if kind is Degradation:
        try:
            return Degradation(t, server, factor=float(d["factor"]))
        except KeyError:
            raise ValueError(
                f"degradation event missing 'factor': {d!r}"
            ) from None
    if kind is ServerLeave:
        timeout = d.get("drain_timeout", 0.0)
        return ServerLeave(
            t, server,
            drain_timeout=float("inf") if timeout is None else float(timeout),
        )
    return kind(t, server)


# ---------------------------------------------------------------------------
# Request streams (schema v2): recurring serving workloads on the timeline
# ---------------------------------------------------------------------------


REQUEST_STREAM_KIND = "request-stream"

# Required fields have no safe default (a stream without a rate or an SLO
# is meaningless); the rest default like the dataclass so hand-written
# scenario files stay terse.  ``to_dict`` always writes every field —
# the serving defaults (the calibrated latency curve) may be refreshed,
# and a committed scenario must replay identically across refreshes.
_STREAM_REQUIRED = ("stream_id", "rate", "duration", "slo")
_STREAM_OPTIONAL = (
    "start", "diurnal_amplitude", "diurnal_period", "phase", "gpus",
    "max_replicas", "max_batch", "svc_base", "svc_per_req", "seed",
)
_STREAM_FIELDS = _STREAM_REQUIRED + _STREAM_OPTIONAL
_ARRIVAL_CHUNK = 4096  # rng draws per block (amortizes Generator overhead)


@dataclass(frozen=True)
class RequestStream:
    """A recurring serving workload: Poisson request arrivals (optionally
    diurnally modulated) with a per-request SLO deadline, co-scheduled
    with training jobs on the same cluster.

    Arrivals are a nonhomogeneous Poisson process at instantaneous rate
    ``rate_at(t) = rate * (1 + diurnal_amplitude * sin(2*pi*(t - start)
    / diurnal_period + phase))`` over ``[start, start + duration)`` —
    ``diurnal_amplitude = 0`` (the default) is plain Poisson at
    ``rate`` req/s.  :meth:`arrivals` generates the timestamps lazily
    (thinning against the peak rate, chunked rng draws), so
    million-request streams never materialize; the draw is a pure
    function of ``(seed, stream_id)``.

    Requests are served by *replicas* — ``gpus`` GPUs on one server,
    allocated out of the same :class:`~repro.core.cluster.ClusterState`
    training jobs use — which batch up to ``max_batch`` queued requests
    and take ``service_time(b) = svc_base + svc_per_req * b`` seconds
    per batch.  The service defaults come from the committed
    engine-calibrated curve
    (:data:`repro.serve.latency.DEFAULT_SERVE_MODEL`); a request meets
    its SLO when completion - arrival <= ``slo``.  The simulator scales
    replicas up to ``max_replicas`` (preempting comm-heavy training
    jobs via ``Policy.plan_preemptions`` when the cluster is full) and
    releases idle ones back to training — see simulator.py.
    """

    stream_id: int
    rate: float
    duration: float
    slo: float
    start: float = 0.0
    diurnal_amplitude: float = 0.0
    diurnal_period: float = 86_400.0
    phase: float = 0.0
    gpus: int = 1
    max_replicas: int = 1
    max_batch: int = 8
    svc_base: float = DEFAULT_SERVE_MODEL.batch_base
    svc_per_req: float = DEFAULT_SERVE_MODEL.batch_per_req
    seed: int = 0

    def __post_init__(self) -> None:
        if self.stream_id < 0:
            raise ValueError(f"stream_id must be >= 0, got {self.stream_id}")
        if not (self.rate > 0.0 and math.isfinite(self.rate)):
            raise ValueError(f"rate must be finite > 0, got {self.rate}")
        if not (self.duration > 0.0 and math.isfinite(self.duration)):
            raise ValueError(
                f"duration must be finite > 0, got {self.duration}"
            )
        if not (self.slo > 0.0 and math.isfinite(self.slo)):
            raise ValueError(f"slo must be finite > 0, got {self.slo}")
        if not (self.start >= 0.0 and math.isfinite(self.start)):
            raise ValueError(
                f"start must be finite >= 0, got {self.start}"
            )
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            # amplitude 1 would zero the instantaneous rate (and < 0
            # flips the phase); keep the modulation strictly positive
            raise ValueError(
                f"diurnal_amplitude must be in [0, 1), got "
                f"{self.diurnal_amplitude}"
            )
        if not (self.diurnal_period > 0.0 and math.isfinite(self.diurnal_period)):
            raise ValueError(
                f"diurnal_period must be finite > 0, got "
                f"{self.diurnal_period}"
            )
        if not math.isfinite(self.phase):
            raise ValueError(f"phase must be finite, got {self.phase}")
        if self.gpus < 1:
            raise ValueError(f"gpus must be >= 1, got {self.gpus}")
        if self.max_replicas < 1:
            raise ValueError(
                f"max_replicas must be >= 1, got {self.max_replicas}"
            )
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if not (self.svc_base >= 0.0 and math.isfinite(self.svc_base)):
            raise ValueError(
                f"svc_base must be finite >= 0, got {self.svc_base}"
            )
        if not (self.svc_per_req > 0.0 and math.isfinite(self.svc_per_req)):
            raise ValueError(
                f"svc_per_req must be finite > 0, got {self.svc_per_req}"
            )

    @property
    def end(self) -> float:
        return self.start + self.duration

    def rate_at(self, t: float) -> float:
        """Instantaneous arrival rate (req/s) at time ``t``."""
        if self.diurnal_amplitude == 0.0:
            return self.rate
        return self.rate * (
            1.0
            + self.diurnal_amplitude
            * math.sin(
                2.0 * math.pi * (t - self.start) / self.diurnal_period
                + self.phase
            )
        )

    def service_time(self, batch: int) -> float:
        """Seconds one replica takes to serve a batch of ``batch``."""
        return self.svc_base + self.svc_per_req * batch

    def arrivals(self) -> Iterator[float]:
        """Lazy time-ordered arrival timestamps (thinning sampler).

        Candidate gaps are exponential at the peak rate
        ``rate * (1 + amplitude)``; a candidate at ``t`` is kept when
        ``u * peak <= rate_at(t)`` — the standard nonhomogeneous-Poisson
        thinning, exact for the sinusoidal profile.  The acceptance
        uniform is drawn for every candidate (amplitude 0 accepts all),
        so enabling modulation never shifts the underlying draw
        sequence.  Replayable: each call re-seeds from
        ``(seed, stream_id)``.
        """
        import numpy as np

        rng = np.random.default_rng([self.seed, self.stream_id])
        peak = self.rate * (1.0 + self.diurnal_amplitude)
        t = self.start
        end = self.end
        while True:
            gaps = rng.exponential(1.0 / peak, _ARRIVAL_CHUNK)
            us = rng.random(_ARRIVAL_CHUNK)
            for gap, u in zip(gaps, us):
                t += gap
                if t >= end:
                    return
                if u * peak <= self.rate_at(t):
                    yield t


def request_stream_to_dict(rs: RequestStream) -> dict:
    d: dict = {"kind": REQUEST_STREAM_KIND}
    d.update({f: getattr(rs, f) for f in _STREAM_FIELDS})
    return d


def request_stream_from_dict(d: Mapping) -> RequestStream:
    tag = d.get("kind")
    if tag != REQUEST_STREAM_KIND:
        raise ValueError(
            f"unknown request-stream kind {tag!r} (schema "
            f"{SCENARIO_SCHEMA_VERSION} knows [{REQUEST_STREAM_KIND!r}])"
        )
    unknown = set(d) - {"kind"} - set(_STREAM_FIELDS)
    if unknown:
        raise ValueError(
            f"request stream has unknown field(s) {sorted(unknown)}: {d!r}"
        )
    missing = [f for f in _STREAM_REQUIRED if f not in d]
    if missing:
        raise ValueError(
            f"request stream missing required field(s) {missing}: {d!r}"
        )
    kwargs = {
        "stream_id": int(d["stream_id"]),
        "rate": float(d["rate"]),
        "duration": float(d["duration"]),
        "slo": float(d["slo"]),
    }
    for f in _STREAM_OPTIONAL:
        if f in d:
            kwargs[f] = (
                int(d[f])
                if f in ("gpus", "max_replicas", "max_batch", "seed")
                else float(d[f])
            )
    return RequestStream(**kwargs)


# ---------------------------------------------------------------------------
# Job + cluster (de)serialization — the frozen-trace format, now documented
# ---------------------------------------------------------------------------


def job_to_dict(job: JobSpec) -> dict:
    d = {f: getattr(job, f) for f in _JOB_FIELDS}
    d["stages"] = [
        [getattr(st, f) for f in _STAGE_FIELDS] for st in job.stages
    ]
    return d


def job_from_dict(d: Mapping) -> JobSpec:
    unknown = set(d) - set(_JOB_FIELDS) - {"stages"}
    if unknown:
        raise ValueError(
            f"job record has unknown field(s) {sorted(unknown)}"
        )
    try:
        stages = tuple(
            StageSpec(**dict(zip(_STAGE_FIELDS, s))) for s in d["stages"]
        )
        return JobSpec(stages=stages, **{f: d[f] for f in _JOB_FIELDS})
    except KeyError as exc:
        raise ValueError(f"job record missing field {exc}") from None


def jobs_to_dicts(jobs: Sequence[JobSpec]) -> List[dict]:
    return [job_to_dict(job) for job in jobs]


def jobs_from_dicts(data: Sequence[Mapping]) -> List[JobSpec]:
    return [job_from_dict(d) for d in data]


# ---------------------------------------------------------------------------
# Streaming jobs sources (bounded-memory million-job scenarios)
# ---------------------------------------------------------------------------


class JobStream:
    """Lazy jobs source for ``Scenario.jobs`` — O(1) resident memory.

    A stream is an iterable yielding :class:`JobSpec` s in nondecreasing
    ``arrival`` order; the simulator pulls arrivals incrementally (each
    job is validated as it is pulled, and an out-of-order yield fails
    loudly — simulator.py).  A ``Scenario`` whose ``jobs`` is a
    ``JobStream`` never materializes the workload: the stream is held
    as-is (not tupled) and ``simulate`` defaults to the streaming
    result backend for it.  Streaming scenarios do not serialize —
    ``to_dict`` refuses; use :meth:`Scenario.materialize` first.

    Subclasses implement ``__iter__``.  Whether iteration is replayable
    is per-subclass: :class:`JsonlJobs` always is (it re-opens its
    shards); :class:`IterJobs` is replayable only in factory form.
    """

    def __iter__(self) -> Iterator[JobSpec]:
        raise NotImplementedError

    def __len__(self) -> int:
        raise TypeError(
            f"{type(self).__name__} is a lazy jobs source: its length is "
            f"unknown without consuming it (materialize the scenario for "
            f"a tuple-backed workload)"
        )


class IterJobs(JobStream):
    """Wrap an iterator — or, for a replayable stream, a zero-argument
    factory returning a fresh iterator — of time-ordered ``JobSpec`` s.

    A bare iterator/generator is single-shot: iterating a second time
    raises (the first pass consumed it), which matters for equivalence
    tests that replay a stream — pass a factory callable there.
    """

    def __init__(
        self,
        source: Union[Callable[[], Iterable[JobSpec]], Iterable[JobSpec]],
        name: str = "",
    ):
        self.name = name
        if callable(source):
            self._factory: Optional[Callable[[], Iterable[JobSpec]]] = source
            self._iter: Optional[Iterator[JobSpec]] = None
        else:
            self._factory = None
            self._iter = iter(source)

    def __iter__(self) -> Iterator[JobSpec]:
        if self._factory is not None:
            return iter(self._factory())
        it, self._iter = self._iter, None
        if it is None:
            raise RuntimeError(
                "single-shot IterJobs already consumed; construct it from "
                "a factory callable for a replayable stream"
            )
        return it


class JsonlJobs(JobStream):
    """JSONL-shard jobs source: one schema-v1 ``<job>`` record per line.

    Shards are read lazily, in the order given; the concatenation must
    be arrival-ordered (enforced at simulation time).  Blank lines are
    skipped; a malformed line fails loudly with its ``path:lineno``.
    Replayable: every iteration re-opens the shards.
    """

    def __init__(
        self,
        paths: Union[str, os.PathLike, Sequence[Union[str, os.PathLike]]],
        name: str = "",
    ):
        if isinstance(paths, (str, os.PathLike)):
            paths = (paths,)
        self.paths: Tuple[str, ...] = tuple(os.fspath(p) for p in paths)
        if not self.paths:
            raise ValueError("JsonlJobs needs at least one shard path")
        self.name = name

    def __iter__(self) -> Iterator[JobSpec]:
        for path in self.paths:
            with open(path) as fh:
                for lineno, line in enumerate(fh, 1):
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        d = json.loads(line)
                    except json.JSONDecodeError as exc:
                        raise ValueError(
                            f"{path}:{lineno}: malformed JSONL job record: "
                            f"{exc}"
                        ) from None
                    try:
                        yield job_from_dict(d)
                    except ValueError as exc:
                        raise ValueError(
                            f"{path}:{lineno}: {exc}"
                        ) from None


def jobs_to_jsonl(jobs: Iterable[JobSpec], path) -> int:
    """Write jobs as a JSONL shard (one schema-v1 record per line, the
    :class:`JsonlJobs` input format); streams — never holds more than
    one job resident.  Returns the number of jobs written."""
    n = 0
    with open(path, "w") as fh:
        for job in jobs:
            fh.write(json.dumps(job_to_dict(job), allow_nan=False))
            fh.write("\n")
            n += 1
    return n


def cluster_to_dict(spec: ClusterSpec) -> dict:
    if spec.is_heterogeneous:
        return {
            "b_intra": spec.b_intra,
            "server_classes": [
                {f: getattr(c, f) for f in _CLASS_FIELDS}
                for c in spec.server_classes
            ],
        }
    return {
        "num_servers": spec.num_servers,
        "gpus_per_server": spec.gpus_per_server,
        "b_inter": spec.b_inter,
        "b_intra": spec.b_intra,
    }


def cluster_from_dict(d: Mapping) -> ClusterSpec:
    unknown = set(d) - {
        "num_servers", "gpus_per_server", "b_inter", "b_intra",
        "server_classes",
    }
    if unknown:
        raise ValueError(
            f"cluster spec has unknown field(s) {sorted(unknown)}"
        )
    try:
        if d.get("server_classes"):
            classes = []
            for c in d["server_classes"]:
                bad = set(c) - set(_CLASS_FIELDS)
                if bad:
                    raise ValueError(
                        f"server class has unknown field(s) {sorted(bad)}"
                    )
                classes.append(
                    ServerClass(**{f: c[f] for f in _CLASS_FIELDS if f in c})
                )
            return ClusterSpec.heterogeneous(classes, b_intra=d["b_intra"])
        return ClusterSpec(
            num_servers=d["num_servers"],
            gpus_per_server=d["gpus_per_server"],
            b_inter=d["b_inter"],
            b_intra=d["b_intra"],
        )
    except KeyError as exc:
        raise ValueError(f"cluster spec missing field {exc}") from None


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """Workload + cluster + one canonical timeline of typed events.

    ``events`` is re-sorted into the canonical ``(t, server, kind,
    magnitude)`` order on construction, so two scenarios built from any
    permutation of the same events compare (and replay) equal.  Event
    server ids are validated against the spec here — failing at
    construction beats failing mid-simulation.

    ``jobs`` is either a time-ordered tuple of :class:`JobSpec` (any
    sequence is tupled on construction) or a :class:`JobStream` — a
    lazy source held as-is, so a scenario no longer implies O(jobs)
    resident memory; per-job validation then happens as the simulator
    pulls arrivals.  Stream-backed scenarios do not serialize (see
    :meth:`to_dict` / :meth:`materialize`).

    ``request_streams`` (schema v2, ISSUE 9) holds the serving
    workloads co-scheduled with the jobs — stored sorted by
    ``stream_id`` (ids must be unique), each validated against the
    cluster (a replica must fit on one server).
    """

    jobs: Union[Tuple[JobSpec, ...], JobStream]
    cluster: ClusterSpec
    events: Tuple[ClusterEvent, ...] = ()
    name: str = ""
    request_streams: Tuple[RequestStream, ...] = ()

    def __post_init__(self) -> None:
        if not isinstance(self.jobs, JobStream):
            object.__setattr__(self, "jobs", tuple(self.jobs))
        events = tuple(sorted(self.events, key=event_sort_key))
        object.__setattr__(self, "events", events)
        n = self.cluster.num_servers
        for ev in events:
            if ev.server >= n:
                raise ValueError(
                    f"{type(ev).__name__} targets server {ev.server}, "
                    f"cluster has {n}"
                )
        streams = tuple(
            sorted(self.request_streams, key=lambda rs: rs.stream_id)
        )
        object.__setattr__(self, "request_streams", streams)
        seen = set()
        cap = self.cluster.gpus_per_server
        for rs in streams:
            if rs.stream_id in seen:
                raise ValueError(
                    f"duplicate request stream_id {rs.stream_id}"
                )
            seen.add(rs.stream_id)
            if rs.gpus > cap:
                raise ValueError(
                    f"request stream {rs.stream_id} needs {rs.gpus} GPUs "
                    f"per replica on one server; largest server has {cap}"
                )

    def materialize(self) -> "Scenario":
        """Tuple-backed copy: pulls the whole stream into memory (O(jobs);
        the escape hatch back to the serializable, indexable form).  A
        tuple-backed scenario returns itself."""
        if not isinstance(self.jobs, JobStream):
            return self
        return Scenario(
            jobs=tuple(self.jobs), cluster=self.cluster,
            events=self.events, name=self.name,
            request_streams=self.request_streams,
        )

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        if isinstance(self.jobs, JobStream):
            raise TypeError(
                "a stream-backed Scenario does not serialize (its jobs "
                "are not resident); call .materialize() first, or keep "
                "the workload as JSONL shards next to the scenario"
            )
        # request-stream-free scenarios serialize as version 1 with no
        # "request_streams" key: every pre-serving document (the golden
        # fixtures included) round-trips byte-identical
        d = {
            "schema": 2 if self.request_streams else 1,
            "name": self.name,
            "cluster": cluster_to_dict(self.cluster),
            "jobs": jobs_to_dicts(self.jobs),
            "events": [event_to_dict(ev) for ev in self.events],
        }
        if self.request_streams:
            d["request_streams"] = [
                request_stream_to_dict(rs) for rs in self.request_streams
            ]
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "Scenario":
        version = d.get("schema")
        if version not in _READABLE_SCHEMAS:
            raise ValueError(
                f"unsupported scenario schema {version!r} "
                f"(this build reads {_READABLE_SCHEMAS})"
            )
        unknown = set(d) - {
            "schema", "name", "cluster", "jobs", "events", "request_streams",
        }
        if unknown:
            raise ValueError(
                f"scenario has unknown section(s) {sorted(unknown)}"
            )
        if version < 2 and "request_streams" in d:
            raise ValueError(
                "request_streams requires scenario schema 2, document "
                f"declares {version}"
            )
        try:
            cluster = d["cluster"]
            jobs = d["jobs"]
        except KeyError as exc:
            raise ValueError(f"scenario missing section {exc}") from None
        return cls(
            jobs=tuple(jobs_from_dicts(jobs)),
            cluster=cluster_from_dict(cluster),
            events=tuple(
                event_from_dict(ev) for ev in d.get("events", ())
            ),
            name=d.get("name", ""),
            request_streams=tuple(
                request_stream_from_dict(rs)
                for rs in d.get("request_streams", ())
            ),
        )

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))

    def dump(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json(indent=2))
            fh.write("\n")

    @classmethod
    def load(cls, path) -> "Scenario":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


def scenario_from_legacy(
    jobs: Sequence[JobSpec],
    cluster_spec: ClusterSpec,
    faults: Optional[Sequence[Tuple[float, int]]] = None,
    degradations: Optional[Sequence[Tuple[float, int, float]]] = None,
    name: str = "",
) -> Scenario:
    """The legacy ``simulate(jobs, spec, faults=, degradations=)`` shim.

    Fault tuples become :class:`Fault` events, degradation triples become
    :class:`Degradation` events; the canonical ``Scenario`` ordering
    replaces the old input-sequence interleaving (same-(t, server)
    collisions now resolve deterministically — see module docstring).

    A :class:`JobStream` jobs source passes through un-tupled (tupling
    would consume — and defeat — the lazy source), so the legacy
    signature streams exactly like ``simulate(scenario, policy)``.
    """
    events: List[ClusterEvent] = [
        Fault(float(t), int(m)) for t, m in faults or ()
    ]
    events.extend(
        Degradation(float(t), int(m), factor=float(f))
        for t, m, f in degradations or ()
    )
    return Scenario(
        jobs=jobs if isinstance(jobs, JobStream) else tuple(jobs),
        cluster=cluster_spec, events=tuple(events),
        name=name,
    )


# ---------------------------------------------------------------------------
# Perturbation samplers (ISSUE 7): seeded Monte-Carlo variants of a base
# scenario.  Each sampler is a frozen config object; ``sample_events``
# draws a fresh batch of ClusterEvents from a caller-provided
# ``numpy.random.Generator`` (one generator per variant keeps variants
# independent and the whole fleet a pure function of the fleet seed), and
# ``perturb_jobs`` may rewrite the workload (arrival jitter).  Layering
# happens in ``perturb_scenario``; the batched fleet driver lives in
# ``repro.core.fleet``.
# ---------------------------------------------------------------------------


def _scenario_horizon(base: "Scenario") -> float:
    """Time scale the samplers draw windows against: the workload's last
    arrival (the trace horizon for generated traces; 0 for 1-job cases)."""
    if isinstance(base.jobs, JobStream):
        raise TypeError(
            "perturbation sampling needs a materialized workload; call "
            "scenario.materialize() first"
        )
    return max((j.arrival for j in base.jobs), default=0.0)


@dataclass(frozen=True)
class Perturbation:
    """Base sampler: no events, jobs unchanged, policy untouched.
    Subclasses override."""

    def sample_events(
        self, base: "Scenario", rng
    ) -> List[ClusterEvent]:
        return []

    def perturb_jobs(
        self, jobs: Tuple[JobSpec, ...], base: "Scenario", rng
    ) -> Tuple[JobSpec, ...]:
        return jobs

    def perturb_policy(self, policy, base: "Scenario", rng) -> None:
        """Policy-level perturbation hook (ISSUE 8): mutate one variant's
        freshly constructed, not-yet-bound policy — e.g. install a noisy
        prediction model (:class:`PredictionNoisePerturbation`).  The
        fleet driver calls it with a *separate* rng stream from the
        event sampler (``default_rng([seed, i, 1])``), so adding a
        policy perturbation never shifts the event draws — and existing
        fleet digests — of the samplers above.  Default: no-op.
        """


@dataclass(frozen=True)
class StragglerPerturbation(Perturbation):
    """Partial degradation on ``n_stragglers`` distinct servers: each
    slows to a uniform factor in ``[factor_low, factor_high)`` starting
    at a uniform fraction of the horizon inside ``start_window``;
    ``recover`` restores full speed ``duration_frac`` of the horizon
    later (mirrors ``trace.straggler_events``)."""

    n_stragglers: int = 4
    factor_low: float = 0.25
    factor_high: float = 0.75
    start_window: Tuple[float, float] = (0.2, 0.6)
    duration_frac: float = 0.25
    recover: bool = True

    def sample_events(self, base, rng):
        horizon = _scenario_horizon(base)
        n = base.cluster.num_servers
        k = min(self.n_stragglers, n)
        servers = rng.choice(n, size=k, replace=False)
        out: List[ClusterEvent] = []
        for m in servers:
            f = float(rng.uniform(self.factor_low, self.factor_high))
            t0 = float(horizon * rng.uniform(*self.start_window))
            out.append(Degradation(t0, int(m), factor=f))
            if self.recover:
                out.append(
                    Degradation(
                        t0 + horizon * self.duration_frac, int(m),
                        factor=1.0,
                    )
                )
        return out


@dataclass(frozen=True)
class ElasticPerturbation(Perturbation):
    """Elastic capacity: ``n_servers`` distinct servers leave at
    ``leave_frac`` of the horizon (0.0 == absent from the start, the
    ``--elastic`` maintenance-window regime) and rejoin at a uniform
    fraction inside ``join_window``."""

    n_servers: int = 2
    leave_frac: float = 0.0
    join_window: Tuple[float, float] = (0.3, 0.6)
    drain_timeout: float = 0.0

    def __post_init__(self) -> None:
        if self.join_window[0] <= self.leave_frac:
            raise ValueError(
                f"join_window must start after leave_frac="
                f"{self.leave_frac}, got {self.join_window}"
            )

    def sample_events(self, base, rng):
        horizon = _scenario_horizon(base)
        n = base.cluster.num_servers
        k = min(self.n_servers, n)
        servers = rng.choice(n, size=k, replace=False)
        out: List[ClusterEvent] = []
        for m in servers:
            t_join = float(horizon * rng.uniform(*self.join_window))
            out.append(
                ServerLeave(
                    self.leave_frac * horizon, int(m),
                    drain_timeout=self.drain_timeout,
                )
            )
            out.append(ServerJoin(t_join, int(m)))
        return out


@dataclass(frozen=True)
class FaultPerturbation(Perturbation):
    """Permanent full failures on ``n_faults`` distinct servers, each at
    a uniform fraction of the horizon inside ``window``."""

    n_faults: int = 1
    window: Tuple[float, float] = (0.2, 0.8)

    def sample_events(self, base, rng):
        horizon = _scenario_horizon(base)
        n = base.cluster.num_servers
        k = min(self.n_faults, n)
        servers = rng.choice(n, size=k, replace=False)
        return [
            Fault(float(horizon * rng.uniform(*self.window)), int(m))
            for m in servers
        ]


@dataclass(frozen=True)
class ArrivalJitterPerturbation(Perturbation):
    """Gaussian arrival jitter: every arrival shifts by N(0, sigma)
    seconds, clamped at 0 (the simulator stable-sorts unsorted tuples by
    arrival, so no re-sort is needed here)."""

    sigma: float = 60.0

    def perturb_jobs(self, jobs, base, rng):
        if not jobs:
            return jobs
        offs = rng.normal(0.0, self.sigma, size=len(jobs))
        return tuple(
            dataclasses.replace(
                j, arrival=max(0.0, j.arrival + float(dt))
            )
            for j, dt in zip(jobs, offs)
        )


@dataclass(frozen=True)
class PredictionNoisePerturbation(Perturbation):
    """Prediction-error injection as a first-class fleet axis (ISSUE 8):
    installs a seeded :class:`~repro.core.prediction_loop.NoisyModel` on
    each variant's policy via ``Policy.set_predictor``, so the
    Monte-Carlo fleet sweeps misprediction regimes exactly like it
    sweeps stragglers or faults.

    ``mode`` selects the error family (``"lognormal"`` multiplicative
    noise of width ``sigma``; ``"rankflip"`` sign-flipped rank order;
    ``"coldstart"`` a ``cold_frac`` fraction of jobs predicted 0 — the
    paper's unseen-job rule hitting a random subset).  Each variant
    draws one noise seed from the policy rng stream, so per-job noise is
    independent across variants yet the whole fleet stays a pure
    function of the fleet seed.  No cluster events and no job rewrites:
    only the policy's beliefs are perturbed.
    """

    mode: str = "lognormal"
    sigma: float = 0.5
    cold_frac: float = 0.3

    def __post_init__(self) -> None:
        from .prediction_loop import NOISE_MODES  # deferred: import cycle

        if self.mode not in NOISE_MODES:
            raise ValueError(
                f"unknown noise mode {self.mode!r} (one of {NOISE_MODES})"
            )

    def perturb_policy(self, policy, base, rng) -> None:
        from .prediction_loop import NoisyModel  # deferred: import cycle

        policy.set_predictor(
            NoisyModel(
                self.mode,
                sigma=self.sigma,
                cold_frac=self.cold_frac,
                seed=int(rng.integers(0, 2**31 - 1)),
            )
        )


def perturb_scenario(
    base: Scenario,
    perturbations: Sequence[Perturbation],
    rng,
    name: str = "",
) -> Scenario:
    """One seeded variant: base jobs/events plus every sampler's draw.

    Samplers are applied in list order against ``rng`` (a
    ``numpy.random.Generator``), so the variant is a pure function of
    ``(base, perturbations, generator state)``.  Sampled events merge
    with the base event stream under the canonical Scenario ordering.
    """
    if isinstance(base.jobs, JobStream):
        raise TypeError(
            "perturbation sampling needs a materialized workload; call "
            "scenario.materialize() first"
        )
    jobs: Tuple[JobSpec, ...] = base.jobs
    events: List[ClusterEvent] = list(base.events)
    for p in perturbations:
        jobs = p.perturb_jobs(jobs, base, rng)
        events.extend(p.sample_events(base, rng))
    return Scenario(
        jobs=jobs, cluster=base.cluster, events=tuple(events),
        name=name or base.name, request_streams=base.request_streams,
    )


# ---------------------------------------------------------------------------
# CLI: schema validation (wired into CI's scenario-schema step)
# ---------------------------------------------------------------------------


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.scenario",
        description="Validate scenario / frozen-trace JSON files "
                    "against the documented schema.",
    )
    ap.add_argument(
        "command", choices=("validate", "validate-jobs"),
        help="'validate' checks a full scenario file; 'validate-jobs' "
             "checks a bare jobs array (e.g. tests/golden/trace.json)",
    )
    ap.add_argument("path", help="JSON file to check")
    args = ap.parse_args(argv)

    try:
        if args.command == "validate":
            sc = Scenario.load(args.path)
            print(
                f"{args.path}: ok (schema {SCENARIO_SCHEMA_VERSION}, "
                f"name={sc.name!r}, {len(sc.jobs)} jobs, "
                f"{len(sc.events)} events, "
                f"{sc.cluster.num_servers} servers / "
                f"{sc.cluster.total_gpus} GPUs)"
            )
        else:
            with open(args.path) as fh:
                jobs = jobs_from_dicts(json.load(fh))
            if any(
                jobs[i].arrival > jobs[i + 1].arrival
                for i in range(len(jobs) - 1)
            ):
                raise ValueError("jobs array is not arrival-ordered")
            print(
                f"{args.path}: ok ({len(jobs)} jobs, "
                f"max g={max(j.g for j in jobs)})"
            )
    except (ValueError, TypeError, OSError, json.JSONDecodeError) as exc:
        print(f"{args.path}: INVALID — {exc}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
