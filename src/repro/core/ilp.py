"""Exact balanced-graph-cut placement (the paper's Table II ILP baseline).

Gurobi is unavailable offline; the same optimum is found by depth-first
branch-and-bound with an admissible bound (accumulated cut weight only) and
symmetry pruning over equal-capacity parts.  Exact for the small instances
(<= ~16 stage replicas) used in the Heavy-Edge comparison.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from .graph import JobGraph, Vertex


def exact_min_cut(
    graph: JobGraph,
    server_caps: Sequence[Tuple[int, int]],
    node_limit: int = 2_000_000,
) -> Tuple[Dict[Vertex, int], float]:
    """Minimize total cut weight subject to per-server capacities.

    Returns (assignment, cut_weight). Raises if the search exceeds
    ``node_limit`` B&B nodes (instance too large for the exact solver).
    """
    caps = [(m, c) for m, c in server_caps if c > 0]
    if sum(c for _, c in caps) != len(graph.vertices):
        raise ValueError("capacities must sum to the vertex count")

    # Order vertices by incident weight, descending: heavy vertices first
    # tightens the bound early.
    vertices = sorted(
        graph.vertices, key=lambda v: -graph.incident_weight(v)
    )
    n_parts = len(caps)
    cap_left = [c for _, c in caps]
    cap_sizes = [c for _, c in caps]

    best_cost = float("inf")
    best_assign: List[int] = []
    assign: List[int] = [-1] * len(vertices)
    vidx = {v: i for i, v in enumerate(vertices)}
    nodes_visited = 0

    def rec(i: int, cost: float) -> None:
        nonlocal best_cost, best_assign, nodes_visited
        nodes_visited += 1
        if nodes_visited > node_limit:
            raise RuntimeError("exact_min_cut: node limit exceeded")
        if cost >= best_cost:
            return
        if i == len(vertices):
            best_cost = cost
            best_assign = assign.copy()
            return
        v = vertices[i]
        seen_empty_caps = set()
        for p in range(n_parts):
            if cap_left[p] == 0:
                continue
            # Symmetry: among still-empty parts of equal capacity, only try
            # the first one.
            if cap_left[p] == cap_sizes[p]:
                if cap_sizes[p] in seen_empty_caps:
                    continue
                seen_empty_caps.add(cap_sizes[p])
            extra = 0.0
            for nb, w in graph.neighbors(v).items():
                j = vidx[nb]
                if j < i and assign[j] != p:
                    extra += w
            cap_left[p] -= 1
            assign[i] = p
            rec(i + 1, cost + extra)
            assign[i] = -1
            cap_left[p] += 1

    rec(0, 0.0)
    result = {
        vertices[i]: caps[best_assign[i]][0] for i in range(len(vertices))
    }
    return result, best_cost
