"""Bounded-memory streaming quantiles (exact warm-up + uniform reservoir).

The streaming result backend (simulator.SimResult with ``records=None``)
folds each job record away at completion, so tail statistics like p99
flow time cannot be answered by sorting records after the fact.  This
module provides :class:`StreamingQuantile`: fixed-memory (one
``exact_cap``-sized buffer) per tracked quantile, fed one observation at
a time.

Approximation contract (tests/test_quantile.py):

* **Exact below the cap** — the first ``exact_cap`` (default 8192)
  observations are kept in a sorted buffer and ``value()`` answers with
  the same linear-interpolation formula as
  ``SimResult.flow_percentile`` — *bit-identical* to the materialized
  percentile, so runs that fit the buffer lose nothing.
* **Reservoir beyond the cap** — Vitter's Algorithm R keeps a uniform
  sample of everything seen; ``value()`` is the sample percentile.
  Unlike marker estimators (P²), a uniform reservoir stays unbiased on
  *trending* streams — exactly what simulator flow times are under
  queue ramp-up — with only sampling noise: the sample rank of the true
  quantile has std ``sqrt(cap * q(1-q))``, about ±0.16 percentile
  points at p99 with the default cap.  The tested bound is **within
  10 % relative error of the exact percentile** on heavy-tailed
  lognormal data at 50k+ observations (typically ~1 %); gate
  thresholds built on these estimates should leave margin accordingly.

The reservoir's RNG is seeded per estimator, so a fixed event stream
yields a reproducible estimate (the serve benchmark gates depend on
that).
"""
from __future__ import annotations

import bisect
from typing import List

import numpy as np

EXACT_CAP_DEFAULT = 8192
_BLOCK = 4096  # uniforms drawn per RNG call (amortizes Generator overhead)


class StreamingQuantile:
    """One tracked quantile ``q`` (percent, e.g. 99.0) over a stream."""

    __slots__ = ("q", "exact_cap", "n", "_buf", "_sorted", "_rng",
                 "_u", "_ui")

    def __init__(
        self, q: float, exact_cap: int = EXACT_CAP_DEFAULT, seed: int = 0
    ):
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"quantile must be in [0, 100], got {q}")
        if exact_cap < 1:
            raise ValueError("exact_cap must be >= 1")
        self.q = float(q)
        self.exact_cap = exact_cap
        self.n = 0
        self._buf: List[float] = []  # sorted while exact, arbitrary after
        self._sorted = True
        self._rng = np.random.default_rng([seed, int(self.q * 1000)])
        self._u = np.empty(0)
        self._ui = 0

    def _percentile(self, flows: List[float]) -> float:
        """flow_percentile's formula verbatim (bit-identity contract)."""
        if not flows:
            return 0.0
        if len(flows) == 1:
            return flows[0]
        pos = (self.q / 100.0) * (len(flows) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(flows) - 1)
        return flows[lo] + (pos - lo) * (flows[hi] - flows[lo])

    def _uniform(self) -> float:
        if self._ui >= len(self._u):
            self._u = self._rng.random(_BLOCK)
            self._ui = 0
        u = self._u[self._ui]
        self._ui += 1
        return u

    def add(self, x: float) -> None:
        self.n += 1
        if self.n <= self.exact_cap:
            bisect.insort(self._buf, x)
            return
        # Algorithm R: every observation lands in the reservoir with
        # probability cap/n — a uniform sample of the whole stream.
        self._sorted = False
        j = int(self._uniform() * self.n)
        if j < self.exact_cap:
            self._buf[j] = x

    def value(self) -> float:
        """Current estimate: exact while n <= exact_cap, reservoir
        percentile beyond."""
        if self._sorted:
            return self._percentile(self._buf)
        return self._percentile(sorted(self._buf))

    @property
    def exact(self) -> bool:
        """True while the estimate is still the exact percentile."""
        return self.n <= self.exact_cap
