"""Checkpoint-restart migration off degraded capacity.

The paper's online setting is non-preemptive: once placed, a job holds its
GPUs to completion.  Under *partial* degradation (straggler servers — see
cluster.py) that assumption is exactly what drives tail flow-time:
characterization studies of production GPU datacenters (Hu et al., arXiv
2109.01313) attribute most slowdowns to degraded-but-alive capacity, and
contention-aware schedulers (Wang et al.) show that reacting to
effective-bandwidth changes mid-run is where the wins are.

``MigrationMixin`` adds the one carefully-scoped exception: when a
degradation event re-times a running job (simulator.py), the policy may
*checkpoint-restart* it onto currently-free capacity.  The decision is a
straight predicted-time race,

    migrate  iff  penalty + iters_rem * alpha_new  <  iters_rem * alpha_cur

with ``alpha_cur`` the post-stretch in-place rate, ``alpha_new`` the
Heavy-Edge alpha on the candidate fresh capacity (speed-aware), and
``penalty`` the configured checkpoint + restart downtime in seconds.  The
candidate placement draws from *currently free* GPUs only — the job's own
(degraded) GPUs are not reused, matching checkpoint-restart semantics
where the replacement allocation must exist before the old one is torn
down.  ``iters_rem`` is true remaining work as tracked by the simulator —
an online quantity (iterations completed so far are observable), unlike
the total iteration count, which stays a prediction.

Re-placement stays on the PR-3 fast path: candidate capacity vectors come
from one consolidating ``FreeCapsSnapshot`` per (event, free-state) —
carved per demand, invalidated on every migration — and the mapping is
answered by the shared ``PlacementCache`` keyed with the per-slot speed
factors (or the retained pure-Python reference pipeline on the uncached
engine, keeping the cached/uncached bit-identical property intact under
degradation).

With ``migrate=False`` (default) or an infinite penalty no job ever
moves, which is what makes the finish-in-place baseline and the
bit-identical clean-run property (tests/test_degradation.py) hold.

Queue-aware race guard (``migration_queue_guard=True``): the per-job
race above is greedy — it ignores the opportunity cost of the free
capacity it claims, and under deep queue pressure it can lose: moving a
long stretched job onto the only free servers makes every queued job
behind it wait out the migrant's full occupancy.  With the guard on,
each accepted migration is first *charged against the head of the ready
queue* (``Policy.migration_queue_head``): when a queued job fits in the
claimed capacity (``g_head <= g``) and its predicted duration is
shorter than the migrant's post-move occupancy (``penalty + rem *
alpha_new``), the migration is skipped — SRPT says the shorter queued
job deserves those GPUs first, and the migrant keeps running in place
(it is re-offered on every later pass, so it still moves once the
queue drains).  The guard is opt-in: it changes schedules, and the
PR-4 golden fixtures pin the unguarded race.
"""
from __future__ import annotations

from typing import List

from .cluster import ClusterState
from .heavy_edge import ConsolidatingLadder, map_job_canonical
from .simulator import Migration

# Default checkpoint + restart downtime, seconds: the scale of writing a
# sharded checkpoint and cold-starting the training processes elsewhere.
MIGRATION_PENALTY_DEFAULT = 120.0


class MigrationMixin:
    """Degradation reaction shared by A-SRPT and the queue baselines.

    Host classes provide ``cluster_spec`` (Policy.bind), ``_pcache`` (a
    ``PlacementCache`` or None for the reference engine), and set
    ``migrate``/``migration_penalty``/``migration_queue_guard`` in their
    constructors.  The queue guard additionally needs ``predictor`` and
    ``alpha_cache`` (both hosts have them) plus a
    ``migration_queue_head`` implementation (see simulator.Policy).
    """

    migrate: bool = False
    migration_penalty: float = MIGRATION_PENALTY_DEFAULT
    migration_queue_guard: bool = False

    def _map_migration(self, job, caps, speeds):
        pcache = getattr(self, "_pcache", None)
        if pcache is not None:
            return pcache.map_job(job, caps, speeds=speeds)
        return map_job_canonical(
            job, caps, self.cluster_spec,
            refine=getattr(self, "refine_mapping", False),
            reference=True, speeds=speeds,
        )

    def plan_migrations(
        self, t: float, cluster: ClusterState, candidates: list
    ) -> List[Migration]:
        if not self.migrate:
            return []
        penalty = self.migration_penalty
        migs: List[Migration] = []
        # Queue-aware guard: resolve the ready-queue head once per sweep
        # (migrations never mutate the queue, so it stays valid).  The
        # head's predicted duration is the opportunity cost every
        # accepted migration is charged against.
        head = head_work = None
        if self.migration_queue_guard:
            head = self.migration_queue_head(t)
            if head is not None:
                _, a_min = self.alpha_cache.bounds(head)
                head_work = self.predictor.predict(head) * a_min
        # Shared snapshot-or-select ladder (same protocol as A-SRPT step
        # 2): any actual migration changes the free state and resets it.
        ladder = ConsolidatingLadder(
            cluster, self.cluster_spec, ranks=cluster.effective_bw_ranks
        )
        for r in candidates:
            g = r.job.g
            if g > cluster.total_free:
                continue  # nowhere to go; finish in place
            caps = ladder.caps_for(g)
            speeds = cluster.speeds_for(caps)
            placement, a_new = self._map_migration(r.job, caps, speeds)
            # Online information only: under the prediction loop the
            # policy races *predicted* remaining iterations (what it
            # believes), not the simulator's true bookkeeping — believing
            # a job nearly done keeps it in place; an overrun re-estimate
            # re-opens the race on a later pass.  Legacy runs
            # (pred_rem None) keep racing true remaining work verbatim.
            rem = r.pred_rem if r.pred_rem is not None else r.iters_rem
            stay = rem * r.alpha
            if r.since > t:
                # mid-restart from an earlier migration: finishing in
                # place still owes the rest of that downtime
                stay += r.since - t
            move = penalty + rem * a_new
            if move >= stay - 1e-12:
                continue
            if head is not None and head.g <= g and head_work < move:
                # the queued job fits in the claimed caps and finishes
                # sooner than the migrant would occupy them: let the next
                # pass start it instead (the migrant is re-offered later)
                continue
            cluster.release(r.job.job_id)
            cluster.allocate(r.job.job_id, placement, counts=dict(caps))
            migs.append(Migration(r.job, placement, a_new, penalty))
            ladder.reset()
        return migs
