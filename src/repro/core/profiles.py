"""DNN workload profiles and JobSpec construction.

Two sources of stage profiles:

1. The paper's nine profiled models (Table I).  We cannot profile real
   V100/H100 GPUs offline, so the per-model single-device iteration time,
   parameter bytes, and boundary activation bytes are *analytic* estimates
   (FLOPs / effective throughput; params x 4 B; batch x seq x hidden x 4 B),
   which is exactly the information the paper's timing model consumes.
2. A bridge from this framework's own architecture configs
   (``repro/configs``): any of the 10 assigned architectures can be turned
   into a DDLwMP job with a pipeline split, so the scheduler schedules the
   same models the data plane trains (see ``job_from_model_shape``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from .job import JobSpec, StageSpec, RAR

MB = 1024.0**2
GB = 1024.0**3


@dataclass(frozen=True)
class ModelProfile:
    """Per-model analytic profile at the paper's mini-batch size."""

    name: str
    params_bytes: float  # trainable bytes (fp32)
    iter_time_1dev: float  # p_f + p_b of the whole model on one device (s)
    act_bytes: float  # activation bytes at a stage boundary (d_out)
    configs: Tuple[Tuple[int, ...], ...]  # per-stage replica counts options


# Paper Table I, with distributed configurations in the spirit of the
# pipeline planner of [20]: a mix of DP (single stage, many replicas),
# MP (many stages, 1 replica) and PP (stages with varying replication).
PAPER_MODELS: Dict[str, ModelProfile] = {
    "vgg19": ModelProfile(
        "vgg19", 144e6 * 4, 0.40, 20 * MB,
        ((1,), (2,), (4,), (8,), (2, 2), (4, 4)),
    ),
    "resnet152": ModelProfile(
        "resnet152", 60e6 * 4, 0.05, 3 * MB,
        ((1,), (2,), (4,), (8,), (2, 2)),
    ),
    "inception_v3": ModelProfile(
        "inception_v3", 24e6 * 4, 0.12, 8 * MB,
        ((1,), (2,), (4,), (8,)),
    ),
    "bert_large": ModelProfile(
        "bert_large", 340e6 * 4, 0.30, 6 * MB,
        ((1,), (2,), (4,), (2, 2), (4, 4)),
    ),
    "xlnet_large": ModelProfile(
        "xlnet_large", 550e6 * 4, 0.45, 6 * MB,
        ((1,), (2,), (4,), (2, 2), (4, 4)),
    ),
    # T5 / GPT entries are the paper's 3-layer profiling slices.
    "t5": ModelProfile(
        "t5", 1.4e9 * 4, 0.35, 17 * MB,
        ((1, 1), (2, 2), (1, 1, 1, 1), (2, 2, 2, 2), (4, 4)),
    ),
    "gpt_6.7b": ModelProfile(
        "gpt_6.7b", 0.63e9 * 4, 4.0, 268 * MB,
        ((1, 1), (2, 2), (1, 1, 1, 1), (2, 2, 2, 2)),
    ),
    "gpt_13b": ModelProfile(
        "gpt_13b", 1.2e9 * 4, 8.0, 335 * MB,
        ((1, 1), (2, 2), (1, 1, 1, 1), (2, 2, 2, 2), (4, 4, 4, 4)),
    ),
    "gpt_175b": ModelProfile(
        "gpt_175b", 5.4e9 * 4, 20.0, 402 * MB,
        ((1, 1, 1, 1), (2, 2, 2, 2), (1,) * 8, (2,) * 8, (4,) * 8),
    ),
}

SINGLE_GPU_MODELS = [
    "vgg19", "resnet152", "inception_v3", "bert_large", "xlnet_large",
]


def build_stages(
    profile: ModelProfile, replicas: Sequence[int]
) -> Tuple[StageSpec, ...]:
    """Split a model profile uniformly into len(replicas) pipeline stages."""
    S = len(replicas)
    stage_time = profile.iter_time_1dev / S
    h = profile.params_bytes / S
    stages: List[StageSpec] = []
    for s, k in enumerate(replicas):
        d_out = profile.act_bytes if s < S - 1 else 0.0
        if s > 0:
            # Consistency: k_{s-1} * d_out_{s-1} == k_s * d_in_s.
            d_in = replicas[s - 1] * profile.act_bytes / k
        else:
            d_in = 0.0
        stages.append(
            StageSpec(
                p_f=stage_time / 3.0,
                p_b=2.0 * stage_time / 3.0,
                d_in=d_in,
                d_out=d_out,
                h=h,
                k=int(k),
            )
        )
    return tuple(stages)


def make_job(
    job_id: int,
    model: str,
    config_idx: int,
    n_iters: int,
    arrival: float = 0.0,
    group_id: int = -1,
    user_id: int = 0,
    allreduce: str = RAR,
) -> JobSpec:
    profile = PAPER_MODELS[model]
    replicas = profile.configs[config_idx % len(profile.configs)]
    return JobSpec(
        job_id=job_id,
        stages=build_stages(profile, replicas),
        n_iters=n_iters,
        arrival=arrival,
        group_id=group_id,
        user_id=user_id,
        allreduce=allreduce,
        model_name=model,
    )


def job_from_model_shape(
    job_id: int,
    name: str,
    total_params: float,
    d_model: int,
    global_batch: int,
    seq_len: int,
    replicas: Sequence[int],
    n_iters: int,
    arrival: float = 0.0,
    group_id: int = -1,
    user_id: int = 0,
    allreduce: str = RAR,
    peak_flops: float = 197e12,
    mfu: float = 0.4,
    param_bytes: int = 2,  # bf16 on TPU
) -> JobSpec:
    """Bridge: one of this framework's architectures -> a DDLwMP job.

    Per-stage compute time = 6 * N_stage * tokens / (mfu * peak);
    boundary activations = batch * seq * d_model * param_bytes.
    """
    tokens = global_batch * seq_len
    S = len(replicas)
    n_stage = total_params / S
    stage_time = 6.0 * n_stage * tokens / (mfu * peak_flops)
    act = float(global_batch) * seq_len * d_model * param_bytes
    stages: List[StageSpec] = []
    for s, k in enumerate(replicas):
        d_out = act if s < S - 1 else 0.0
        d_in = replicas[s - 1] * act / k if s > 0 else 0.0
        stages.append(
            StageSpec(
                p_f=stage_time / 3.0,
                p_b=2.0 * stage_time / 3.0,
                d_in=d_in,
                d_out=d_out,
                h=n_stage * param_bytes,
                k=int(k),
            )
        )
    return JobSpec(
        job_id=job_id,
        stages=tuple(stages),
        n_iters=n_iters,
        arrival=arrival,
        group_id=group_id,
        user_id=user_id,
        allreduce=allreduce,
        model_name=name,
    )
