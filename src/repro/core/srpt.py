"""Preemptive single-machine SRPT — the virtual instances A1 / A1-tilde.

The paper maps the cluster problem onto a hypothetical single machine where
job ``i`` has work ``(g_i / G) * n_i * alpha_i_min`` (instance A1) or, with
predicted iterations, ``(g_i / G) * n_tilde_i * alpha_i_min`` (A1-tilde).
On heterogeneous clusters ``G`` is the class-weighted total GPU count and
``alpha_i_min`` the Heavy-Edge estimate on the biggest/fastest-NIC servers
(see heavy_edge.consolidated_caps) — the virtual machine itself stays a
unit-speed single machine.
Preemptive SRPT is optimal for total completion time on one machine; the
*virtual completion order* then drives the real scheduler.

``VirtualSRPT`` is an online incremental simulator: jobs arrive with a work
amount; ``advance(t)`` returns jobs that complete by ``t``; the next virtual
completion time is exposed so the event-driven cluster simulator can wake
the policy exactly when the pending queue grows.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple


class VirtualSRPT:
    """Incremental preemptive SRPT on a unit-speed single machine.

    ``keep_history=False`` drops the ``completion_times`` log (an
    O(all-jobs) dict nothing in the online pipeline reads — A-SRPT only
    consumes the ``advance`` backlog), keeping memory bounded by the
    *live* virtual queue on million-job streams.  The offline helper
    ``srpt_total_completion`` is the one history consumer.
    """

    def __init__(self, keep_history: bool = True) -> None:
        # (remaining_work, tiebreak_seq, job_id)
        self._heap: List[Tuple[float, int, int]] = []
        self._seq = itertools.count()
        self._now = 0.0
        self.completion_times: Optional[Dict[int, float]] = (
            {} if keep_history else None
        )
        self._unreleased: List[Tuple[float, int]] = []  # completion backlog

    def _complete(self, jid: int, t: float) -> None:
        if self.completion_times is not None:
            self.completion_times[jid] = t
        self._unreleased.append((t, jid))

    def arrive(self, t: float, job_id: int, work: float) -> None:
        if t + 1e-12 < self._now:
            raise ValueError(f"arrival at {t} before current time {self._now}")
        self._run_until(t)
        if work <= 0.0:
            # Zero predicted work (unseen job): completes instantly.
            self._complete(job_id, t)
        else:
            heapq.heappush(self._heap, (work, next(self._seq), job_id))

    def _run_until(self, t: float) -> None:
        """Execute the machine from self._now to t (no arrivals inside)."""
        while self._heap and self._now < t:
            rem, seq, jid = self._heap[0]
            dt = t - self._now
            if rem <= dt + 1e-9:  # absolute-seconds tolerance (ulp guard)
                heapq.heappop(self._heap)
                self._now += rem
                self._complete(jid, self._now)
            else:
                heapq.heapreplace(self._heap, (rem - dt, seq, jid))
                self._now = t
        self._now = max(self._now, t)

    def advance(self, t: float) -> List[Tuple[float, int]]:
        """Run to ``t``; return the completion backlog [(time, job_id)],
        ordered by completion time (arrival order breaks ties)."""
        self._run_until(t)
        done = self._unreleased
        self._unreleased = []
        done.sort(key=lambda cj: cj[0])
        return done

    def next_completion_time(self) -> Optional[float]:
        """Time of the next completion assuming no further arrivals."""
        if not self._heap:
            return None
        return self._now + self._heap[0][0]

    @property
    def is_idle(self) -> bool:
        """No queued work and no unreleased completions: advance is a no-op."""
        return not self._heap and not self._unreleased

    @property
    def now(self) -> float:
        return self._now


def srpt_total_completion(
    jobs: List[Tuple[int, float, float]]
) -> Tuple[float, Dict[int, float]]:
    """Offline helper: total completion time of preemptive SRPT.

    ``jobs``: (job_id, arrival, work). Returns (sum of completions, per-job
    completion times). Used by tests to check optimality against brute force.
    """
    vm = VirtualSRPT()
    for jid, r, w in sorted(jobs, key=lambda x: x[1]):
        vm.arrive(r, jid, w)
    vm.advance(float("inf"))
    total = sum(vm.completion_times.values())
    return total, dict(vm.completion_times)
