"""Datacenter-trace ingestion: Philly/PAI-style CSV rows -> JobSpecs.

Production GPU-cluster traces (Microsoft Philly, Alibaba PAI; Hu et al.,
arXiv 2109.01313) ship as CSV: one row per job with a submit timestamp, a
GPU demand, an observed duration, and optional user/model tags.  This
module turns such rows into the simulator's :class:`JobSpec` s — either

* **lazily**, via :func:`iter_trace_csv` / :func:`trace_jobs_source`,
  holding O(1) rows resident (the bounded-memory path for million-job
  replays; rows must already be submit-ordered), or
* **eagerly**, via :func:`load_trace_csv` / :func:`ingest_scenario`,
  which sorts by submit time and can serialize to Scenario JSON v1.

CSV format
----------

A header row is required.  Column names are matched case-insensitively
against the alias table below; the canonical name is listed first.

===========  ==========================================  =========
column        aliases                                    required
===========  ==========================================  =========
submit_time  submitted_time, submit, start_time,         yes
             arrival
gpus         num_gpus, gpu_num, gpu_demand, plan_gpu     yes
duration     run_time, runtime, duration_s, run_time_s   one of
iterations   n_iters, iters                              the two
user         user_id, user_name                          no
model        model_name, workload                        no
group        group_id, group_tag                         no
===========  ==========================================  =========

* ``submit_time`` is either a float (seconds) or an ISO-8601 timestamp
  (``2017-10-03 14:21:09``).  ISO timestamps are converted to seconds
  relative to the first row's timestamp; numeric values pass through
  unchanged (override with an explicit ``t0``).
* ``gpus`` must parse as a positive integer (a float with zero
  fractional part is accepted — PAI's ``plan_gpu`` style ``800.0``
  means 800 GPUs only after the caller rescales; this module does not
  guess units).
* ``iterations`` wins when both it and ``duration`` are present.  A
  duration is converted to an iteration count by dividing by the
  assigned model profile's single-device iteration time (the quantity
  the paper's predictor estimates) — ``max(1, round(dur / t_iter))``.
* ``model``, when present, must name a profile in
  :data:`repro.core.profiles.PAPER_MODELS`.  When absent (or blank),
  a profile is assigned deterministically by hashing the recurrence
  tag, so resubmissions of the same group get the same model.
* ``user`` and ``group`` tags are interned to dense integer ids in
  first-seen order.  When ``group`` is absent the recurrence key falls
  back to ``(user, model, gpus)`` — the PAI notion that a user
  resubmitting the same workload shape is the same recurring job.

Malformed-row policy (fail-loud by default)
-------------------------------------------

Header-level problems — a missing required column, an unreadable header
— always raise :class:`TraceSchemaError`.  Row-level problems raise
``TraceSchemaError`` with a ``path:line:`` prefix naming the offending
row under the default ``on_error="raise"``; ``on_error="skip"`` instead
drops the row and counts it in :class:`IngestStats` (use for known-dirty
real traces, never silently).  A row is malformed when:

* a required field is missing or blank,
* ``submit_time`` parses as neither float nor ISO-8601, or is negative
  after ``t0`` normalization, or is NaN/inf,
* ``gpus`` is not a positive integer (zero-GPU rows — PAI CPU-only
  jobs — are *malformed here*: filter them upstream or use ``skip``),
* ``duration``/``iterations`` is not a positive finite number,
* ``model`` names an unknown profile.

Out-of-order submits are **not** a row-level defect: real traces are
logged by completion and arrive unsorted.  The eager loaders sort.  The
lazy iterator cannot (bounded memory), so it raises ``TraceSchemaError``
on the first regression unless constructed with ``sorted_input=False``
— in which case use it only to feed an eager sort or a JSONL re-shard
(the simulator enforces arrival order itself and would fail anyway).

CLI
---

``python -m repro.core.trace_ingest stats FILE.csv`` parses and prints
summary statistics (fail-loud).  ``convert FILE.csv --jsonl OUT.jsonl``
re-shards a CSV into the :class:`~repro.core.scenario.JsonlJobs` format
streamingly; ``convert FILE.csv --scenario OUT.json --servers N
--gpus-per-server G`` emits a full Scenario JSON v1 document (eager).
"""
from __future__ import annotations

import csv
import math
import zlib
from dataclasses import dataclass, field
from datetime import datetime
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .job import ClusterSpec, JobSpec, RAR
from .profiles import PAPER_MODELS, ModelProfile, build_stages
from .scenario import ClusterEvent, IterJobs, Scenario

__all__ = [
    "IngestStats",
    "TraceSchemaError",
    "ingest_scenario",
    "iter_trace_csv",
    "load_trace_csv",
    "trace_jobs_source",
]


class TraceSchemaError(ValueError):
    """A trace violates the documented CSV schema (header or row)."""


# canonical -> accepted header spellings (all matched lowercased)
_ALIASES: Dict[str, Tuple[str, ...]] = {
    "submit_time": (
        "submit_time", "submitted_time", "submit", "start_time", "arrival",
    ),
    "gpus": ("gpus", "num_gpus", "gpu_num", "gpu_demand", "plan_gpu"),
    "duration": ("duration", "run_time", "runtime", "duration_s",
                 "run_time_s"),
    "iterations": ("iterations", "n_iters", "iters"),
    "user": ("user", "user_id", "user_name"),
    "model": ("model", "model_name", "workload"),
    "group": ("group", "group_id", "group_tag"),
}

_MODEL_NAMES: Tuple[str, ...] = tuple(PAPER_MODELS)


@dataclass
class IngestStats:
    """Counters filled in while a trace is parsed (also under ``skip``)."""

    n_rows: int = 0  # data rows seen (header excluded)
    n_jobs: int = 0  # rows successfully converted
    n_skipped: int = 0  # malformed rows dropped (on_error="skip" only)
    skipped_lines: List[int] = field(default_factory=list)  # first 20
    n_users: int = 0
    n_groups: int = 0
    total_gpu_demand: int = 0
    first_submit: Optional[float] = None
    last_submit: Optional[float] = None


def _resolve_header(fieldnames: Sequence[str], path: str) -> Dict[str, int]:
    """Map canonical column -> index, applying the alias table."""
    lowered = [(name or "").strip().lower() for name in fieldnames]
    out: Dict[str, int] = {}
    for canon, aliases in _ALIASES.items():
        for alias in aliases:
            if alias in lowered:
                out[canon] = lowered.index(alias)
                break
    missing = [c for c in ("submit_time", "gpus") if c not in out]
    if "duration" not in out and "iterations" not in out:
        missing.append("duration|iterations")
    if missing:
        raise TraceSchemaError(
            f"{path}: header {list(fieldnames)!r} is missing required "
            f"column(s) {missing} (aliases: "
            + "; ".join(f"{c}={list(_ALIASES[c])}" for c in _ALIASES)
        )
    return out


def _parse_submit(raw: str) -> Tuple[float, bool]:
    """Returns (value, is_wallclock).  Wallclock = ISO-8601 timestamp."""
    try:
        return float(raw), False
    except ValueError:
        pass
    try:
        return datetime.fromisoformat(raw).timestamp(), True
    except ValueError:
        raise TraceSchemaError(
            f"submit_time {raw!r} is neither a float (seconds) nor an "
            f"ISO-8601 timestamp"
        ) from None


def _parse_gpus(raw: str) -> int:
    try:
        v = float(raw)
    except ValueError:
        raise TraceSchemaError(f"gpus {raw!r} is not a number") from None
    if not math.isfinite(v) or v <= 0 or v != int(v):
        raise TraceSchemaError(
            f"gpus {raw!r} is not a positive integer (zero-GPU / "
            f"fractional rows are malformed; filter or rescale upstream)"
        )
    return int(v)


def _pick_model(tag: str, group_tag: str) -> ModelProfile:
    if tag:
        profile = PAPER_MODELS.get(tag)
        if profile is None:
            raise TraceSchemaError(
                f"model {tag!r} is not a known profile "
                f"(known: {list(_MODEL_NAMES)})"
            )
        return profile
    # no tag: deterministic by recurrence key, so a recurring group
    # keeps one model across resubmissions
    idx = zlib.crc32(group_tag.encode()) % len(_MODEL_NAMES)
    return PAPER_MODELS[_MODEL_NAMES[idx]]


def _replicas_for(profile: ModelProfile, g: int) -> Tuple[int, ...]:
    """The profile's listed distributed config matching the GPU demand,
    else a pure data-parallel single stage (any g is schedulable)."""
    for cfg in profile.configs:
        if sum(cfg) == g:
            return cfg
    return (g,)


def iter_trace_csv(
    path,
    *,
    on_error: str = "raise",
    t0: Optional[float] = None,
    start_job_id: int = 0,
    sorted_input: bool = True,
    stats: Optional[IngestStats] = None,
) -> Iterator[JobSpec]:
    """Lazily parse a trace CSV into time-ordered :class:`JobSpec` s.

    O(1) rows resident (plus the user/group interning maps, which are
    O(distinct tags) — hundreds in real traces, not O(jobs)).  With the
    default ``sorted_input=True`` an out-of-order submit raises; see the
    module docstring for the full malformed-row policy.  Pass an
    :class:`IngestStats` to collect counters while streaming.
    """
    if on_error not in ("raise", "skip"):
        raise ValueError(f"on_error must be 'raise' or 'skip': {on_error!r}")
    path = str(path)
    st = stats if stats is not None else IngestStats()
    users: Dict[str, int] = {}
    groups: Dict[str, int] = {}
    # per-(model, replicas) memoized stage tuples: recurrent jobs share
    # one stages object, which is what keeps a million-job pull small
    stage_cache: Dict[Tuple[str, Tuple[int, ...]], tuple] = {}
    job_id = start_job_id
    wall_t0: Optional[float] = None
    last_submit = -math.inf

    with open(path, newline="") as fh:
        reader = csv.reader(fh)
        try:
            header = next(reader)
        except StopIteration:
            raise TraceSchemaError(f"{path}: empty file (no header)") \
                from None
        cols = _resolve_header(header, path)

        def get(row: Sequence[str], canon: str) -> str:
            i = cols.get(canon)
            if i is None or i >= len(row):
                return ""
            return row[i].strip()

        for lineno, row in enumerate(reader, start=2):
            if not row or all(not c.strip() for c in row):
                continue
            st.n_rows += 1
            try:
                raw_submit = get(row, "submit_time")
                if not raw_submit:
                    raise TraceSchemaError("submit_time is blank")
                submit, wallclock = _parse_submit(raw_submit)
                if wallclock:
                    if wall_t0 is None:
                        wall_t0 = submit if t0 is None else t0
                    submit -= wall_t0
                elif t0 is not None:
                    submit -= t0
                if not math.isfinite(submit) or submit < 0.0:
                    raise TraceSchemaError(
                        f"submit_time {raw_submit!r} normalizes to "
                        f"{submit!r} (negative or non-finite)"
                    )

                g = _parse_gpus(get(row, "gpus"))

                user_tag = get(row, "user")
                model_tag = get(row, "model")
                group_tag = get(row, "group")
                if not group_tag:
                    group_tag = f"{user_tag}/{model_tag}/g{g}"
                profile = _pick_model(model_tag, group_tag)

                raw_iters = get(row, "iterations")
                if raw_iters:
                    try:
                        n_iters = int(float(raw_iters))
                    except ValueError:
                        raise TraceSchemaError(
                            f"iterations {raw_iters!r} is not a number"
                        ) from None
                    if not 0 < n_iters < 2**62:
                        raise TraceSchemaError(
                            f"iterations {raw_iters!r} out of range"
                        )
                else:
                    raw_dur = get(row, "duration")
                    if not raw_dur:
                        raise TraceSchemaError(
                            "row has neither iterations nor duration"
                        )
                    try:
                        dur = float(raw_dur)
                    except ValueError:
                        raise TraceSchemaError(
                            f"duration {raw_dur!r} is not a number"
                        ) from None
                    if not math.isfinite(dur) or dur <= 0.0:
                        raise TraceSchemaError(
                            f"duration {raw_dur!r} is not positive finite"
                        )
                    n_iters = max(
                        1, int(round(dur / profile.iter_time_1dev))
                    )
            except TraceSchemaError as exc:
                if on_error == "raise":
                    raise TraceSchemaError(
                        f"{path}:{lineno}: {exc}"
                    ) from None
                st.n_skipped += 1
                if len(st.skipped_lines) < 20:
                    st.skipped_lines.append(lineno)
                continue

            if sorted_input and submit < last_submit:
                # not a row defect: the *file* isn't stream-ingestible
                raise TraceSchemaError(
                    f"{path}:{lineno}: out-of-order submit {submit!r} "
                    f"after {last_submit!r} — the lazy reader needs a "
                    f"submit-sorted trace; sort the CSV, or use "
                    f"load_trace_csv() (eager, sorts in memory)"
                )
            last_submit = max(last_submit, submit)

            replicas = _replicas_for(profile, g)
            skey = (profile.name, replicas)
            stages = stage_cache.get(skey)
            if stages is None:
                stages = stage_cache[skey] = build_stages(profile, replicas)

            st.n_jobs += 1
            st.total_gpu_demand += g
            if st.first_submit is None:
                st.first_submit = submit
            st.last_submit = submit
            yield JobSpec(
                job_id=job_id,
                stages=stages,
                n_iters=n_iters,
                arrival=submit,
                group_id=groups.setdefault(group_tag, len(groups)),
                user_id=users.setdefault(user_tag, len(users)),
                allreduce=RAR,
                model_name=profile.name,
            )
            job_id += 1
            st.n_users = len(users)
            st.n_groups = len(groups)


def trace_jobs_source(path, **kw) -> IterJobs:
    """Replayable :class:`~repro.core.scenario.JobStream` over a CSV —
    the ``Scenario.jobs`` / ``simulate`` input for bounded-memory replay
    (each iteration re-opens and re-parses the file)."""
    return IterJobs(
        lambda: iter_trace_csv(path, **kw), name=f"csv:{path}"
    )


def load_trace_csv(
    path,
    *,
    on_error: str = "raise",
    t0: Optional[float] = None,
    start_job_id: int = 0,
    stats: Optional[IngestStats] = None,
) -> List[JobSpec]:
    """Eagerly parse a trace CSV (O(jobs) memory): rows are sorted by
    submit time — out-of-order files are fine here — and job ids are
    reassigned in arrival order, so the result is directly a schema-v1
    ``jobs`` array."""
    jobs = list(
        iter_trace_csv(
            path, on_error=on_error, t0=t0, start_job_id=start_job_id,
            sorted_input=False, stats=stats,
        )
    )
    jobs.sort(key=lambda j: j.arrival)
    return [
        JobSpec(
            job_id=start_job_id + i,
            stages=j.stages,
            n_iters=j.n_iters,
            arrival=j.arrival,
            group_id=j.group_id,
            user_id=j.user_id,
            allreduce=j.allreduce,
            model_name=j.model_name,
        )
        for i, j in enumerate(jobs)
    ]


def ingest_scenario(
    path,
    cluster: ClusterSpec,
    events: Sequence[ClusterEvent] = (),
    name: str = "",
    **kw,
) -> Scenario:
    """Eager CSV -> :class:`Scenario` (serializable via ``to_json()``,
    Scenario JSON schema v1)."""
    return Scenario(
        jobs=load_trace_csv(path, **kw),
        cluster=cluster,
        events=tuple(events),
        name=name or f"csv:{path}",
    )


# ---------------------------------------------------------------------------
# CLI: python -m repro.core.trace_ingest {stats,convert} FILE.csv ...
# ---------------------------------------------------------------------------


def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.trace_ingest",
        description="Philly/PAI-style CSV trace ingestion",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_stats = sub.add_parser("stats", help="parse + print trace statistics")
    p_conv = sub.add_parser(
        "convert", help="CSV -> JSONL shard (streaming) or Scenario JSON"
    )
    for p in (p_stats, p_conv):
        p.add_argument("csv", help="trace CSV file")
        p.add_argument(
            "--skip-malformed", action="store_true",
            help="drop malformed rows (default: fail loud)",
        )
    p_conv.add_argument("--jsonl", help="output JSONL shard (streaming)")
    p_conv.add_argument(
        "--scenario", help="output Scenario JSON v1 (eager, sorts)"
    )
    p_conv.add_argument("--servers", type=int, default=16)
    p_conv.add_argument("--gpus-per-server", type=int, default=8)
    p_conv.add_argument("--b-inter", type=float, default=1.25e9)
    p_conv.add_argument("--b-intra", type=float, default=300e9)
    args = ap.parse_args(argv)

    on_error = "skip" if args.skip_malformed else "raise"
    st = IngestStats()

    if args.cmd == "stats":
        for _ in iter_trace_csv(
            args.csv, on_error=on_error, sorted_input=False, stats=st,
        ):
            pass
    else:
        if bool(args.jsonl) == bool(args.scenario):
            ap.error("convert needs exactly one of --jsonl / --scenario")
        if args.jsonl:
            from .scenario import jobs_to_jsonl

            jobs_to_jsonl(
                iter_trace_csv(
                    args.csv, on_error=on_error, sorted_input=True,
                    stats=st,
                ),
                args.jsonl,
            )
            print(f"wrote {st.n_jobs} jobs -> {args.jsonl}")
        else:
            spec = ClusterSpec(
                num_servers=args.servers,
                gpus_per_server=args.gpus_per_server,
                b_inter=args.b_inter,
                b_intra=args.b_intra,
            )
            scn = ingest_scenario(
                args.csv, spec, on_error=on_error, stats=st,
            )
            with open(args.scenario, "w") as fh:
                fh.write(scn.to_json())
            print(f"wrote {st.n_jobs}-job scenario -> {args.scenario}")

    print(
        json.dumps(
            {
                "rows": st.n_rows,
                "jobs": st.n_jobs,
                "skipped": st.n_skipped,
                "users": st.n_users,
                "groups": st.n_groups,
                "total_gpu_demand": st.total_gpu_demand,
                "first_submit": st.first_submit,
                "last_submit": st.last_submit,
            },
            indent=2,
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
