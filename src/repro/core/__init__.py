"""Core: the paper's contribution — A-SRPT scheduling for DDLwMP jobs."""
from .job import (  # noqa: F401
    ClusterSpec,
    JobSpec,
    RAR,
    ServerClass,
    StageSpec,
    TAR,
)
from .graph import JobGraph, build_job_graph  # noqa: F401
from .timing import alpha, alpha_max, beta  # noqa: F401
from .heavy_edge import (  # noqa: F401
    alpha_min_estimate,
    map_job,
    select_servers,
)
from .cluster import ClusterState  # noqa: F401
from .srpt import VirtualSRPT, srpt_total_completion  # noqa: F401
from .scenario import (  # noqa: F401
    ArrivalJitterPerturbation,
    ClusterEvent,
    Degradation,
    ElasticPerturbation,
    Fault,
    FaultPerturbation,
    IterJobs,
    JobStream,
    JsonlJobs,
    Perturbation,
    PredictionNoisePerturbation,
    REQUEST_STREAM_KIND,
    RequestStream,
    SCENARIO_SCHEMA_VERSION,
    Scenario,
    ServerJoin,
    ServerLeave,
    StragglerPerturbation,
    jobs_to_jsonl,
    perturb_scenario,
    request_stream_from_dict,
    request_stream_to_dict,
    scenario_from_legacy,
)
from .simulator import (  # noqa: F401
    Allocation,
    Migration,
    Policy,
    SERVE_LAT_QUANTILES,
    STREAM_FLOW_QUANTILES,
    SchedulingPolicy,
    SimResult,
    Start,
    simulate,
)
from .quantile import StreamingQuantile  # noqa: F401
from .fleet import (  # noqa: F401
    FleetResult,
    FleetShared,
    VariantResult,
    fleet_variants,
    run_fleet,
)
from .migration import MIGRATION_PENALTY_DEFAULT, MigrationMixin  # noqa: F401
from .asrpt import ASRPTPolicy  # noqa: F401
from .baselines import BASELINES  # noqa: F401
from .predictor import (  # noqa: F401
    GroupStatPredictor,
    IterationPredictor,
    PerfectPredictor,
    RandomForestPredictor,
    RandomForestRegressor,
    make_predictor,
)
from .prediction_loop import (  # noqa: F401
    NoisyModel,
    OnlineForestModel,
    OracleModel,
    PredictionModel,
    ZeroColdStartModel,
    make_prediction_model,
)
from .trace import (  # noqa: F401
    StreamTraceConfig,
    TraceConfig,
    elastic_events,
    elastic_scenario,
    generate_trace,
    mixed_cluster_spec,
    straggler_events,
    straggler_scenario,
    stream_trace,
    stream_trace_source,
    trace_stats,
)
from .trace_ingest import (  # noqa: F401
    IngestStats,
    TraceSchemaError,
    ingest_scenario,
    iter_trace_csv,
    load_trace_csv,
    trace_jobs_source,
)
from .profiles import PAPER_MODELS, make_job, job_from_model_shape  # noqa: F401
from .ilp import exact_min_cut  # noqa: F401
