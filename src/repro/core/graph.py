"""Job communication graph Omega = (V, E)  (paper Sec. IV-B).

Vertices are stage replicas ``(stage, replica)``. Edges carry communication
bytes per iteration:

* inter-stage: complete bipartite edges between replicas of stage ``s-1`` and
  ``s`` with weight ``2 d_out_{s-1} / k_s == 2 d_in_s / k_{s-1}``;
* intra-stage AllReduce for stage ``s`` with ``k >= 2`` replicas:
    - RAR: ring edges, each weighted ``2 (k-1)/k * h``;
    - TAR: double-binary-tree edges, each weighted ``(k-1)/k * h`` (half of
      RAR: each of the two trees carries half the data, NCCL 2.4 model).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .job import JobSpec, RAR, TAR

Vertex = Tuple[int, int]  # (stage_index, replica_index)
EdgeWeights = Dict[Tuple[Vertex, Vertex], float]


def _edge_key(u: Vertex, v: Vertex) -> Tuple[Vertex, Vertex]:
    return (u, v) if u <= v else (v, u)


class DenseGraph:
    """Array form of a ``JobGraph``, built once and shared by every placement
    computed for the job config (see ``heavy_edge.PlacementCache._graphs``).

    ``W`` is the vertex-indexed symmetric weight matrix over ``verts`` (the
    vertices in sorted order — the order every tiebreak in the greedy uses).
    ``edge_a/edge_b`` list the edge endpoint indices sorted by
    ``(-w, a, rank[a, b])`` with ``rank[i, j]`` the position at which
    vertex ``j`` was inserted into ``i``'s adjacency dict — the precise
    order in which the reference seed scan prefers equally-heavy edges —
    so "heaviest edge among unassigned" is one masked ``argmax``.
    ``stage_internal`` accumulates intra-stage edge weights in the same
    edge-iteration order as the former per-call loop (bit-identical sums).
    """

    __slots__ = (
        "verts", "index", "W", "incident", "edge_a", "edge_b",
        "stage_of", "stage_bounds", "n_stages", "stage_internal", "arange",
        "swap_invalid", "nbr_pairs",
    )

    def __init__(self, graph: "JobGraph"):
        verts = sorted(graph.vertices)
        n = len(verts)
        index = {v: i for i, v in enumerate(verts)}
        W = np.zeros((n, n))
        rank = np.full((n, n), n * n, dtype=np.int64)
        counters = [0] * n
        edges = []
        for (u, v), w in graph.edges.items():
            i, j = index[u], index[v]
            W[i, j] += w
            W[j, i] += w
            if rank[i, j] == n * n:
                rank[i, j] = counters[i]
                counters[i] += 1
            if rank[j, i] == n * n:
                rank[j, i] = counters[j]
                counters[j] += 1
            a, b = (i, j) if i < j else (j, i)
            edges.append((w, a, b))
        edges.sort(key=lambda e: (-e[0], e[1], rank[e[1], e[2]]))
        # rank stays local: only the edge sort above needs it
        self.verts = verts
        self.index = index
        self.W = W
        self.incident = W.sum(axis=1)
        self.edge_a = np.array([a for _w, a, _b in edges], dtype=np.int64)
        self.edge_b = np.array([b for _w, _a, b in edges], dtype=np.int64)
        # verts are sorted (stage, replica): stages occupy contiguous slices
        self.stage_of = np.array([s for s, _r in verts], dtype=np.int64)
        n_stages = int(self.stage_of[-1]) + 1 if n else 0
        self.n_stages = n_stages
        bounds = np.searchsorted(self.stage_of, np.arange(n_stages + 1))
        self.stage_bounds = bounds
        internal = [0.0] * n_stages
        for (u, v), w in graph.edges.items():
            if u[0] == v[0]:
                internal[u[0]] += w
        self.stage_internal = internal
        self.arange = np.arange(n)
        # ordered-pair / same-index mask shared by the refine swap search
        self.swap_invalid = self.arange[:, None] >= self.arange[None, :]
        # per-vertex neighbor lists in adjacency *insertion* order, for
        # exact replication of reference float-accumulation sequences
        self.nbr_pairs = [
            [(index[nb], w) for nb, w in graph._adj[v].items()]
            for v in verts
        ]


class JobGraph:
    """Undirected weighted communication graph of one DDLwMP job."""

    def __init__(self, vertices: List[Vertex], edges: EdgeWeights):
        self.vertices = list(vertices)
        self.edges = dict(edges)
        self._adj: Dict[Vertex, Dict[Vertex, float]] = {v: {} for v in vertices}
        for (u, v), w in self.edges.items():
            self._adj[u][v] = self._adj[u].get(v, 0.0) + w
            self._adj[v][u] = self._adj[v].get(u, 0.0) + w
        self._dense: DenseGraph | None = None

    def dense(self) -> DenseGraph:
        """Cached array form (weight matrix, tiebreak ranks, stage slices)."""
        d = self._dense
        if d is None:
            d = self._dense = DenseGraph(self)
        return d

    def neighbors(self, v: Vertex) -> Dict[Vertex, float]:
        return self._adj[v]

    def incident_weight(self, v: Vertex) -> float:
        return sum(self._adj[v].values())

    def total_weight(self) -> float:
        return sum(self.edges.values())

    def cut_weight(self, assignment: Dict[Vertex, int]) -> float:
        """Total weight of edges whose endpoints land on different servers."""
        return sum(
            w
            for (u, v), w in self.edges.items()
            if assignment[u] != assignment[v]
        )


def _double_binary_tree_edges(k: int) -> List[Tuple[int, int]]:
    """Parent-child pairs of NCCL-style double binary trees over ranks [0,k).

    Tree 1 is the balanced binary tree in in-order rank layout (rank r's
    parent flips the lowest set bit region); tree 2 is tree 1 with ranks
    shifted by 1 (mod k), the classic "mirrored/shifted" construction in
    which every rank is a leaf in one tree and interior in the other.
    """
    if k < 2:
        return []

    def tree1(n: int) -> List[Tuple[int, int]]:
        # In-order labeled complete-ish binary tree over 0..n-1.
        edges: List[Tuple[int, int]] = []

        def build(lo: int, hi: int, parent: int | None) -> None:
            if lo > hi:
                return
            mid = (lo + hi) // 2
            if parent is not None:
                edges.append((parent, mid))
            build(lo, mid - 1, mid)
            build(mid + 1, hi, mid)

        build(0, n - 1, None)
        return edges

    t1 = tree1(k)
    t2 = [((u + 1) % k, (v + 1) % k) for (u, v) in t1]
    return t1 + t2


def build_job_graph(job: JobSpec) -> JobGraph:
    vertices = list(job.replica_vertices())
    edges: EdgeWeights = {}

    def add(u: Vertex, v: Vertex, w: float) -> None:
        if u == v or w <= 0.0:
            return
        key = _edge_key(u, v)
        edges[key] = edges.get(key, 0.0) + w

    # Inter-stage bipartite edges.
    for s in range(1, job.num_stages):
        prev, cur = job.stages[s - 1], job.stages[s]
        if prev.d_out <= 0:
            continue
        w = 2.0 * prev.d_out / cur.k
        for r_prev in range(prev.k):
            for r_cur in range(cur.k):
                add((s - 1, r_prev), (s, r_cur), w)

    # Intra-stage AllReduce edges.
    for s, st in enumerate(job.stages):
        k = st.k
        if k < 2 or st.h <= 0:
            continue
        if job.allreduce == RAR:
            w = 2.0 * (k - 1) / k * st.h
            if k == 2:
                add((s, 0), (s, 1), w)
            else:
                for r in range(k):
                    add((s, r), (s, (r + 1) % k), w)
        else:  # TAR
            w = (k - 1) / k * st.h
            for (u, v) in _double_binary_tree_edges(k):
                add((s, u), (s, v), w)

    return JobGraph(vertices, edges)
