"""A-SRPT: adaptive shortest-remaining-processing-time-first (paper Alg. 1).

Pipeline of decisions per scheduling event:

1. advance the virtual single machine (instance A1-tilde, preemptive SRPT on
   predicted scaled work ``(g_i/G) n~_i alpha~_i^min``) to the current time;
   newly (virtually) completed jobs join ``pending_queue`` in completion
   order — this is the release order for the real cluster;
2. re-evaluate *delayed* communication-heavy jobs: start if the achievable
   per-iteration time improved (``alpha < kappa``), dropped under the
   COMM_HEAVY ratio, or the delay budget ``tau (g_i/G) n~_i alpha~_i^min``
   expired;
3. pop the head of ``pending_queue`` while it fits:
   - communication-heavy (``alpha_max / alpha~_min >= COMM_HEAVY``): place on
     the *most*-available servers (consolidation); if still comm-heavy,
     delay (step 2 takes over);
   - otherwise: place on the *least*-available servers (fragmentation-aware)
     and start immediately.

Incremental evaluation (trace scale, default on): a delayed job's
evaluation is a pure function of the capacity vector ``select_servers``
returns for it, so it is skipped outright while the cluster state — and
hence that vector — is unchanged (``ClusterState.epoch``), re-selected
but not re-mapped when the vector comes back equal, and answered from the
memoized Heavy-Edge mapping (``heavy_edge.PlacementCache``) otherwise.
(Note a *stronger* skip — "allocations can only worsen alpha, so only
releases invalidate" — is unsound: Heavy-Edge is greedy, and shrinking
capacities can reshuffle the selected vector into one greedy maps
better.)  Deadline expiry is checked unconditionally.  The schedule is
bit-identical to exhaustive re-evaluation (property-tested in
tests/test_sched_cache.py) while per-event work drops by an order of
magnitude on congested traces.

Degraded clusters (stragglers, see cluster.py): while any server carries
a speed factor != 1.0, server selection tie-breaks by *effective*
bandwidth, placements are evaluated (and cached) per speed signature,
and the step-2 skip keys on (caps, speeds) so a speed change alone
re-evaluates delayed jobs.  All of it is gated on
``cluster.has_degraded`` — clean passes run the original code paths
byte for byte.  With ``migrate=True`` the policy also checkpoint-
restarts running jobs off degraded capacity (migration.py).
"""
from __future__ import annotations

import heapq
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .cluster import ClusterState
from .heavy_edge import (
    ConsolidatingLadder,
    PlacementCache,
    map_job_canonical,
    select_servers,
)
from .job import ClusterSpec, JobSpec
from .migration import MIGRATION_PENALTY_DEFAULT, MigrationMixin
from .predictor import IterationPredictor
from .simulator import Policy, Start
from .srpt import VirtualSRPT

COMM_HEAVY_DEFAULT = 1.5


class _Delayed:
    __slots__ = ("job", "kappa", "deadline", "eval_epoch", "eval_caps")

    def __init__(self, job: JobSpec, kappa: float, deadline: float):
        self.job = job
        self.kappa = kappa
        self.deadline = deadline
        # cluster.epoch and (selected caps, their speed factors, the
        # admission a_min bound) at the last placement evaluation.  While
        # the cluster is unchanged — or changes leave the capacity vector,
        # those servers' speeds, and the degradation-aware bound all
        # identical — the evaluation outcome is unchanged (the mapping is
        # a pure function of caps + speeds; the decision additionally
        # reads the bound).
        self.eval_epoch = -1
        self.eval_caps: Optional[tuple] = None


class ASRPTPolicy(MigrationMixin, Policy):
    def __init__(
        self,
        predictor: IterationPredictor,
        comm_heavy: float = COMM_HEAVY_DEFAULT,
        tau: float = 2.0,
        refine_mapping: bool = False,  # beyond-paper local-search swaps
        placement_cache: bool = True,  # incremental eval + memoized mapping
        migrate: bool = False,  # checkpoint-restart off degraded servers
        migration_penalty: float = MIGRATION_PENALTY_DEFAULT,
        # queue-aware race (migration.py).  Default stays False: the
        # `sched_scale --guard` A/B at 20k-job straggler scale measured
        # flow_vs_unguarded = 1.20 — deferring migrations behind a deep
        # queue (peak ~13k) starves stretched jobs of healthy capacity.
        migration_queue_guard: bool = False,
        degraded_admission: bool = True,  # speed-aware alpha bounds (AlphaCache)
        # Heterogeneity-aware server *selection* (ROADMAP carry-over):
        # score candidate capacity vectors by mapped alpha across server
        # classes instead of only tie-breaking by NIC bandwidth within
        # equal free counts.  Opt-in: it changes schedules, and the
        # golden fixtures pin the default.  Only bites on heterogeneous
        # clusters (homogeneous specs have a single class).
        hetero_selection: bool = False,
    ):
        self.predictor = predictor
        self.comm_heavy = comm_heavy
        self.tau = tau
        self.refine_mapping = refine_mapping
        self.placement_cache = placement_cache
        self.migrate = migrate
        self.migration_penalty = migration_penalty
        self.migration_queue_guard = migration_queue_guard
        self.degraded_admission = degraded_admission
        self.hetero_selection = hetero_selection
        # prediction-loop opt-in (simulator.Policy / prediction_loop):
        # derived from the predictor so plain predictors keep the legacy
        # engine byte for byte
        self.track_overruns = bool(getattr(predictor, "track_overruns", False))
        # no history: the vm's completion log is unread here, and dropping
        # it keeps policy memory bounded by the live queue on job streams
        self.vm = VirtualSRPT(keep_history=False)
        self.pending: Deque[JobSpec] = deque()
        self.delayed: "OrderedDict[int, _Delayed]" = OrderedDict()
        self._by_id: Dict[int, JobSpec] = {}
        self._pred_work: Dict[int, float] = {}
        # (deadline, job_id) heap over self.delayed; stale entries (started
        # jobs) and past deadlines are pruned lazily.  Used by next_wakeup
        # and by the step-2 settled-epoch gate.
        self._dheap: List[tuple] = []
        # cluster.epoch at the end of the last scheduling pass that started
        # nothing: while unchanged, every delayed job's evaluation would
        # repeat verbatim, so step 2 is skipped outright (deadline expiry
        # aside).  Only consulted in incremental mode.
        self._settled_epoch: int = -1

    def bind(self, cluster_spec: ClusterSpec) -> None:
        super().bind(cluster_spec)
        # built through the Policy helpers so a fleet run (fleet_shared
        # set) hands out fleet-shared caches instead of cold private ones
        self.alpha_cache = self._make_alpha_cache(cluster_spec)
        self._pcache: Optional[PlacementCache] = (
            self._make_placement_cache(
                cluster_spec, refine=self.refine_mapping
            )
            if self.placement_cache
            else None
        )
        self._hetero_sel = self.hetero_selection and cluster_spec.is_heterogeneous
        if self._hetero_sel:
            by_cls: Dict[int, List[int]] = {}
            for m in range(cluster_spec.num_servers):
                by_cls.setdefault(cluster_spec.class_of(m), []).append(m)
            self._class_servers = [by_cls[c] for c in sorted(by_cls)]

    # -- event hooks --------------------------------------------------------

    def on_arrival(self, t: float, job: JobSpec) -> None:
        n_pred = self.predictor.predict(job)
        _, a_min = self.alpha_cache.bounds(job)
        g_frac = job.g / self.cluster_spec.total_gpus
        work = g_frac * n_pred * a_min
        self._by_id[job.job_id] = job
        self._pred_work[job.job_id] = work
        self.vm.arrive(t, job.job_id, work)
        self._drain_vm(t)

    def on_completion(self, t: float, job: JobSpec) -> None:
        self.predictor.observe(job, job.n_iters)
        # a completed job's spec and predicted work are never read again
        # (virtual completion precedes the real start, which precedes this);
        # dropping them keeps policy state bounded by the live job count
        self._by_id.pop(job.job_id, None)
        self._pred_work.pop(job.job_id, None)

    def _drain_vm(self, t: float) -> None:
        vm = self.vm
        if vm.is_idle:
            return
        for _ct, jid in vm.advance(t):
            self.pending.append(self._by_id[jid])

    # -- placement helpers ---------------------------------------------------

    def _map(self, job: JobSpec, caps, speeds=None) -> tuple:
        if self._pcache is not None:
            return self._pcache.map_job(job, caps, speeds=speeds)
        # Uncached reference path: identical canonicalization, no memo,
        # and the retained pure-Python greedy/alpha pipeline — the cached
        # array-native engine must be bit-identical to this.
        return map_job_canonical(
            job, caps, self.cluster_spec, refine=self.refine_mapping,
            reference=True, speeds=speeds,
        )

    def _scored_consolidating(
        self, job: JobSpec, cluster: ClusterState, bw_ranks, speeds_for,
        caps: tuple, sp,
    ) -> tuple:
        """Score candidate capacity vectors by mapped alpha across server
        classes (hetero_selection).

        The default most-available-first pick (``caps``, already
        selected) competes with one class-restricted consolidation per
        server class whose free capacity alone holds the job: on a
        heterogeneous cluster the globally most-available servers are
        often the *slow-NIC* class (biggest servers drain last), while a
        comm-heavy job consolidated on fewer fast-NIC servers maps to a
        strictly better alpha.  Every candidate goes through the same
        memoized Heavy-Edge mapping; the lowest alpha wins, ties keep
        the default (deterministic: candidates are visited in fixed
        class order).  Returns ``(alpha, placement, caps, speeds)``.
        """
        placement, a = self._map(job, caps, sp)
        best = (a, placement, caps, sp)
        free = cluster.free
        g = job.g
        spec = self.cluster_spec
        seen = {caps}
        for servers in self._class_servers:
            cfree: Dict[int, int] = {}
            total = 0
            for m in servers:
                f = free.get(m, 0)
                if f > 0:
                    cfree[m] = f
                    total += f
            if total < g:
                continue  # this class alone cannot hold the job
            c_caps = tuple(
                select_servers(
                    cfree, g, consolidate=True, spec=spec, ranks=bw_ranks
                )
            )
            if c_caps in seen:
                continue
            seen.add(c_caps)
            c_sp = speeds_for(c_caps) if speeds_for else None
            c_pl, c_a = self._map(job, c_caps, c_sp)
            if c_a < best[0]:
                best = (c_a, c_pl, c_caps, c_sp)
        return best

    # -- main scheduling pass -------------------------------------------------

    def _min_deadline(self) -> Optional[float]:
        """Earliest deadline among still-delayed jobs (lazily pruned)."""
        h = self._dheap
        delayed = self.delayed
        while h:
            if h[0][1] in delayed:
                return h[0][0]
            heapq.heappop(h)  # job already started: stale entry
        return None

    def plan_pass(self, t: float, cluster: ClusterState) -> List[Start]:
        self._drain_vm(t)
        starts: List[Start] = []
        incremental = self._pcache is not None
        # Degradation-aware admission: classify against speed-aware alpha
        # bounds while any allocatable server is degraded (None on clean
        # clusters — the clean AlphaCache path runs byte-identical).
        bcluster = (
            cluster
            if self.degraded_admission and cluster.has_degraded
            else None
        )

        # Step 2: re-evaluate delayed communication-heavy jobs (Alg. 1 l.16-19).
        run_step2 = bool(self.delayed)
        if run_step2 and incremental and cluster.epoch == self._settled_epoch:
            # Cluster untouched since a pass where every delayed job stayed
            # delayed: re-evaluation would repeat verbatim.  Only a deadline
            # expiring at/before t can change an outcome.
            dl = self._min_deadline()
            run_step2 = dl is not None and t >= dl - 1e-12
        # Batched step-2 state (incremental mode): the consolidating pick
        # order is shared by every evaluation against one free state, so
        # the second evaluation onward carves its capacity vector from a
        # prefix-sum snapshot (``ConsolidatingLadder``; reset on every
        # start — the free state changed).  Jobs sharing (config, g) —
        # hence provably the same caps, placement, and alpha — share one
        # evaluation via ``memo``.
        memo: Dict[tuple, tuple] = {}
        spec = self.cluster_spec
        # Degradation state (None on clean clusters — every added branch
        # below degrades to the original clean code path): effective-
        # bandwidth ranks steer selection away from stragglers, per-slot
        # speed factors key the mapping.  Speeds only change between
        # passes (simulator events), never inside one.
        bw_ranks = cluster.effective_bw_ranks
        speeds_for = cluster.speeds_for if cluster.has_degraded else None
        ladder = ConsolidatingLadder(cluster, spec, ranks=bw_ranks)
        consolidating_caps = ladder.caps_for

        if run_step2:
            for jid in list(self.delayed.keys()):
                d = self.delayed[jid]
                g = d.job.g
                if g > cluster.total_free:
                    continue  # cannot fit yet; keep waiting
                expired = t >= d.deadline - 1e-12
                if incremental:
                    if not expired and d.eval_epoch == cluster.epoch:
                        # The evaluation is a pure function of the selected
                        # capacity vector; skip it when that provably
                        # didn't change.  (Sound under hetero_selection
                        # too: the epoch covers every free-count and
                        # speed change the scored choice reads.)
                        continue
                    caps = consolidating_caps(g)
                    sp = speeds_for(caps) if speeds_for else None
                    # a_min joins the skip signature: the degradation-aware
                    # bound shifts with speed changes *outside* the
                    # selected caps, so equal (caps, speeds) alone no
                    # longer implies an equal decision (clean runs see a
                    # constant — skip behavior there is unchanged)
                    _, a_min = self.alpha_cache.bounds(d.job, bcluster)
                    if not expired:
                        d.eval_epoch = cluster.epoch
                        if not self._hetero_sel:
                            if (caps, sp, a_min) == d.eval_caps:
                                continue  # same caps+speeds+bound -> same decision
                            d.eval_caps = (caps, sp, a_min)
                        # hetero_selection reads the *whole* free state:
                        # an equal default pick no longer implies an equal
                        # decision, so only the epoch skip applies
                    key = (d.job.config_key, g)
                    hit = memo.get(key)
                    if hit is None:
                        if self._hetero_sel:
                            hit = self._scored_consolidating(
                                d.job, cluster, bw_ranks, speeds_for,
                                caps, sp,
                            )
                        else:
                            placement, a = self._map(d.job, caps, sp)
                            hit = (a, placement, caps, sp)
                        memo[key] = hit
                    a, placement, caps, sp = hit
                else:
                    caps = tuple(
                        select_servers(
                            cluster.free, g,
                            consolidate=True, spec=spec,
                            ranks=bw_ranks,
                        )
                    )
                    sp = speeds_for(caps) if speeds_for else None
                    if self._hetero_sel:
                        a, placement, caps, sp = self._scored_consolidating(
                            d.job, cluster, bw_ranks, speeds_for, caps, sp
                        )
                    else:
                        placement, a = self._map(d.job, caps, sp)
                    _, a_min = self.alpha_cache.bounds(d.job, bcluster)
                if a < d.kappa or a / a_min <= self.comm_heavy or expired:
                    del self.delayed[jid]
                    starts.append(
                        Start(d.job, placement, a, n_pred=self._n_pred(d.job))
                    )
                    cluster.allocate(jid, placement, counts=dict(caps))
                    # free capacity changed: drop every per-state structure
                    ladder.reset()
                    memo = {}
                # else: stay delayed

        # Step 3: Alg. 1 main loop over the head of pending_queue.  The
        # consolidating snapshot stays valid across heads that delay
        # (delaying changes nothing) and is dropped on every allocation.
        while self.pending:
            job = self.pending[0]
            if job.g > cluster.total_free:
                break  # head-of-line blocking (Alg. 1 line 25)
            self.pending.popleft()
            a_max, a_min = self.alpha_cache.bounds(job, bcluster)
            if a_max / a_min >= self.comm_heavy:
                if incremental:
                    caps = consolidating_caps(job.g)
                else:
                    caps = tuple(
                        select_servers(
                            cluster.free, job.g,
                            consolidate=True, spec=spec,
                            ranks=bw_ranks,
                        )
                    )
                sp = speeds_for(caps) if speeds_for else None
                if self._hetero_sel:
                    a, placement, caps, sp = self._scored_consolidating(
                        job, cluster, bw_ranks, speeds_for, caps, sp
                    )
                else:
                    placement, a = self._map(job, caps, sp)
                delay_budget = self.tau * self._pred_work[job.job_id]
                if a / a_min <= self.comm_heavy or delay_budget <= 0.0:
                    starts.append(
                        Start(job, placement, a, n_pred=self._n_pred(job))
                    )
                    cluster.allocate(job.job_id, placement, counts=dict(caps))
                    ladder.reset()
                else:
                    d = _Delayed(job, kappa=a, deadline=t + delay_budget)
                    # Seed with this evaluation: caps were selected at the
                    # current cluster state, so step 2 can skip until the
                    # state (and the resulting caps or the admission
                    # bound) actually changes.
                    d.eval_epoch = cluster.epoch
                    d.eval_caps = (caps, sp, a_min)
                    self.delayed[job.job_id] = d
                    heapq.heappush(self._dheap, (d.deadline, job.job_id))
            else:
                if incremental:
                    caps = select_servers(
                        cluster.free, job.g,
                        consolidate=False, spec=spec,
                        buckets=cluster.free_buckets,
                        total_free=cluster.total_free,
                        ranks=bw_ranks,
                    )
                else:
                    caps = select_servers(
                        cluster.free, job.g,
                        consolidate=False, spec=spec,
                        ranks=bw_ranks,
                    )
                sp = speeds_for(caps) if speeds_for else None
                placement, a = self._map(job, caps, sp)
                starts.append(
                    Start(job, placement, a, n_pred=self._n_pred(job))
                )
                cluster.allocate(job.job_id, placement, counts=dict(caps))
                ladder.reset()

        # A pass that started nothing left the cluster exactly as it found
        # it; record the epoch so step 2 can skip until something changes.
        self._settled_epoch = cluster.epoch if not starts else -1
        return starts

    def next_wakeup(self, t: float) -> Optional[float]:
        eps = 1e-9 * max(1.0, abs(t))
        best: Optional[float] = None
        if not self.pending:
            # With a non-empty pending queue the head did not fit (strict
            # head-of-line), and virtual completions only append behind it —
            # a wake could not start anything, so don't schedule one.
            nxt = self.vm.next_completion_time()
            if nxt is not None:
                # The vm holds finite work; a completion at/behind t is
                # float-ulp residue — nudge once so it drains. (Bounded: the
                # residue job completes on that wake.)
                best = max(nxt, t + 1e-6)
        # Earliest future delay-deadline.  Entries already expired can only
        # start after a *real* completion event — never wake for them (a
        # nudge would busy-loop); prune so they don't mask later deadlines.
        h = self._dheap
        delayed = self.delayed
        while h:
            dl, jid = h[0]
            if jid not in delayed:
                heapq.heappop(h)  # stale: job started
                continue
            if dl <= t + eps:
                heapq.heappop(h)  # expired: real events drive it from here
                continue
            if best is None or dl < best:
                best = dl
            break
        return best

    def migration_queue_head(self, t: float) -> Optional[JobSpec]:
        """Queue-aware migration guard hook: the next job ``plan_pass``
        would pop.  The virtual machine is drained to ``t`` first so
        jobs whose virtual completion already passed are visible — the
        hook runs before the pass that would release them for real."""
        self._drain_vm(t)
        return self.pending[0] if self.pending else None

    def plan_preemptions(
        self, t: float, cluster: ClusterState, candidates, gpus_needed: int
    ):
        """Serving-lane preemption (ISSUE 9): pick comm-heavy victims.

        Only communication-heavy jobs (``alpha_max / alpha~_min >=
        comm_heavy`` — the same classification Alg. 1 consolidates and
        delays by) are evictable: they make the worst use of the GPUs a
        latency-bound replica needs, and their checkpoint-restart cost
        amortizes over the longest remaining runtimes.  Victims are
        ordered longest-predicted-remaining-first (remaining iterations
        x current alpha; job id breaks ties), and the list is truncated
        to the prefix whose hypothetically freed capacity first gives
        *some* active server ``gpus_needed`` free GPUs — if even evicting
        every comm-heavy job cannot host the replica, nothing is
        preempted (pointless evictions would only stretch training flow
        time).  The simulator owns the actual eviction (release +
        :meth:`on_preemption`); no allocations change here.
        """
        heavy = []
        for r in candidates:
            a_max, a_min = self.alpha_cache.bounds(r.job)
            if a_max / a_min >= self.comm_heavy:
                heavy.append(r)
        if not heavy:
            return []
        heavy.sort(key=lambda r: (-(r.iters_rem * r.alpha), r.job.job_id))
        inactive = cluster.downed_servers | cluster.draining_servers
        free = {
            m: f for m, f in cluster.free.items() if m not in inactive
        }
        out = []
        for r in heavy:
            out.append(r)
            for m, x in r.placement.items():
                if m in free:
                    free[m] += int(np.asarray(x).sum())
            if any(f >= gpus_needed for f in free.values()):
                return out
        return []

    def on_preemption(self, t: float, job: JobSpec) -> None:
        """An evicted job re-enters at the *head* of the release queue: it
        already virtually completed (that is why it was running), so it
        outranks everything the virtual machine has yet to release."""
        self.pending.appendleft(job)

    def queue_depth(self) -> int:
        return len(self.pending) + len(self.delayed)
