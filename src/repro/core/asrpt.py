"""A-SRPT: adaptive shortest-remaining-processing-time-first (paper Alg. 1).

Pipeline of decisions per scheduling event:

1. advance the virtual single machine (instance A1-tilde, preemptive SRPT on
   predicted scaled work ``(g_i/G) n~_i alpha~_i^min``) to the current time;
   newly (virtually) completed jobs join ``pending_queue`` in completion
   order — this is the release order for the real cluster;
2. re-evaluate *delayed* communication-heavy jobs: start if the achievable
   per-iteration time improved (``alpha < kappa``), dropped under the
   COMM_HEAVY ratio, or the delay budget ``tau (g_i/G) n~_i alpha~_i^min``
   expired;
3. pop the head of ``pending_queue`` while it fits:
   - communication-heavy (``alpha_max / alpha~_min >= COMM_HEAVY``): place on
     the *most*-available servers (consolidation); if still comm-heavy,
     delay (step 2 takes over);
   - otherwise: place on the *least*-available servers (fragmentation-aware)
     and start immediately.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional

from .cluster import ClusterState
from .heavy_edge import map_job, select_servers
from .job import ClusterSpec, JobSpec
from .predictor import IterationPredictor
from .simulator import AlphaCache, Policy, Start
from .srpt import VirtualSRPT

COMM_HEAVY_DEFAULT = 1.5


class _Delayed:
    __slots__ = ("job", "kappa", "deadline")

    def __init__(self, job: JobSpec, kappa: float, deadline: float):
        self.job = job
        self.kappa = kappa
        self.deadline = deadline


class ASRPTPolicy(Policy):
    def __init__(
        self,
        predictor: IterationPredictor,
        comm_heavy: float = COMM_HEAVY_DEFAULT,
        tau: float = 2.0,
        refine_mapping: bool = False,  # beyond-paper local-search swaps
    ):
        self.predictor = predictor
        self.comm_heavy = comm_heavy
        self.tau = tau
        self.refine_mapping = refine_mapping
        self.vm = VirtualSRPT()
        self.pending: Deque[JobSpec] = deque()
        self.delayed: "OrderedDict[int, _Delayed]" = OrderedDict()
        self._by_id: Dict[int, JobSpec] = {}
        self._pred_work: Dict[int, float] = {}

    def bind(self, cluster_spec: ClusterSpec) -> None:
        super().bind(cluster_spec)
        self.alpha_cache = AlphaCache(cluster_spec)

    # -- event hooks --------------------------------------------------------

    def on_arrival(self, t: float, job: JobSpec) -> None:
        n_pred = self.predictor.predict(job)
        _, a_min = self.alpha_cache.bounds(job)
        g_frac = job.g / self.cluster_spec.total_gpus
        work = g_frac * n_pred * a_min
        self._by_id[job.job_id] = job
        self._pred_work[job.job_id] = work
        self.vm.arrive(t, job.job_id, work)
        self._drain_vm(t)

    def on_completion(self, t: float, job: JobSpec) -> None:
        self.predictor.observe(job, job.n_iters)

    def _drain_vm(self, t: float) -> None:
        for _ct, jid in self.vm.advance(t):
            self.pending.append(self._by_id[jid])

    # -- placement helpers ---------------------------------------------------

    def _place(self, job: JobSpec, cluster: ClusterState, consolidate: bool):
        caps = select_servers(cluster.free, job.g, consolidate=consolidate)
        return map_job(
            job, caps, self.cluster_spec, refine=self.refine_mapping
        )

    # -- main scheduling pass -------------------------------------------------

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        self._drain_vm(t)
        starts: List[Start] = []

        # Step 2: re-evaluate delayed communication-heavy jobs (Alg. 1 l.16-19).
        for jid in list(self.delayed.keys()):
            d = self.delayed[jid]
            if d.job.g > cluster.total_free:
                continue  # cannot fit yet; keep waiting
            placement, a = self._place(d.job, cluster, consolidate=True)
            _, a_min = self.alpha_cache.bounds(d.job)
            if (
                a < d.kappa
                or a / a_min <= self.comm_heavy
                or t >= d.deadline - 1e-12
            ):
                del self.delayed[jid]
                starts.append(Start(d.job, placement, a))
                cluster.allocate(jid, placement)  # reserve within this pass
            # else: stay delayed

        # Step 3: Alg. 1 main loop over the head of pending_queue.
        while self.pending:
            job = self.pending[0]
            if job.g > cluster.total_free:
                break  # head-of-line blocking (Alg. 1 line 25)
            self.pending.popleft()
            a_max, a_min = self.alpha_cache.bounds(job)
            if a_max / a_min >= self.comm_heavy:
                placement, a = self._place(job, cluster, consolidate=True)
                delay_budget = self.tau * self._pred_work[job.job_id]
                if a / a_min <= self.comm_heavy or delay_budget <= 0.0:
                    starts.append(Start(job, placement, a))
                    cluster.allocate(job.job_id, placement)
                else:
                    self.delayed[job.job_id] = _Delayed(
                        job, kappa=a, deadline=t + delay_budget
                    )
            else:
                placement, a = self._place(job, cluster, consolidate=False)
                starts.append(Start(job, placement, a))
                cluster.allocate(job.job_id, placement)

        # The simulator re-allocates; undo our in-pass reservations.
        for s in starts:
            cluster.release(s.job.job_id)
        return starts

    def next_wakeup(self, t: float) -> Optional[float]:
        eps = 1e-9 * max(1.0, abs(t))
        candidates = []
        nxt = self.vm.next_completion_time()
        if nxt is not None:
            # The vm holds finite work; a completion at/behind t is float-ulp
            # residue — nudge once so it drains. (Bounded: the residue job
            # completes on that wake.)
            candidates.append(max(nxt, t + 1e-6))
        for d in self.delayed.values():
            # Past-deadline delayed jobs that still do not fit can only
            # start after a *real* completion event — never wake for them
            # (a nudge here would busy-loop at +1e-6 forever).
            if d.deadline > t + eps:
                candidates.append(d.deadline)
        return min(candidates) if candidates else None
