"""Event-driven cluster simulator for online non-preemptive scheduling.

The paper's Algorithm 1 iterates unit time-slots; cluster state only changes
at job arrivals/completions (plus the comm-heavy delay deadlines), so we
advance event-to-event — the schedule produced is identical while remaining
tractable for 10^5-job traces.  ``tests/test_asrpt.py`` cross-checks against
a literal slotted execution on small instances.

Policies observe only online information: arrivals as they happen, true
iteration counts only at completion (fed to the predictor).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .cluster import ClusterState
from .job import ClusterSpec, JobSpec
from . import timing

_COMPLETION, _ARRIVAL, _WAKE = 0, 1, 2


@dataclass
class Start:
    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float


@dataclass
class JobRecord:
    arrival: float
    start: float
    completion: float
    alpha: float
    servers: Tuple[int, ...]


@dataclass
class SimResult:
    records: Dict[int, JobRecord] = field(default_factory=dict)

    @property
    def total_completion_time(self) -> float:
        return sum(r.completion for r in self.records.values())

    @property
    def total_flow_time(self) -> float:
        return sum(r.completion - r.arrival for r in self.records.values())

    @property
    def makespan(self) -> float:
        return max(r.completion for r in self.records.values())

    @property
    def mean_jct(self) -> float:
        return self.total_flow_time / max(len(self.records), 1)


class Policy:
    """Scheduling policy interface (see asrpt.py / baselines.py)."""

    def bind(self, cluster_spec: ClusterSpec) -> None:
        self.cluster_spec = cluster_spec

    def on_arrival(self, t: float, job: JobSpec) -> None:
        raise NotImplementedError

    def on_completion(self, t: float, job: JobSpec) -> None:
        pass

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        raise NotImplementedError

    def next_wakeup(self, t: float) -> Optional[float]:
        return None


def simulate(
    jobs: List[JobSpec],
    cluster_spec: ClusterSpec,
    policy: Policy,
) -> SimResult:
    for job in jobs:
        if job.g > cluster_spec.total_gpus:
            raise ValueError(
                f"job {job.job_id} needs {job.g} GPUs, cluster has "
                f"{cluster_spec.total_gpus}"
            )
    policy.bind(cluster_spec)
    cluster = ClusterState(cluster_spec)
    result = SimResult()

    seq = itertools.count()
    events: List[Tuple[float, int, int, Optional[JobSpec]]] = []
    for job in jobs:
        heapq.heappush(events, (job.arrival, _ARRIVAL, next(seq), job))

    n_completed = 0
    scheduled_wakes: set = set()

    while events:
        t = events[0][0]
        # Drain all events at time t (completions sort before arrivals).
        while events and events[0][0] == t:
            _, kind, _, job = heapq.heappop(events)
            if kind == _COMPLETION:
                assert job is not None
                cluster.release(job.job_id)
                policy.on_completion(t, job)
                n_completed += 1
            elif kind == _ARRIVAL:
                assert job is not None
                policy.on_arrival(t, job)
            else:  # _WAKE: no state change; just triggers a scheduling pass.
                scheduled_wakes.discard(t)

        for start in policy.schedule(t, cluster):
            job = start.job
            timing.validate_placement(job, start.placement)
            cluster.allocate(job.job_id, start.placement)
            completion = t + job.n_iters * start.alpha
            result.records[job.job_id] = JobRecord(
                arrival=job.arrival,
                start=t,
                completion=completion,
                alpha=start.alpha,
                servers=tuple(sorted(timing.servers_touched(start.placement))),
            )
            heapq.heappush(
                events, (completion, _COMPLETION, next(seq), job)
            )

        wake = policy.next_wakeup(t)
        if wake is not None and wake > t and wake not in scheduled_wakes:
            heapq.heappush(events, (wake, _WAKE, next(seq), None))
            scheduled_wakes.add(wake)

    if n_completed != len(jobs):
        missing = len(jobs) - n_completed
        raise RuntimeError(f"simulation ended with {missing} unfinished jobs")
    return result


# ---------------------------------------------------------------------------
# Shared helpers: per-config alpha bounds cache
# ---------------------------------------------------------------------------


class AlphaCache:
    """alpha_max / alpha-tilde_min per unique (stages, allreduce) config."""

    def __init__(self, cluster_spec: ClusterSpec):
        self.spec = cluster_spec
        self._cache: Dict[tuple, Tuple[float, float]] = {}

    def bounds(self, job: JobSpec) -> Tuple[float, float]:
        """Returns (alpha_max, alpha_min_tilde)."""
        key = (job.stages, job.allreduce)
        hit = self._cache.get(key)
        if hit is None:
            from . import heavy_edge as he  # local import to avoid cycle

            a_max = timing.alpha_max(job, self.spec)
            a_min = he.alpha_min_estimate(job, self.spec)
            # The consolidated estimate can only be <= the all-spread bound.
            a_max = max(a_max, a_min)
            hit = (a_max, a_min)
            self._cache[key] = hit
        return hit
