"""Event-driven cluster simulator for online non-preemptive scheduling.

The paper's Algorithm 1 iterates unit time-slots; cluster state only changes
at job arrivals/completions (plus the comm-heavy delay deadlines), so we
advance event-to-event — the schedule produced is identical while remaining
tractable for 10^5-job traces.  ``tests/test_asrpt.py`` cross-checks against
a literal slotted execution on small instances.

``simulate(scenario, policy)`` is the one entry point: a
:class:`~repro.core.scenario.Scenario` bundles the workload, the cluster
spec, and a single canonical timeline of typed cluster events (faults,
degradations, elastic ServerJoin/ServerLeave — see scenario.py).  The
legacy ``simulate(jobs, spec, faults=..., degradations=...)`` signature
is kept as a thin shim that builds a ``Scenario``; it is property-tested
bit-identical (tests/test_scenario.py) and the golden fixtures
(tests/golden/) pin it byte-for-byte.  Same-timestamp events apply in
the scenario's canonical ``(t, server, kind, magnitude)`` order — not in
caller interleaving order (the PR-5 tie-break fix; scenario.py
documents the ranking).

Hot-path design (trace scale):

* policies *own* their allocations: ``plan_pass`` allocates on the live
  ``ClusterState`` and the simulator only releases on completion.  (The old
  protocol had each pass allocate, undo, and the simulator re-allocate —
  three O(placement) dict walks per start, and the undo releases defeated
  the release-epoch change tracking policies use to skip recomputation.)
* wake-ups are epoch-tagged: at most one *live* wake event exists at a
  time; superseded wakes stay in the heap but are recognised as stale by
  their epoch and skipped without a scheduling pass.
* all events at the same timestamp are drained before a single scheduling
  pass runs.

Policies observe only online information: arrivals as they happen, true
iteration counts only at completion (fed to the predictor).

Degradation events (stragglers): a ``Degradation(t, server, factor)``
scales a server's effective speed mid-run (see cluster.py / timing.py).
Running jobs touching the server are *re-timed*: their remaining
iterations are brought to ``t`` under the old alpha, a new alpha is
evaluated under the updated speed map, and the completion event is
re-issued.  Completion events are therefore epoch-tagged per job (like
wakes): superseded completions stay in the heap and are dropped on pop.
A ``factor == 0.0`` event takes the PR-2 fault path verbatim (capacity
forfeited, running jobs finish in place, no re-timing) — ``Fault`` is
the same event, and the legacy ``faults=`` keyword is sugar for it.
After re-timing, the policy's ``plan_migrations`` hook may
checkpoint-restart affected jobs onto fresh capacity (see migration.py);
the simulator re-times migrated jobs with the restart penalty and
updates their records in place.

Elastic capacity: a ``ServerLeave(t, server, drain_timeout)`` starts a
graceful drain — no new allocations; while the window is open, jobs
still running on the leaving server join the migration watch (a
migrating policy can checkpoint-restart them off before the server
disappears; for an undegraded drain the race only moves a job whose
fresh placement beats its current one by more than the penalty).  At
``t + drain_timeout`` the server is gone for good — jobs still on it
finish in place, PR-2 style.  ``drain_timeout == 0`` *is* the fault
path (property-tested equal).  A ``ServerJoin(t, server)`` brings an
inactive slot online (class capacity minus GPUs still held by running
jobs); the epoch bump wakes settled policies so queued work starts on
the new capacity in the same pass.
"""
from __future__ import annotations

import hashlib
import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import (
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    Union,
    runtime_checkable,
)

import numpy as np

from .cluster import ClusterState
from .job import ClusterSpec, JobSpec
from .scenario import (
    ClusterEvent,
    Degradation,
    Fault,
    JobStream,
    Scenario,
    ServerJoin,
    ServerLeave,
    scenario_from_legacy,
)
from . import timing

# Completions free capacity and cluster events (faults, degradations,
# joins/leaves) change it before arrivals/wakes at the same timestamp
# trigger the scheduling pass.
_COMPLETION, _CLUSTER, _ARRIVAL, _WAKE = 0, 1, 2, 3


@dataclass(slots=True)
class Allocation:
    """One placement decision returned by ``Policy.plan_pass``.

    The policy has already called ``cluster.allocate`` for it (policies
    own their allocations); the simulator only computes the completion
    and releases on it.

    ``n_pred`` is the predicted iteration count the decision was made
    with (prediction_loop): when set — policies attach it only when
    their predictor tracks overruns — the simulator watches for the job
    running past ``t + n_pred * alpha`` and asks the policy to
    re-estimate there.  ``None`` (the default, and what every
    pre-prediction-loop policy produces) means nothing is watched; the
    physical completion is always timed with the *true* ``job.n_iters``
    either way.
    """

    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float
    n_pred: Optional[float] = None


# Historical name (PR 1-4); same type.
Start = Allocation


@dataclass(slots=True)
class Migration:
    """A checkpoint-restart decision returned by ``Policy.plan_migrations``.

    The policy has already released the job's old allocation and allocated
    ``placement`` (policies own their allocations, as with ``Allocation``);
    the simulator re-times the job: remaining iterations resume at
    ``alpha`` after ``penalty`` seconds of checkpoint-restart downtime.
    """

    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float
    penalty: float


@dataclass(slots=True)
class JobRecord:
    arrival: float
    start: float
    completion: float
    alpha: float
    servers: Tuple[int, ...]
    migrations: int = 0


@dataclass(slots=True)
class _Running:
    """Live bookkeeping for one started job (degradation re-timing).

    ``iters_rem`` is the remaining iteration count as of ``since`` —
    which is the time the job last (re)started *computing*: after a
    migration ``since`` sits at ``t + penalty``, so the checkpoint-
    restart downtime is never credited as productive work if another
    event re-times the job mid-restart (re-timings subtract elapsed
    iterations only for ``t > since``).  The live completion event
    carries ``epoch`` — re-timing bumps it, turning the superseded event
    into a stale heap entry.  Instances double as the read-only views
    handed to ``Policy.plan_migrations``.

    ``pred_rem`` mirrors ``iters_rem`` for the *predicted* iteration
    count (prediction_loop): decremented in lockstep at every
    elapsed-iteration subtraction, re-set by mid-flight re-estimation.
    ``pred_epoch`` tags the one live predicted-completion check event
    the way ``epoch`` tags the completion — superseded checks stay in
    the heap and are dropped on pop.  ``None`` (any start without
    ``n_pred``) disables the watch for this job.
    """

    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float
    iters_rem: float
    since: float
    epoch: int = 0
    pred_rem: Optional[float] = None
    pred_epoch: int = 0


@dataclass(slots=True)
class _DrainDeadline:
    """Internal event: a ServerLeave drain window closes (not part of the
    scenario schema — synthesized when the leave is applied).  ``gen``
    is the per-server drain generation at synthesis: a join cancelling
    the drain and a later leave re-opening it would otherwise let this
    stale deadline close the *new* window early (like wake/completion
    events, stale entries stay in the heap and are dropped on pop)."""

    server: int
    gen: int


@dataclass(slots=True)
class _PredCheck:
    """Internal event: a watched job reached its *predicted* completion
    while still running (prediction_loop).  Rides the ``_CLUSTER`` lane
    (after completions at the same timestamp — a job finishing exactly
    on its prediction needs no re-estimate) but, like
    :class:`_DrainDeadline`, never reaches ``Policy.on_event``: the
    simulator consumes it, brings the job's bookkeeping to ``t``, asks
    ``policy.on_overrun`` for a fresh predicted-remaining, and re-arms
    the check there.  ``epoch`` is the job's ``pred_epoch`` at push
    time; any re-timing bumps it, so superseded checks are dropped on
    pop."""

    job_id: int
    epoch: int


@dataclass(slots=True)
class _ReqArrival:
    """Internal event: the next request of one serving lane arrives.  One
    live instance per lane rides the heap (re-pushed at the following
    arrival time as each pops), so the heap stays bounded by live events
    even for million-request streams.  Never reaches ``Policy.on_event``.
    """

    lane: int


@dataclass(slots=True)
class _BatchDone:
    """Internal event: a serving replica finishes its in-flight batch.
    The batch's request arrival times live on the lane (bounded by
    ``max_batch * max_replicas``); latencies fold into the result's
    bounded estimators at pop.  Never reaches ``Policy.on_event``."""

    lane: int
    replica: int


@dataclass(slots=True)
class _Resume:
    """Checkpoint state of a preempted training job awaiting restart:
    remaining iterations at eviction, the epoch its restarted completion
    event must carry (old epoch + 1, so the stale pre-preemption
    completion is dropped on pop), the ``pred_epoch`` to continue from
    (same staleness argument for in-flight prediction checks), and the
    original :class:`JobRecord` — a restart updates it in place, so
    ``arrival`` and first ``start`` survive and the eviction counts as a
    migration."""

    iters_rem: float
    epoch: int
    pred_epoch: int
    rec: "JobRecord"


_DIGEST_MOD = 1 << 256

# Flow-time quantiles the streaming backend tracks with bounded-memory
# estimators (quantile.py) — the tail metrics the serving/prediction
# gates read.  Exact (bit-identical to the materialized formula) while
# the completed-job count fits the estimator buffer (8192), uniform-
# reservoir approximate beyond.
STREAM_FLOW_QUANTILES = (50.0, 95.0, 99.0)

# Request-latency quantiles the serving lane tracks.  Request counts are
# unbounded (million-request streams), so latencies always go through
# the bounded estimators — even on materialized runs.
SERVE_LAT_QUANTILES = (50.0, 99.0)


def _record_digest(jid: int, r: JobRecord) -> int:
    """sha256 of one per-job record line, as an integer.

    ``repr`` of the floats keeps the line exact (shortest round-trip
    repr) and platform-stable for the matmul-free engines.  The
    per-record hashes combine by *summation* mod 2^256 (see
    ``SimResult.schedule_digest``), so the streaming backend can fold a
    record the moment its job completes and forget it — no jid-sorted
    walk over an O(jobs) dict."""
    return int.from_bytes(
        hashlib.sha256(
            (
                f"{jid}:{r.start!r}:{r.completion!r}:{r.alpha!r}:"
                f"{r.servers}:{r.migrations}\n"
            ).encode()
        ).digest(),
        "big",
    )


def _msum_add(partials: List[float], x: float) -> None:
    """Shewchuk growth step: add ``x`` to the non-overlapping partial-sum
    list in place.  ``math.fsum(partials)`` afterwards equals
    ``math.fsum`` over every value ever added — exactly, in any insertion
    order — which is what makes the streaming backend's flow-time sums
    bit-identical to the materialized path's ``fsum`` over records."""
    i = 0
    for y in partials:
        if abs(x) < abs(y):
            x, y = y, x
        hi = x + y
        lo = y - (hi - x)
        if lo:
            partials[i] = lo
            i += 1
        x = hi
    partials[i:] = [x]


@dataclass
class SimResult:
    """Per-job schedule records + engine stats — or, in streaming mode,
    incremental aggregates over the same records.

    Materialized runs fill ``records`` (job_id -> :class:`JobRecord`).
    Streaming runs (``simulate(..., stream=True)`` or a
    :class:`~repro.core.scenario.JobStream`-backed scenario) set
    ``records = None`` and fold each record into exact aggregates at its
    completion, so memory stays bounded by the *live* job count.  Every
    metric property and ``schedule_digest`` answers identically over
    either backend (property-tested + pinned by the golden fixtures):
    sums are order-independent correctly-rounded ``fsum`` s, and the
    digest is a commutative per-record sum."""

    records: Optional[Dict[int, JobRecord]] = field(default_factory=dict)
    # engine statistics (filled by ``simulate``; benchmarks/sched_scale.py)
    n_events: int = 0
    n_sched_passes: int = 0
    peak_queue_depth: int = 0
    n_migrations: int = 0
    # mid-flight prediction re-estimates (prediction_loop): 0 for oracle
    # and for every policy that doesn't track overruns
    n_reestimates: int = 0
    wall_s: float = 0.0
    n_jobs: int = 0
    # streaming aggregates (used when records is None): Shewchuk partial
    # sums, running max, the commutative digest accumulator, and
    # bounded-memory flow-time quantile estimators (quantile.py)
    _flow_parts: List[float] = field(default_factory=list)
    _comp_parts: List[float] = field(default_factory=list)
    _max_completion: float = 0.0
    _digest_acc: int = 0
    _flow_q: Optional[Dict[float, "StreamingQuantile"]] = None
    # serving-lane aggregates (ISSUE 9): request counts/latencies fold at
    # each batch completion (requests never materialize), training-job
    # preemptions for serving replicas count here
    n_requests: int = 0
    n_slo_met: int = 0
    n_preemptions: int = 0
    _req_lat_parts: List[float] = field(default_factory=list)
    _req_q: Optional[Dict[float, "StreamingQuantile"]] = None

    def _fold_request(self, latency: float, slo: float) -> None:
        """Stream one served request into the serving aggregates."""
        self.n_requests += 1
        if latency <= slo:
            self.n_slo_met += 1
        _msum_add(self._req_lat_parts, latency)
        if self._req_q is None:
            from .quantile import StreamingQuantile

            self._req_q = {
                q: StreamingQuantile(q) for q in SERVE_LAT_QUANTILES
            }
        for est in self._req_q.values():
            est.add(latency)

    @property
    def slo_attainment(self) -> float:
        """Fraction of requests served within their stream's SLO (1.0 on
        runs without requests — an empty serving lane violates nothing)."""
        return self.n_slo_met / self.n_requests if self.n_requests else 1.0

    @property
    def mean_request_latency(self) -> float:
        return math.fsum(self._req_lat_parts) / max(self.n_requests, 1)

    def request_latency_percentile(self, q: float) -> float:
        """Request-latency percentile over the tracked quantiles
        (``SERVE_LAT_QUANTILES``: p50/p99), answered by the bounded
        estimators — exact below the 8192-request buffer, uniform-
        reservoir approximate beyond (quantile.py documents the bound).
        0.0 on runs without requests; untracked quantiles raise."""
        est = (self._req_q or {}).get(float(q))
        if est is not None:
            return est.value()
        if self._req_q is None:
            return 0.0
        raise RuntimeError(
            f"serving runs track only the {sorted(self._req_q)} request-"
            f"latency percentiles; q={q} is not tracked"
        )

    def _fold(self, jid: int, rec: JobRecord) -> None:
        """Stream one completed record into the aggregates (after this
        the record can be forgotten)."""
        flow = rec.completion - rec.arrival
        _msum_add(self._flow_parts, flow)
        _msum_add(self._comp_parts, rec.completion)
        if rec.completion > self._max_completion:
            self._max_completion = rec.completion
        if self._flow_q is None:
            from .quantile import StreamingQuantile

            self._flow_q = {
                q: StreamingQuantile(q) for q in STREAM_FLOW_QUANTILES
            }
        for est in self._flow_q.values():
            est.add(flow)
        self._digest_acc = (
            self._digest_acc + _record_digest(jid, rec)
        ) % _DIGEST_MOD

    @property
    def total_completion_time(self) -> float:
        if self.records is None:
            return math.fsum(self._comp_parts)
        return math.fsum(r.completion for r in self.records.values())

    @property
    def total_flow_time(self) -> float:
        if self.records is None:
            return math.fsum(self._flow_parts)
        return math.fsum(r.completion - r.arrival for r in self.records.values())

    @property
    def makespan(self) -> float:
        if self.records is None:
            return self._max_completion
        # guard the empty case like mean_jct (max() raises on no records)
        if not self.records:
            return 0.0
        return max(r.completion for r in self.records.values())

    @property
    def mean_jct(self) -> float:
        n = self.n_jobs if self.records is None else len(self.records)
        return self.total_flow_time / max(n, 1)

    @property
    def events_per_sec(self) -> float:
        return self.n_events / self.wall_s if self.wall_s > 0 else float("nan")

    def flow_percentile(self, q: float) -> float:
        """Per-job flow-time percentile (linear interpolation, numpy's
        default definition).

        Materialized runs sort the records exactly.  Streaming runs fold
        records away, so the tracked quantiles (``STREAM_FLOW_QUANTILES``:
        p50/p95/p99) are answered by bounded-memory estimators
        (quantile.py): *exact and bit-identical* to this method's
        materialized formula while the completed-job count fits the
        estimator buffer (8192), uniform-reservoir approximate beyond
        (documented bound: within ~10 % relative on heavy-tailed flows,
        typically ~1 %).  Untracked quantiles on a streaming run
        raise."""
        if self.records is None:
            est = (self._flow_q or {}).get(float(q))
            if est is not None:
                return est.value()
            if self._flow_q is None and self.n_jobs == 0:
                return 0.0
            raise RuntimeError(
                f"streaming runs track only the "
                f"{sorted(self._flow_q or STREAM_FLOW_QUANTILES)} flow "
                f"percentiles; q={q} needs a materialized run "
                f"(stream=False)"
            )
        if not self.records:
            return 0.0
        flows = sorted(r.completion - r.arrival for r in self.records.values())
        if len(flows) == 1:
            return flows[0]
        pos = (q / 100.0) * (len(flows) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(flows) - 1)
        return flows[lo] + (pos - lo) * (flows[hi] - flows[lo])

    def schedule_digest(self) -> str:
        """Byte-identity fingerprint over every per-job record — what the
        golden harness (tests/test_golden.py) and ``sched_scale
        --scenario`` replays compare.  Per-record sha256 values are
        summed mod 2^256 (hex-formatted to the usual 64 chars): the sum
        commutes, so the streaming backend folds records at completion
        time in completion order, the materialized backend in dict
        order, and both land on the same digest."""
        if self.records is None:
            acc = self._digest_acc
        else:
            acc = 0
            for jid, r in self.records.items():
                acc = (acc + _record_digest(jid, r)) % _DIGEST_MOD
        return f"{acc:064x}"


@runtime_checkable
class SchedulingPolicy(Protocol):
    """The formal policy contract ``simulate`` drives (third-party
    policies implement this; ``Policy`` below is the in-tree base with
    default no-ops).  Lifecycle per simulation:

    1. ``bind(cluster_spec)`` once, before any event;
    2. per event timestamp, after state changes apply:
       ``on_arrival``/``on_completion`` for job events, ``on_event`` for
       every cluster event (fault/degradation/join/leave);
    3. ``plan_migrations(t, cluster, candidates)`` — only while migration
       candidates exist and ``migrate`` is truthy;
    4. ``plan_pass(t, cluster)`` — the scheduling pass; returns
       ``Allocation`` s the policy has already allocated on ``cluster``;
    5. ``next_wakeup(t)`` — optional future self-wake.
    """

    migrate: bool

    def bind(self, cluster_spec: ClusterSpec) -> None: ...

    def on_arrival(self, t: float, job: JobSpec) -> None: ...

    def on_completion(self, t: float, job: JobSpec) -> None: ...

    def on_event(
        self, t: float, event: ClusterEvent, cluster: ClusterState
    ) -> None: ...

    def plan_pass(self, t: float, cluster: ClusterState) -> List[Allocation]: ...

    def plan_migrations(
        self, t: float, cluster: ClusterState, candidates: List["_Running"]
    ) -> List[Migration]: ...

    def next_wakeup(self, t: float) -> Optional[float]: ...

    def queue_depth(self) -> int: ...


class Policy:
    """Scheduling policy base class (see asrpt.py / baselines.py).

    ``plan_pass`` must ``cluster.allocate`` every returned allocation —
    the allocation is kept (the simulator releases it at the job's
    completion).  ``schedule`` remains from the pre-protocol API both as
    a caller-facing alias and as an override point: a subclass that only
    defines ``schedule`` is still dispatched through it (the simulator
    binds the override when one exists); new code overrides
    ``plan_pass``.
    """

    # Opt-in for the degradation/drain migration hook: the simulator
    # maintains the migration watchlist and calls ``plan_migrations`` only
    # when this is truthy (MigrationMixin exposes it as a constructor arg).
    migrate: bool = False

    # Prediction-loop opt-in (repro.core.prediction_loop): truthy when the
    # policy's predictor wants predicted completions watched.  Policies
    # derive it from ``predictor.track_overruns`` in their constructors
    # (and through ``set_predictor``); the simulator keeps the running-job
    # registry and fires ``on_overrun`` only when it is set, so every
    # pre-prediction-loop policy runs the legacy event sequence byte for
    # byte.
    track_overruns: bool = False

    # Fleet cache sharing (repro.core.fleet): ``run_fleet`` installs a
    # shared-cache provider here before the simulator binds the policy.
    # Subclasses that construct an AlphaCache / PlacementCache in ``bind``
    # do so through the helpers below, so warm cache state — pure
    # functions of the cluster spec and the request key — is amortized
    # across a fleet's variants while all per-run state (queues, virtual
    # machine, allocations, degraded-bounds memos) stays per policy
    # instance.  ``None`` (the default) builds private caches: a lone
    # ``simulate()`` call is byte-for-byte the pre-fleet engine.
    fleet_shared = None

    def bind(self, cluster_spec: ClusterSpec) -> None:
        self.cluster_spec = cluster_spec

    def _make_alpha_cache(self, cluster_spec: ClusterSpec) -> "AlphaCache":
        fs = self.fleet_shared
        if fs is None:
            return AlphaCache(cluster_spec)
        return fs.alpha_cache(cluster_spec)

    def _make_placement_cache(
        self, cluster_spec: ClusterSpec, refine: bool = False
    ):
        fs = self.fleet_shared
        if fs is None:
            from .heavy_edge import PlacementCache  # avoid import cycle

            return PlacementCache(cluster_spec, refine=refine)
        return fs.placement_cache(cluster_spec, refine=refine)

    def set_predictor(self, predictor) -> None:
        """Swap the iteration predictor and re-derive ``track_overruns``.

        The policy-level perturbation hook
        (``Perturbation.perturb_policy``; see
        ``scenario.PredictionNoisePerturbation``) uses this to install a
        per-variant prediction model on a freshly constructed, not yet
        bound policy.
        """
        self.predictor = predictor
        self.track_overruns = bool(getattr(predictor, "track_overruns", False))

    def _n_pred(self, job: JobSpec) -> Optional[float]:
        """Predicted iterations to stamp on an :class:`Allocation` —
        ``None`` unless this policy tracks overruns (keeping legacy
        starts, and therefore the golden schedules, untouched)."""
        if not self.track_overruns:
            return None
        return float(self.predictor.predict(job))

    def on_overrun(self, t: float, job: JobSpec, elapsed_iters: float) -> float:
        """A watched job ran past its predicted completion: return the new
        predicted *remaining* iterations.  The default delegates to the
        predictor's ``reestimate(job, elapsed)`` (the prediction_loop
        backoff contract, returning a new predicted total) and falls back
        to plain doubling; the result is floored at one iteration so the
        re-estimation loop always advances.
        """
        re = getattr(self.predictor, "reestimate", None)
        if re is not None:
            new_total = float(re(job, elapsed_iters))
        else:
            new_total = max(elapsed_iters, 1.0) * 2.0
        return max(new_total - elapsed_iters, 1.0)

    def on_arrival(self, t: float, job: JobSpec) -> None:
        raise NotImplementedError

    def on_completion(self, t: float, job: JobSpec) -> None:
        pass

    def on_event(
        self, t: float, event: ClusterEvent, cluster: ClusterState
    ) -> None:
        """Cluster-event lifecycle hook: called for every scenario event
        at its timestamp, after the cluster state change applied (and
        for no-op events — e.g. a repeated speed factor — so policies
        see the full timeline).  Policies needing custom reactions
        (telemetry, learned schedulers re-planning on capacity churn)
        override this; the default relies on the epoch-based change
        tracking every pass already does.
        """

    def plan_pass(self, t: float, cluster: ClusterState) -> List[Allocation]:
        raise NotImplementedError

    def schedule(self, t: float, cluster: ClusterState) -> List[Allocation]:
        """Pre-protocol alias for ``plan_pass`` (PR 1-4 name)."""
        return self.plan_pass(t, cluster)

    def next_wakeup(self, t: float) -> Optional[float]:
        return None

    def plan_migrations(
        self, t: float, cluster: ClusterState, candidates: List["_Running"]
    ) -> List[Migration]:
        """Migration hook: while any job is running on degraded or
        draining capacity, called before every scheduling pass with
        those jobs as read-only views (so capacity freed by completions
        since the triggering event is still exploitable).  A migrating
        policy releases the old allocation, allocates the new placement,
        and returns a ``Migration`` per moved job (see migration.py);
        the default never migrates.  Only called when ``self.migrate``
        is truthy (non-migrating policies skip the watchlist bookkeeping
        entirely); never called on clean runs.
        """
        return []

    def plan_preemptions(
        self,
        t: float,
        cluster: ClusterState,
        candidates: List["_Running"],
        gpus_needed: int,
    ) -> List["_Running"]:
        """Serving-lane preemption hook (ISSUE 9): a request stream needs
        ``gpus_needed`` GPUs on one server for a replica and no server
        has them free.  ``candidates`` are the running training jobs
        (read-only views; dead-straddlers excluded).  Return victims in
        eviction order — the simulator preempts one at a time (release,
        ``on_preemption`` re-queue) and stops as soon as some server
        fits the replica, so order the cheapest evictions first.
        Unlike ``plan_migrations``, the policy must NOT release or
        allocate here — the simulator owns the eviction.  The default
        never preempts (request backlogs then wait for capacity).
        """
        return []

    def on_preemption(self, t: float, job: JobSpec) -> None:
        """A running job was evicted for a serving replica: re-queue it so
        a later ``plan_pass`` restarts it (the simulator resumes its
        remaining iterations after a checkpoint-restart penalty and
        counts the restart as a migration on its record).  Any policy
        returning victims from ``plan_preemptions`` must implement
        this — a dropped job fails the end-of-run completeness check.
        """
        raise NotImplementedError(
            f"{type(self).__name__} returned preemption victims but does "
            f"not implement on_preemption"
        )

    def migration_queue_head(self, t: float) -> Optional[JobSpec]:
        """Head of the policy's ready queue (the next job a pass would
        start), or None.  Consulted by the queue-aware migration race
        guard (migration.py): a checkpoint-restart claims free capacity
        that the queue head may deserve first.  The base returns None —
        policies without a visible queue never block migrations.
        """
        return None

    def queue_depth(self) -> int:
        """Jobs held by the policy (pending + delayed); for engine stats."""
        return 0


def simulate(
    jobs: Union[Scenario, List[JobSpec]],
    cluster_spec: Optional[Union[ClusterSpec, Policy]] = None,
    policy: Optional[Policy] = None,
    validate: bool = True,
    faults: Optional[Sequence[Tuple[float, int]]] = None,
    degradations: Optional[Sequence[Tuple[float, int, float]]] = None,
    stream: Optional[bool] = None,
) -> SimResult:
    """Run a policy over a scenario; returns per-job records + engine stats.

    Preferred form::

        simulate(scenario, policy)              # Scenario from scenario.py

    Legacy shim (bit-identical; builds the equivalent ``Scenario``)::

        simulate(jobs, cluster_spec, policy, faults=..., degradations=...)

    ``validate=False`` skips the per-start placement re-validation (safety
    net for policy bugs) — benchmarks use it; tests keep it on.

    ``stream`` selects the result backend: ``True`` folds completed
    records into incremental aggregates (``SimResult.records is None``;
    memory bounded by the live job count), ``False`` keeps the full
    per-job record dict.  The default (``None``) streams exactly when
    the scenario's jobs source is a lazy
    :class:`~repro.core.scenario.JobStream`.  Both backends produce the
    same metrics and ``schedule_digest`` bit-for-bit.

    ``faults``: (time, server_id) failure injections — sugar for
    :class:`Fault` events (capacity vanishes; GPUs held by running jobs
    are forfeited on release; the epoch bump wakes incremental policies).
    Jobs whose GPU demand exceeds the degraded capacity can never start;
    the end-of-run unfinished-jobs check reports them.

    ``degradations``: (time, server_id, speed_factor) straggler events —
    sugar for :class:`Degradation`.  ``factor`` in (0, 1) slows the
    server (compute + NIC stretch by ``1/factor``), 1.0 restores it,
    > 1.0 models a boost, and exactly 0.0 is a full failure.  Running
    jobs touching a ``factor > 0`` change are re-timed at the event and
    offered to ``policy.plan_migrations``; a repeated factor equal to
    the server's current speed is a no-op and triggers no scheduling
    pass, so an all-1.0 schedule is bit-identical to the clean run.

    Same-timestamp events apply in the scenario's canonical
    ``(t, server, kind, magnitude)`` order, not input order — see
    scenario.py for the documented tie-break.
    """
    if isinstance(jobs, Scenario):
        if faults is not None or degradations is not None:
            raise TypeError(
                "faults=/degradations= belong to the legacy signature; "
                "encode them as Scenario events instead"
            )
        if policy is not None and cluster_spec is not None:
            raise TypeError(
                "simulate(scenario, policy) takes no cluster spec — the "
                "scenario carries its own cluster"
            )
        pol = policy if policy is not None else cluster_spec
        if not isinstance(pol, Policy) and not isinstance(
            pol, SchedulingPolicy
        ):
            raise TypeError(
                f"simulate(scenario, policy): policy implementing "
                f"SchedulingPolicy required, got {type(pol).__name__}"
            )
        return _simulate_scenario(jobs, pol, validate, stream)
    if not isinstance(policy, Policy) and not isinstance(
        policy, SchedulingPolicy
    ):
        raise TypeError(
            f"simulate(jobs, cluster_spec, policy): policy implementing "
            f"SchedulingPolicy required, got {type(policy).__name__}"
        )
    scenario = scenario_from_legacy(
        jobs, cluster_spec, faults=faults, degradations=degradations
    )
    return _simulate_scenario(scenario, policy, validate, stream)


def _arrival_stream(src: JobStream, total_gpus: int):
    """Validate a lazy jobs source as it is pulled: the per-job GPU-demand
    check the materialized path runs upfront, plus fail-loud time
    ordering — a stream yielding out of arrival order would silently
    corrupt the event heap."""
    last = float("-inf")
    for job in src:
        if job.g > total_gpus:
            raise ValueError(
                f"job {job.job_id} needs {job.g} GPUs, cluster has "
                f"{total_gpus}"
            )
        if job.arrival < last:
            raise ValueError(
                f"job stream out of time order: job {job.job_id} arrives "
                f"at {job.arrival} after {last}"
            )
        last = job.arrival
        yield job


class _ServeLane:
    """Per-stream serving state: the lazy arrival iterator, the FIFO
    backlog of arrival timestamps (memory ∝ current backlog, never the
    stream length), and up to ``max_replicas`` replica slots (hosting
    server, in-flight batch)."""

    __slots__ = ("rs", "it", "queue", "servers", "batch", "idle",
                 "exhausted")

    def __init__(self, rs):
        self.rs = rs
        self.it = rs.arrivals()
        self.queue: deque = deque()  # arrival times awaiting dispatch
        self.servers: List[Optional[int]] = [None] * rs.max_replicas
        self.batch: List[Optional[List[float]]] = [None] * rs.max_replicas
        self.idle: List[int] = []  # allocated, no in-flight batch (sorted)
        self.exhausted = False  # arrival iterator consumed


class _ServeState:
    """Runtime for the serving lanes of one simulation (ISSUE 9).

    Requests and training jobs share the one :class:`ClusterState`:
    replicas allocate real GPUs under reserved negative allocation ids
    (job ids are >= 0), so every replica up scales training capacity
    down and vice versa.  Per lane the driver batches the backlog onto
    idle replicas (batch = min(backlog, max_batch); service time from
    the stream's engine-calibrated curve), scales up — preempting
    comm-heavy training jobs through ``Policy.plan_preemptions`` when no
    server has room — while the projected queue-head latency exceeds
    half the SLO, and releases idle replicas beyond the first back to
    training (the last one once the lane drains).  Serve events trigger
    a policy scheduling pass only when cluster capacity actually changed
    — a million-request stream must not run a million passes.
    """

    def __init__(self, streams, cluster, policy, result, events, seq):
        self.lanes = [_ServeLane(rs) for rs in streams]
        self.cluster: ClusterState = cluster
        self.policy = policy
        self.result: SimResult = result
        self.events = events  # the driver's heap (shared identity)
        self.seq = seq
        self.starved: set = set()  # lanes with a backlog and no replica
        self.resume: Dict[int, _Resume] = {}  # preempted jobs awaiting restart
        self.restart_penalty = float(
            getattr(policy, "migration_penalty", 0.0)
        )
        self._preempt = getattr(policy, "plan_preemptions", None)
        # bound by the driver once its registries exist (bind_runtime)
        self.running: Dict[int, _Running] = {}
        self.records: Dict[int, JobRecord] = {}
        self.migration_watch: set = set()

    def bind_runtime(self, running, records, migration_watch) -> None:
        self.running = running
        self.records = records
        self.migration_watch = migration_watch

    def prime(self) -> None:
        """Arm one arrival event per lane (each re-arms the next on pop)."""
        for li, lane in enumerate(self.lanes):
            nxt = next(lane.it, None)
            if nxt is None:
                lane.exhausted = True
            else:
                heapq.heappush(
                    self.events,
                    (nxt, _CLUSTER, next(self.seq), _ReqArrival(li)),
                )

    def on_arrival(self, payload: _ReqArrival, t: float) -> bool:
        lane = self.lanes[payload.lane]
        lane.queue.append(t)
        nxt = next(lane.it, None)
        if nxt is None:
            lane.exhausted = True
        else:  # re-arm with the same payload object — one live per lane
            heapq.heappush(
                self.events, (nxt, _CLUSTER, next(self.seq), payload)
            )
        return self.dispatch(payload.lane, t)

    def on_batch_done(self, payload: _BatchDone, t: float) -> bool:
        lane = self.lanes[payload.lane]
        fold = self.result._fold_request
        slo = lane.rs.slo
        for arr in lane.batch[payload.replica]:
            fold(t - arr, slo)
        lane.batch[payload.replica] = None
        lane.idle.append(payload.replica)
        lane.idle.sort()
        changed = self.dispatch(payload.lane, t)
        return self._scale_down(payload.lane, t) or changed

    # -- dispatch -----------------------------------------------------------

    def dispatch(self, li: int, t: float) -> bool:
        """Feed idle replicas from the backlog; scale up when the backlog
        threatens the SLO.  Returns True when cluster capacity changed
        (the driver then runs a scheduling pass)."""
        lane = self.lanes[li]
        rs = lane.rs
        changed = False
        while True:
            while lane.queue and lane.idle:
                ridx = lane.idle.pop(0)
                b = min(len(lane.queue), rs.max_batch)
                batch = [lane.queue.popleft() for _ in range(b)]
                lane.batch[ridx] = batch
                heapq.heappush(
                    self.events,
                    (
                        t + rs.service_time(b),
                        _CLUSTER,
                        next(self.seq),
                        _BatchDone(li, ridx),
                    ),
                )
            if not lane.queue:
                self.starved.discard(li)
                return changed
            n_rep = sum(1 for s in lane.servers if s is not None)
            if n_rep >= rs.max_replicas or not self._want_scale(
                lane, t, n_rep
            ):
                self.starved.discard(li)
                return changed
            server = self._find_server(rs.gpus)
            if server is None and self._preempt is not None:
                server = self._preempt_for(t, rs.gpus)
                if server is not None:
                    changed = True
            if server is None:
                # no capacity even after preemption: the backlog waits;
                # re-tried while starved at every live timestamp (training
                # completions free capacity without a serve event)
                if n_rep == 0:
                    self.starved.add(li)
                return changed
            ridx = lane.servers.index(None)
            self.cluster.allocate(
                self._aid(li, ridx), {}, counts={server: rs.gpus}
            )
            lane.servers[ridx] = server
            lane.idle.append(ridx)
            lane.idle.sort()
            changed = True
            # loop: the fresh replica takes a batch immediately

    def _aid(self, li: int, ridx: int) -> int:
        """Reserved allocation id for replica ``ridx`` of lane ``li`` —
        negative, so it can never collide with a job id (>= 0)."""
        return -1 - (li * self.lanes[li].rs.max_replicas + ridx)

    def _want_scale(self, lane: _ServeLane, t: float, n_rep: int) -> bool:
        """Scale-up trigger: projected queue-head latency (elapsed wait +
        full-batch rounds to drain the backlog at current width) beyond
        half the SLO — the half leaves the service time itself headroom."""
        if n_rep == 0:
            return True
        rs = lane.rs
        batches = -(-len(lane.queue) // rs.max_batch)  # ceil
        rounds = -(-batches // n_rep)  # ceil
        est = (t - lane.queue[0]) + rounds * rs.service_time(rs.max_batch)
        return est > 0.5 * rs.slo

    def _find_server(self, gpus: int) -> Optional[int]:
        """Most-free active server with >= ``gpus`` free (lowest id on
        ties) — consolidation would fragment training's multi-server
        placements for no serving benefit."""
        fb = self.cluster.free_buckets
        for c in range(len(fb) - 1, gpus - 1, -1):
            if fb[c]:
                return fb[c][0]
        return None

    def _preempt_for(self, t: float, gpus: int) -> Optional[int]:
        """Ask the policy for training victims and evict until a server
        fits a replica.  Victims are brought to ``t``, released, and
        re-queued via ``on_preemption``; their checkpoint
        (:class:`_Resume`) restarts them through a later ``plan_pass``.
        Returns the server that now fits, or None."""
        running = self.running
        if not running:
            return None
        down = self.cluster.downed_servers
        candidates = [
            r for r in running.values() if down.isdisjoint(r.placement)
        ]
        if not candidates:
            return None
        victims = self._preempt(t, self.cluster, candidates, gpus)
        server = None
        for r in victims:
            jid = r.job.job_id
            if jid not in running:
                continue
            if t > r.since:
                el = (t - r.since) / r.alpha
                r.iters_rem -= el
                if r.iters_rem < 0.0:
                    r.iters_rem = 0.0
                if r.pred_rem is not None:
                    r.pred_rem -= el
                    if r.pred_rem < 0.0:
                        r.pred_rem = 0.0
                r.since = t
            # epoch + 1 turns the in-heap completion stale; pred_epoch
            # carries over so stale prediction checks stay stale too
            self.resume[jid] = _Resume(
                r.iters_rem, r.epoch + 1, r.pred_epoch, self.records[jid]
            )
            self.cluster.release(jid)
            del running[jid]
            self.migration_watch.discard(jid)
            self.result.n_preemptions += 1
            self.policy.on_preemption(t, r.job)
            server = self._find_server(gpus)
            if server is not None:
                break
        return server

    def _scale_down(self, li: int, t: float) -> bool:
        """Release idle replicas beyond the first immediately; keep the
        last while the lane can still produce work (no alloc/release per
        lull), release it too once the lane drains."""
        lane = self.lanes[li]
        changed = False
        drained = (
            lane.exhausted
            and not lane.queue
            and all(b is None for b in lane.batch)
        )
        while lane.idle:
            n_rep = sum(1 for s in lane.servers if s is not None)
            if n_rep > 1 or drained:
                ridx = lane.idle.pop()
                self.cluster.release(self._aid(li, ridx))
                lane.servers[ridx] = None
                changed = True
            else:
                break
        return changed

    def unserved(self) -> int:
        return sum(len(lane.queue) for lane in self.lanes)


def _simulate_scenario(
    scenario: Scenario,
    policy: Policy,
    validate: bool,
    stream: Optional[bool] = None,
) -> SimResult:
    import time as _time

    jobs_src = scenario.jobs
    lazy = isinstance(jobs_src, JobStream)
    if stream is None:
        stream = lazy
    cluster_spec = scenario.cluster
    total_gpus = cluster_spec.total_gpus
    if lazy:
        arrivals = _arrival_stream(jobs_src, total_gpus)
    else:
        jobs = jobs_src
        for job in jobs:
            if job.g > total_gpus:
                raise ValueError(
                    f"job {job.job_id} needs {job.g} GPUs, cluster has "
                    f"{total_gpus}"
                )
        if any(
            jobs[i].arrival > jobs[i + 1].arrival
            for i in range(len(jobs) - 1)
        ):
            # the pre-streaming heap popped arrivals by (arrival, input
            # index); a stable sort by arrival reproduces that order
            # exactly for an unsorted tuple workload
            jobs = sorted(jobs, key=lambda j: j.arrival)
        arrivals = iter(jobs)
    policy.bind(cluster_spec)
    cluster = ClusterState(cluster_spec)
    result = SimResult()
    records = result.records  # job_id -> JobRecord (all jobs, materialized)
    if stream:
        # bounded working set: records holds only not-yet-completed jobs;
        # a completed record folds into the aggregates and is dropped
        result.records = None
        records = {}

    # DET003-allowlisted ([tool.detlint] _simulate_scenario): this
    # perf_counter
    # pair brackets the run for SimResult.wall_s / events_per_sec —
    # reported only, never folded into schedule decisions, completions,
    # or the schedule digest.
    wall0 = _time.perf_counter()
    seq = itertools.count()
    # (time, kind, seq-or-epoch, payload); kind breaks time ties
    # (completions/cluster events before arrivals before wakes), seq keeps
    # sorts stable.  Payload: (JobSpec, completion-epoch) for completions,
    # the JobSpec for arrivals, the typed ClusterEvent (or an internal
    # _DrainDeadline) for cluster events, None for wakes.  Scenario events
    # take consecutive seq numbers in their canonical order, so the
    # documented tie-break survives the heap.  Arrivals are *not*
    # pre-loaded: the main loop feeds them from the time-ordered iterator
    # as the clock reaches them, keeping the heap bounded by live events —
    # seq only breaks ties within one (t, kind), and same-t arrivals enter
    # in stream order, so pop order is identical to the pre-loaded heap.
    events: List[Tuple[float, int, int, object]] = []
    migrate_capable = bool(getattr(policy, "migrate", False))
    # Running-job bookkeeping is needed when anything can re-time a job
    # (factor > 0 degradations) or feed the migration watch (drain
    # windows, which only matter to migration-capable policies).  Clean
    # and fault-only runs skip the registry entirely (measured ~10-20%
    # of the cheap baselines' event cost at 5k jobs).  A prediction-loop
    # policy (track_overruns) needs the registry too: predicted-
    # completion checks live on _Running.pred_rem.
    track_overruns = bool(getattr(policy, "track_overruns", False))
    track_running = track_overruns
    # Serving lanes (ISSUE 9): request streams need the running-job
    # registry — preemption victims come from it.
    streams = scenario.request_streams
    if streams:
        track_running = True
    offer_migrations = False
    for ev in scenario.events:
        events.append((ev.t, _CLUSTER, next(seq), ev))
        kind = type(ev)
        if kind is Degradation and ev.factor > 0.0:
            track_running = True
            offer_migrations = migrate_capable
        elif (
            kind is ServerLeave
            and ev.drain_timeout > 0.0
            and migrate_capable
        ):
            track_running = True
            offer_migrations = True
    heapq.heapify(events)

    n_arrived = 0
    n_completed = 0
    n_events = 0
    peak_depth = 0
    n_passes = 0
    n_migrations = 0
    n_reestimates = 0
    # job_id -> live bookkeeping (placement, remaining iterations, the
    # epoch of the one non-stale completion event); see track_running.
    running: Dict[int, _Running] = {}
    # Jobs currently running on *risky* capacity — degraded (factor < 1)
    # or draining (ServerLeave window open): they are (re-)offered to
    # ``plan_migrations`` on every scheduling pass while the set is
    # non-empty — a saturated cluster often has nowhere to migrate *at*
    # the triggering event, but completions free capacity moments later.
    # Empty on clean runs (the hook is never called).
    migration_watch: set = set()
    # Single live wake: stale wake events carry an older epoch and are
    # dropped on pop without triggering a scheduling pass.
    wake_epoch = 0
    wake_time: Optional[float] = None
    # Per-server drain generation (see _DrainDeadline).
    drain_gen: Dict[int, int] = {}

    serve: Optional[_ServeState] = None
    if streams:
        serve = _ServeState(streams, cluster, policy, result, events, seq)
        serve.bind_runtime(running, records, migration_watch)
        serve.prime()

    heappop, heappush = heapq.heappop, heapq.heappush
    # Canonical pass entry is ``plan_pass``; a pre-protocol subclass that
    # only overrides ``schedule`` (the PR 1-4 name) must still be
    # dispatched through its override, so bind through ``schedule``
    # exactly when it is overridden (zero extra indirection otherwise;
    # pure-protocol policies may not define ``schedule`` at all).
    cls_sched = getattr(type(policy), "schedule", None)
    if cls_sched is None or cls_sched is Policy.schedule:
        plan_pass = policy.plan_pass
    else:
        plan_pass = policy.schedule
    queue_depth = policy.queue_depth
    next_wakeup = policy.next_wakeup
    on_arrival = policy.on_arrival
    on_completion = policy.on_completion
    on_event = policy.on_event
    release = cluster.release
    on_overrun = getattr(policy, "on_overrun", None)

    def push_pred_check(r: _Running) -> None:
        """(Re-)arm the predicted-completion check for ``r``.

        Bumps ``pred_epoch`` first so any in-flight check is superseded
        even when no new one is pushed.  A check is observable only if
        the predicted completion precedes the true one
        (``pred_rem < iters_rem`` — both convert to time under the same
        alpha); otherwise the job physically completes first and a check
        would pop as a stale no-op, so it is elided.  Timed off
        ``since``, so a job inside a migration's restart window is
        checked only after the downtime, like its completion.
        """
        r.pred_epoch += 1
        if r.pred_rem is not None and r.pred_rem < r.iters_rem:
            heappush(
                events,
                (
                    r.since + r.pred_rem * r.alpha,
                    _CLUSTER,
                    next(seq),
                    _PredCheck(r.job.job_id, r.pred_epoch),
                ),
            )

    next_arrival = next(arrivals, None)
    while events or next_arrival is not None:
        # feed the heap every arrival at or before the earliest queued
        # event — the source is arrival-ordered, so nothing later can
        # precede the heap top; each push may lower the top, hence the
        # re-check against events[0]
        while next_arrival is not None and (
            not events or next_arrival.arrival <= events[0][0]
        ):
            heappush(
                events,
                (next_arrival.arrival, _ARRIVAL, next(seq), next_arrival),
            )
            n_arrived += 1
            next_arrival = next(arrivals, None)
        t = events[0][0]
        live = False  # any non-stale event at this timestamp?
        speed_changed: List[int] = []  # servers re-sped at t (factor > 0)
        downed: List[int] = []  # servers killed at t (fault/leave/deadline)
        while events and events[0][0] == t:
            _, kind, tag, payload = heappop(events)
            n_events += 1
            if kind == _COMPLETION:
                job, ep = payload
                if track_running:
                    r = running.get(job.job_id)
                    if r is None or ep != r.epoch:
                        continue  # superseded by a re-timing: stale entry
                    del running[job.job_id]
                    migration_watch.discard(job.job_id)
                release(job.job_id)
                on_completion(t, job)
                if stream:
                    result._fold(job.job_id, records.pop(job.job_id))
                n_completed += 1
                live = True
            elif kind == _ARRIVAL:
                on_arrival(t, payload)
                live = True
            elif kind == _CLUSTER:
                ev_kind = type(payload)
                if ev_kind is _ReqArrival:
                    # internal serve event: live only when cluster capacity
                    # changed (a million-request stream must not force a
                    # million scheduling passes)
                    if serve.on_arrival(payload, t):
                        live = True
                    continue
                if ev_kind is _BatchDone:
                    if serve.on_batch_done(payload, t):
                        live = True
                    continue
                if ev_kind is _PredCheck:
                    # A watched job reached its predicted completion while
                    # still running: bring the bookkeeping to t, ask the
                    # policy to re-estimate the remaining work, and re-arm
                    # the check at the new prediction.  The backoff
                    # contract (prediction_loop) makes consecutive checks
                    # geometrically spaced, so a job with n true
                    # iterations fires O(log n) of these no matter how
                    # wrong the initial prediction was.
                    r = running.get(payload.job_id)
                    if r is not None and payload.epoch == r.pred_epoch:
                        if t > r.since:
                            el = (t - r.since) / r.alpha
                            r.iters_rem -= el
                            if r.iters_rem < 0.0:
                                r.iters_rem = 0.0
                            r.pred_rem -= el
                            r.since = t
                        elapsed = r.job.n_iters - r.iters_rem
                        if on_overrun is None:
                            # protocol policy stamped n_pred but has no
                            # hook: plain doubling of the elapsed work
                            new_rem = max(elapsed, 1.0)
                        else:
                            new_rem = float(on_overrun(t, r.job, elapsed))
                        if new_rem <= 0.0:
                            # never trust a hook into a same-time loop
                            new_rem = 1.0
                        r.pred_rem = new_rem
                        n_reestimates += 1
                        push_pred_check(r)
                        live = True
                    continue  # internal event: no on_event call
                if ev_kind is _DrainDeadline:
                    # internal: the leave window closed — the server is
                    # down for good (jobs still on it finish in place and
                    # drop off the migration watch via the downed prune).
                    # A deadline from a superseded drain (cancelled by a
                    # join, window re-opened by a later leave) carries an
                    # older generation and is dropped.
                    if payload.gen == drain_gen.get(
                        payload.server
                    ) and cluster.finish_drain(payload.server):
                        if track_running:
                            downed.append(payload.server)
                        live = True
                    continue  # not a scenario event: no on_event call
                if ev_kind is Fault or (
                    ev_kind is Degradation and payload.factor == 0.0
                ):
                    # full failure: the PR-2 fault path verbatim (capacity
                    # forfeited; running jobs finish in place, un-re-timed)
                    cluster.mark_server_down(payload.server)
                    if track_running:
                        downed.append(payload.server)
                    live = True
                elif ev_kind is Degradation:
                    if cluster.set_server_speed(
                        payload.server, payload.factor
                    ):
                        speed_changed.append(payload.server)
                        live = True
                    # else: factor equals the current speed — a no-op
                    # (neither re-timing nor a scheduling pass; keeps
                    # all-1.0 degradation schedules identical to clean)
                elif ev_kind is ServerLeave:
                    if payload.drain_timeout <= 0.0:
                        # immediate leave == the fault path (property-
                        # tested); the slot stays rejoinable via ServerJoin
                        cluster.mark_server_down(payload.server)
                        if track_running:
                            downed.append(payload.server)
                        live = True
                    elif cluster.drain_server(payload.server):
                        live = True
                        m = payload.server
                        gen = drain_gen.get(m, 0) + 1
                        drain_gen[m] = gen
                        if offer_migrations:
                            down = cluster.downed_servers
                            for jid, r in running.items():
                                # dead-straddlers can't checkpoint-restart
                                # (state on the dead server is gone)
                                if m in r.placement and down.isdisjoint(
                                    r.placement
                                ):
                                    migration_watch.add(jid)
                        if payload.drain_timeout != float("inf"):
                            heappush(
                                events,
                                (
                                    t + payload.drain_timeout,
                                    _CLUSTER,
                                    next(seq),
                                    _DrainDeadline(m, gen),
                                ),
                            )
                elif ev_kind is ServerJoin:
                    if cluster.activate_server(payload.server):
                        live = True
                        if migration_watch:
                            # a join cancelling a drain un-risks the
                            # server: drop watched jobs that no longer
                            # touch degraded or draining capacity
                            sp = cluster.speed_factors
                            dr = cluster.draining_servers
                            # sorted() by job id (DET001): discard-only
                            # loop, but set order must never become an
                            # observable sequence
                            for jid in sorted(migration_watch):
                                p = running[jid].placement
                                if (
                                    not sp or sp.keys().isdisjoint(p)
                                ) and (not dr or dr.isdisjoint(p)):
                                    migration_watch.discard(jid)
                else:
                    # custom ClusterEvent subclass: no engine-side state
                    # change — it reaches the policy via on_event (the
                    # extensibility point), and triggers a pass so the
                    # policy's reaction can schedule immediately
                    live = True
                on_event(t, payload, cluster)
            else:  # _WAKE: no state change; just triggers a scheduling pass.
                if tag == wake_epoch:
                    wake_time = None
                    live = True
                # else: superseded wake — ignore.
        if not live:
            continue

        if serve is not None and serve.starved:
            # replica-less backlogs retry on any live timestamp: training
            # completions free capacity without raising a serve event, and
            # the replica must claim GPUs before the scheduling pass below
            # hands them to queued training jobs
            for li in sorted(serve.starved):
                serve.dispatch(li, t)

        if downed and migration_watch:
            # A job whose placement touches a *dead* server can never
            # checkpoint-restart (its checkpoint state lived there): drop
            # it from the watch — it finishes in place, PR-2 style.
            dead = set(downed)
            # sorted() by job id (DET001): discard-only loop, but set
            # order must never become an observable sequence
            for jid in [
                j for j in sorted(migration_watch)
                if not dead.isdisjoint(running[j].placement)
            ]:
                migration_watch.discard(jid)

        if speed_changed:
            # Re-time every running job touching a re-sped server under the
            # final (post-drain) speed map; jobs left on degraded capacity
            # join the migration watchlist.
            changed = set(speed_changed)
            speeds = cluster.speed_factors
            down = cluster.downed_servers
            draining = cluster.draining_servers
            for jid, r in running.items():
                if changed.isdisjoint(r.placement):
                    continue
                if not down.isdisjoint(r.placement):
                    # straddles a dead server: it finishes in place at its
                    # last re-timed alpha (PR-2).  Re-timing here would
                    # evaluate the dead server at full speed — its _speed
                    # entry died with it — shrinking the completion.
                    continue
                if t > r.since:
                    el = (t - r.since) / r.alpha
                    r.iters_rem -= el
                    if r.iters_rem < 0.0:
                        r.iters_rem = 0.0
                    if r.pred_rem is not None:
                        r.pred_rem -= el
                        if r.pred_rem < 0.0:
                            r.pred_rem = 0.0
                    r.since = t
                a_new = timing.alpha(
                    r.job, r.placement, cluster_spec,
                    speeds=speeds or None,
                )
                if a_new != r.alpha:
                    r.alpha = a_new
                    r.epoch += 1
                    # r.since == t normally; for a job still inside a
                    # migration's restart window (since > t) the pending
                    # downtime is preserved, not re-counted as progress
                    completion = r.since + r.iters_rem * a_new
                    rec = records[jid]
                    rec.alpha = a_new
                    rec.completion = completion
                    heappush(
                        events,
                        (completion, _COMPLETION, next(seq), (r.job, r.epoch)),
                    )
                    if r.pred_rem is not None:
                        # the in-flight check was timed under the old
                        # alpha: supersede and re-arm it
                        push_pred_check(r)
                # (dead-straddlers never reach here — the `continue`
                # above — so no downed-server check is needed)
                if offer_migrations and (
                    (speeds and not speeds.keys().isdisjoint(r.placement))
                    or (
                        draining
                        and not draining.isdisjoint(r.placement)
                    )
                ):
                    migration_watch.add(jid)
                else:
                    migration_watch.discard(jid)

        if migration_watch:
            speeds = cluster.speed_factors
            draining = cluster.draining_servers
            if not speeds and not draining:
                # every watched job's risk resolved: stragglers recovered
                # or died (a downed server's jobs finish in place at their
                # last re-timed alpha — PR-2) and drain windows closed
                migration_watch.clear()
            else:
                risky = set(speeds)
                risky.update(draining)
                candidates: List[_Running] = []
                for jid in sorted(migration_watch):
                    r = running[jid]
                    if t > r.since:
                        # bring remaining-iteration bookkeeping to t so the
                        # stay-vs-move race compares current quantities
                        # (no check re-arm needed: alpha is unchanged, so
                        # the in-flight check's timestamp stays valid)
                        el = (t - r.since) / r.alpha
                        r.iters_rem -= el
                        if r.iters_rem < 0.0:
                            r.iters_rem = 0.0
                        if r.pred_rem is not None:
                            r.pred_rem -= el
                            if r.pred_rem < 0.0:
                                r.pred_rem = 0.0
                        r.since = t
                    candidates.append(r)
                for mig in policy.plan_migrations(t, cluster, candidates):
                    job = mig.job
                    if validate:
                        timing.validate_placement(job, mig.placement)
                    r = running[job.job_id]
                    r.placement = mig.placement
                    r.alpha = mig.alpha
                    r.epoch += 1
                    # computing resumes only after the restart downtime;
                    # parking ``since`` there keeps later re-timings from
                    # crediting the penalty window as iterations done
                    r.since = t + mig.penalty
                    completion = r.since + r.iters_rem * mig.alpha
                    rec = records[job.job_id]
                    rec.alpha = mig.alpha
                    rec.completion = completion
                    rec.servers = tuple(sorted(mig.placement))
                    rec.migrations += 1
                    n_migrations += 1
                    heappush(
                        events,
                        (completion, _COMPLETION, next(seq), (job, r.epoch)),
                    )
                    if r.pred_rem is not None:
                        # new alpha + restart downtime: supersede and
                        # re-arm the predicted-completion check
                        push_pred_check(r)
                    if risky.isdisjoint(mig.placement):
                        migration_watch.discard(job.job_id)

        for start in plan_pass(t, cluster):
            job = start.job
            if validate:
                timing.validate_placement(job, start.placement)
            res = (
                serve.resume.pop(job.job_id, None)
                if serve is not None and serve.resume
                else None
            )
            if res is None:
                ep = 0
                iters = float(job.n_iters)
                since = t
                completion = t + job.n_iters * start.alpha
                records[job.job_id] = JobRecord(
                    arrival=job.arrival,
                    start=t,
                    completion=completion,
                    alpha=start.alpha,
                    # placements never carry empty per-server vectors, so
                    # the touched servers are exactly the placement keys
                    servers=tuple(sorted(start.placement)),
                )
            else:
                # preemption restart: remaining iterations resume after
                # the checkpoint-restart downtime; the original record
                # keeps its first start and counts the restart as a
                # migration.  The carried epoch outdates the stale
                # pre-preemption completion still in the heap.
                ep = res.epoch
                iters = res.iters_rem
                since = t + serve.restart_penalty
                completion = since + iters * start.alpha
                rec = res.rec
                rec.alpha = start.alpha
                rec.completion = completion
                rec.servers = tuple(sorted(start.placement))
                rec.migrations += 1
                records[job.job_id] = rec
            if track_running:
                n_pred = start.n_pred
                running[job.job_id] = r = _Running(
                    job=job,
                    placement=start.placement,
                    alpha=start.alpha,
                    iters_rem=iters,
                    since=since,
                    epoch=ep,
                    pred_rem=(None if n_pred is None else float(n_pred)),
                    pred_epoch=(0 if res is None else res.pred_epoch),
                )
                if r.pred_rem is not None:
                    # arm the predicted-completion watch; a 0-predicted
                    # (unseen) job fires it at t itself — the outer loop
                    # re-pops the same timestamp, the backoff re-estimate
                    # raises pred_rem to >= one iteration, and the job
                    # proceeds without starving anyone (physical
                    # completion uses the true n_iters regardless)
                    push_pred_check(r)
                # a job *started* onto degraded capacity (a straggler can
                # still hold the most free GPUs) is as migratable as one
                # caught there by the event; placements never touch downed
                # or draining servers (zero free), so neither needs a check
                if offer_migrations:
                    sp = cluster.speed_factors
                    if sp and not sp.keys().isdisjoint(start.placement):
                        migration_watch.add(job.job_id)
            heappush(events, (completion, _COMPLETION, next(seq), (job, ep)))
        n_passes += 1
        depth = queue_depth()
        if depth > peak_depth:
            peak_depth = depth

        wake = next_wakeup(t)
        if wake is not None and wake > t and wake != wake_time:
            wake_epoch += 1
            wake_time = wake
            heappush(events, (wake, _WAKE, wake_epoch, None))

    if n_completed != n_arrived:
        missing = n_arrived - n_completed
        raise RuntimeError(f"simulation ended with {missing} unfinished jobs")
    if serve is not None and serve.unserved():
        raise RuntimeError(
            f"simulation ended with {serve.unserved()} unserved requests "
            f"(no replica could ever be placed — check stream gpus vs "
            f"cluster capacity)"
        )
    result.n_jobs = n_completed
    result.n_events = n_events
    result.n_sched_passes = n_passes
    result.peak_queue_depth = peak_depth
    result.n_migrations = n_migrations
    result.n_reestimates = n_reestimates
    # DET003-allowlisted: wall_s lands after every record/digest above
    # is final (see the matching comment at wall0)
    result.wall_s = _time.perf_counter() - wall0
    return result


# ---------------------------------------------------------------------------
# Shared helpers: per-config alpha bounds cache
# ---------------------------------------------------------------------------


class AlphaCache:
    """alpha_max / alpha-tilde_min per unique (stages, allreduce) config.

    ``bounds(job)`` answers the *clean*-cluster bounds (cached per
    config).  ``bounds(job, cluster)`` with a degraded live
    :class:`ClusterState` folds the current per-server speed factors in
    (ISSUE 6 satellite; open since the PR-4 straggler work):

    * ``alpha_max`` — the spread worst case must cover a lone replica
      landing on a straggler: for every *allocatable* degraded server
      (not down, not draining) the per-class spread bound is stretched
      by ``1/factor`` (degradation divides the whole per-stage time —
      compute and NIC alike — by the factor; see cluster.py), and the
      worst such value joins the clean bound in the max.
    * ``alpha_min_tilde`` — the consolidated best case divides by the
      best allocatable factor: a fully-degraded cluster (no clean
      server left) can do no better than its fastest straggler, while a
      boosted server (factor > 1) improves the estimate.

    A heavily degraded cluster therefore *raises* ``a_max/a_min`` and
    can flip a borderline job into the comm-heavy class — admission
    then consolidates/delays it instead of spreading it across
    stragglers on clean-cluster assumptions.  The per-instance
    ``(cluster epoch, speed version)`` signature only gates the
    O(num_servers) active-server *scan*; the degraded answers themselves
    are memoized content-addressed — keyed by the sorted multiset of
    allocatable ``(class, factor)`` stragglers, the best factor, and the
    job config — because the fold below is a pure function of exactly
    that key.  Content addressing is what lets
    :class:`~repro.core.fleet.FleetShared` alias one degraded memo
    across every variant of a fleet (the PR-7 limitation this closes):
    two variants hitting the same straggler state — common under
    shared samplers — reuse each other's folds, and entries survive
    signature churn *within* a run (degrade -> recover -> re-degrade
    re-hits the memo instead of recomputing).  Clean clusters never
    touch any of this path.
    """

    def __init__(self, cluster_spec: ClusterSpec):
        self.spec = cluster_spec
        self._cache: Dict[int, Tuple[float, float]] = {}
        # degradation-aware state: per-(config, class) spread bounds,
        # the per-signature scan memo, and the content-addressed degraded
        # answers (shareable across fleet variants; never cleared)
        self._class_amax: Dict[Tuple[int, int], float] = {}
        self._deg_sig: Optional[Tuple[int, int]] = None
        self._deg_cache: Dict[tuple, Tuple[float, float]] = {}
        self._deg_active: Tuple[Tuple[int, float], ...] = ()
        self._deg_best: float = 1.0

    def bounds(
        self, job: JobSpec, cluster: Optional[ClusterState] = None
    ) -> Tuple[float, float]:
        """Returns (alpha_max, alpha_min_tilde); degradation-aware when a
        degraded live ``cluster`` is passed."""
        if cluster is not None and cluster.has_degraded:
            return self._degraded_bounds(job, cluster)
        key = job.config_key
        hit = self._cache.get(key)
        if hit is None:
            from . import heavy_edge as he  # local import to avoid cycle

            a_max = timing.alpha_max(job, self.spec)
            a_min = he.alpha_min_estimate(job, self.spec)
            # The consolidated estimate can only be <= the all-spread bound.
            a_max = max(a_max, a_min)
            hit = (a_max, a_min)
            self._cache[key] = hit
        return hit

    def _class_alpha_max(self, job: JobSpec, cls: int) -> float:
        key = (job.config_key, cls)
        v = self._class_amax.get(key)
        if v is None:
            g, b_inter, _b_intra = self.spec.class_geom(cls)
            v = timing.alpha_max(job, self.spec, nic_share=b_inter / g)
            self._class_amax[key] = v
        return v

    def _degraded_bounds(
        self, job: JobSpec, cluster: ClusterState
    ) -> Tuple[float, float]:
        sig = (cluster.epoch, cluster.speed_version)
        if sig != self._deg_sig:
            self._deg_sig = sig
            sp = cluster.speed_factors
            down = cluster.downed_servers
            drain = cluster.draining_servers
            spec = self.spec
            active: List[Tuple[int, float]] = []
            best = 0.0
            any_clean = False
            for m in range(spec.num_servers):
                if m in down or m in drain:
                    continue  # takes no new allocations: not admission-visible
                f = sp.get(m)
                if f is None:
                    any_clean = True
                else:
                    active.append((spec.class_of(m), f))
                    if f > best:
                        best = f
            if any_clean and best < 1.0:
                best = 1.0
            # sorted: the fold is order-independent (a max over per-class
            # stretches), so two clusters with the same straggler multiset
            # share memo entries regardless of which server ids degraded
            self._deg_active = tuple(sorted(active))
            self._deg_best = best
        if not self._deg_active and self._deg_best >= 1.0:
            # every straggler is down or draining: new placements can only
            # land on clean capacity, so the clean bounds apply verbatim
            return self.bounds(job)
        key = (self._deg_active, self._deg_best, job.config_key)
        hit = self._deg_cache.get(key)
        if hit is None:
            a_max, a_min = self.bounds(job)  # clean baseline (cached)
            for cls, f in self._deg_active:
                v = self._class_alpha_max(job, cls) / f
                if v > a_max:
                    a_max = v
            if self._deg_best > 0.0:
                a_min = a_min / self._deg_best
            a_max = max(a_max, a_min)
            hit = (a_max, a_min)
            self._deg_cache[key] = hit
        return hit
