"""Event-driven cluster simulator for online non-preemptive scheduling.

The paper's Algorithm 1 iterates unit time-slots; cluster state only changes
at job arrivals/completions (plus the comm-heavy delay deadlines), so we
advance event-to-event — the schedule produced is identical while remaining
tractable for 10^5-job traces.  ``tests/test_asrpt.py`` cross-checks against
a literal slotted execution on small instances.

Hot-path design (trace scale):

* policies *own* their allocations: ``schedule`` allocates on the live
  ``ClusterState`` and the simulator only releases on completion.  (The old
  protocol had each pass allocate, undo, and the simulator re-allocate —
  three O(placement) dict walks per start, and the undo releases defeated
  the release-epoch change tracking policies use to skip recomputation.)
* wake-ups are epoch-tagged: at most one *live* wake event exists at a
  time; superseded wakes stay in the heap but are recognised as stale by
  their epoch and skipped without a scheduling pass.  The old
  ``scheduled_wakes`` set grew without bound on long traces.
* all events at the same timestamp are drained before a single scheduling
  pass runs.

Policies observe only online information: arrivals as they happen, true
iteration counts only at completion (fed to the predictor).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterState
from .job import ClusterSpec, JobSpec
from . import timing

# Completions free capacity and faults remove it before arrivals/wakes at
# the same timestamp trigger the scheduling pass.
_COMPLETION, _FAULT, _ARRIVAL, _WAKE = 0, 1, 2, 3


@dataclass(slots=True)
class Start:
    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float


@dataclass(slots=True)
class JobRecord:
    arrival: float
    start: float
    completion: float
    alpha: float
    servers: Tuple[int, ...]


@dataclass
class SimResult:
    records: Dict[int, JobRecord] = field(default_factory=dict)
    # engine statistics (filled by ``simulate``; benchmarks/sched_scale.py)
    n_events: int = 0
    n_sched_passes: int = 0
    peak_queue_depth: int = 0
    wall_s: float = 0.0

    @property
    def total_completion_time(self) -> float:
        return sum(r.completion for r in self.records.values())

    @property
    def total_flow_time(self) -> float:
        return sum(r.completion - r.arrival for r in self.records.values())

    @property
    def makespan(self) -> float:
        # guard the empty case like mean_jct (max() raises on no records)
        if not self.records:
            return 0.0
        return max(r.completion for r in self.records.values())

    @property
    def mean_jct(self) -> float:
        return self.total_flow_time / max(len(self.records), 1)

    @property
    def events_per_sec(self) -> float:
        return self.n_events / self.wall_s if self.wall_s > 0 else float("nan")


class Policy:
    """Scheduling policy interface (see asrpt.py / baselines.py).

    ``schedule`` must ``cluster.allocate`` every returned start — the
    allocation is kept (the simulator releases it at the job's completion).
    """

    def bind(self, cluster_spec: ClusterSpec) -> None:
        self.cluster_spec = cluster_spec

    def on_arrival(self, t: float, job: JobSpec) -> None:
        raise NotImplementedError

    def on_completion(self, t: float, job: JobSpec) -> None:
        pass

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        raise NotImplementedError

    def next_wakeup(self, t: float) -> Optional[float]:
        return None

    def queue_depth(self) -> int:
        """Jobs held by the policy (pending + delayed); for engine stats."""
        return 0


def simulate(
    jobs: List[JobSpec],
    cluster_spec: ClusterSpec,
    policy: Policy,
    validate: bool = True,
    faults: Optional[Sequence[Tuple[float, int]]] = None,
) -> SimResult:
    """Run ``policy`` over ``jobs``; returns per-job records + engine stats.

    ``validate=False`` skips the per-start placement re-validation (safety
    net for policy bugs) — benchmarks use it; tests keep it on.

    ``faults``: (time, server_id) failure injections — the server is marked
    down at that time (free capacity vanishes immediately; GPUs held by
    running jobs are forfeited on release, see ClusterState).  The epoch
    bump wakes incremental policies out of their settled state.  Jobs
    whose GPU demand exceeds the *degraded* cluster capacity can never
    start; the end-of-run unfinished-jobs check reports them.
    """
    import time as _time

    for job in jobs:
        if job.g > cluster_spec.total_gpus:
            raise ValueError(
                f"job {job.job_id} needs {job.g} GPUs, cluster has "
                f"{cluster_spec.total_gpus}"
            )
    policy.bind(cluster_spec)
    cluster = ClusterState(cluster_spec)
    result = SimResult()
    records = result.records

    wall0 = _time.perf_counter()
    seq = itertools.count()
    # (time, kind, seq-or-epoch, payload); kind breaks time ties
    # (completions/faults before arrivals before wakes), seq keeps sorts
    # stable.  Payload: the JobSpec for completions/arrivals, the server id
    # for faults, None for wakes.
    events: List[Tuple[float, int, int, object]] = [
        (job.arrival, _ARRIVAL, next(seq), job) for job in jobs
    ]
    for fault_t, server_id in faults or ():
        events.append((fault_t, _FAULT, next(seq), server_id))
    heapq.heapify(events)

    n_completed = 0
    n_events = 0
    peak_depth = 0
    n_passes = 0
    # Single live wake: stale wake events carry an older epoch and are
    # dropped on pop without triggering a scheduling pass.
    wake_epoch = 0
    wake_time: Optional[float] = None

    heappop, heappush = heapq.heappop, heapq.heappush
    schedule = policy.schedule
    queue_depth = policy.queue_depth
    next_wakeup = policy.next_wakeup
    on_arrival = policy.on_arrival
    on_completion = policy.on_completion
    release = cluster.release
    while events:
        t = events[0][0]
        live = False  # any non-stale event at this timestamp?
        while events and events[0][0] == t:
            _, kind, tag, payload = heappop(events)
            n_events += 1
            if kind == _COMPLETION:
                release(payload.job_id)
                on_completion(t, payload)
                n_completed += 1
                live = True
            elif kind == _ARRIVAL:
                on_arrival(t, payload)
                live = True
            elif kind == _FAULT:
                cluster.mark_server_down(payload)
                live = True
            else:  # _WAKE: no state change; just triggers a scheduling pass.
                if tag == wake_epoch:
                    wake_time = None
                    live = True
                # else: superseded wake — ignore.
        if not live:
            continue

        for start in schedule(t, cluster):
            job = start.job
            if validate:
                timing.validate_placement(job, start.placement)
            completion = t + job.n_iters * start.alpha
            records[job.job_id] = JobRecord(
                arrival=job.arrival,
                start=t,
                completion=completion,
                alpha=start.alpha,
                # placements never carry empty per-server vectors, so the
                # touched servers are exactly the placement keys
                servers=tuple(sorted(start.placement)),
            )
            heappush(events, (completion, _COMPLETION, next(seq), job))
        n_passes += 1
        depth = queue_depth()
        if depth > peak_depth:
            peak_depth = depth

        wake = next_wakeup(t)
        if wake is not None and wake > t and wake != wake_time:
            wake_epoch += 1
            wake_time = wake
            heappush(events, (wake, _WAKE, wake_epoch, None))

    if n_completed != len(jobs):
        missing = len(jobs) - n_completed
        raise RuntimeError(f"simulation ended with {missing} unfinished jobs")
    result.n_events = n_events
    result.n_sched_passes = n_passes
    result.peak_queue_depth = peak_depth
    result.wall_s = _time.perf_counter() - wall0
    return result


# ---------------------------------------------------------------------------
# Shared helpers: per-config alpha bounds cache
# ---------------------------------------------------------------------------


class AlphaCache:
    """alpha_max / alpha-tilde_min per unique (stages, allreduce) config."""

    def __init__(self, cluster_spec: ClusterSpec):
        self.spec = cluster_spec
        self._cache: Dict[int, Tuple[float, float]] = {}

    def bounds(self, job: JobSpec) -> Tuple[float, float]:
        """Returns (alpha_max, alpha_min_tilde)."""
        key = job.config_key
        hit = self._cache.get(key)
        if hit is None:
            from . import heavy_edge as he  # local import to avoid cycle

            a_max = timing.alpha_max(job, self.spec)
            a_min = he.alpha_min_estimate(job, self.spec)
            # The consolidated estimate can only be <= the all-spread bound.
            a_max = max(a_max, a_min)
            hit = (a_max, a_min)
            self._cache[key] = hit
        return hit
