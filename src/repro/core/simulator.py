"""Event-driven cluster simulator for online non-preemptive scheduling.

The paper's Algorithm 1 iterates unit time-slots; cluster state only changes
at job arrivals/completions (plus the comm-heavy delay deadlines), so we
advance event-to-event — the schedule produced is identical while remaining
tractable for 10^5-job traces.  ``tests/test_asrpt.py`` cross-checks against
a literal slotted execution on small instances.

Hot-path design (trace scale):

* policies *own* their allocations: ``schedule`` allocates on the live
  ``ClusterState`` and the simulator only releases on completion.  (The old
  protocol had each pass allocate, undo, and the simulator re-allocate —
  three O(placement) dict walks per start, and the undo releases defeated
  the release-epoch change tracking policies use to skip recomputation.)
* wake-ups are epoch-tagged: at most one *live* wake event exists at a
  time; superseded wakes stay in the heap but are recognised as stale by
  their epoch and skipped without a scheduling pass.  The old
  ``scheduled_wakes`` set grew without bound on long traces.
* all events at the same timestamp are drained before a single scheduling
  pass runs.

Policies observe only online information: arrivals as they happen, true
iteration counts only at completion (fed to the predictor).

Degradation events (stragglers): ``degradations=[(t, server, factor)]``
scales a server's effective speed mid-run (see cluster.py / timing.py).
Running jobs touching the server are *re-timed*: their remaining
iterations are brought to ``t`` under the old alpha, a new alpha is
evaluated under the updated speed map, and the completion event is
re-issued.  Completion events are therefore epoch-tagged per job (like
wakes): superseded completions stay in the heap and are dropped on pop.
A ``factor == 0.0`` event takes the PR-2 fault path verbatim (capacity
forfeited, running jobs finish in place, no re-timing) — ``faults=`` is
now sugar for factor-0.0 degradations.  After re-timing, the policy's
``plan_migrations`` hook may checkpoint-restart affected jobs onto
fresh capacity (see migration.py); the simulator re-times migrated jobs
with the restart penalty and updates their records in place.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cluster import ClusterState
from .job import ClusterSpec, JobSpec
from . import timing

# Completions free capacity and faults remove it before arrivals/wakes at
# the same timestamp trigger the scheduling pass.
_COMPLETION, _FAULT, _ARRIVAL, _WAKE = 0, 1, 2, 3


@dataclass(slots=True)
class Start:
    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float


@dataclass(slots=True)
class Migration:
    """A checkpoint-restart decision returned by ``Policy.plan_migrations``.

    The policy has already released the job's old allocation and allocated
    ``placement`` (policies own their allocations, as with ``Start``); the
    simulator re-times the job: remaining iterations resume at ``alpha``
    after ``penalty`` seconds of checkpoint-restart downtime.
    """

    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float
    penalty: float


@dataclass(slots=True)
class JobRecord:
    arrival: float
    start: float
    completion: float
    alpha: float
    servers: Tuple[int, ...]
    migrations: int = 0


@dataclass(slots=True)
class _Running:
    """Live bookkeeping for one started job (degradation re-timing).

    ``iters_rem`` is the remaining iteration count as of ``since`` —
    which is the time the job last (re)started *computing*: after a
    migration ``since`` sits at ``t + penalty``, so the checkpoint-
    restart downtime is never credited as productive work if another
    event re-times the job mid-restart (re-timings subtract elapsed
    iterations only for ``t > since``).  The live completion event
    carries ``epoch`` — re-timing bumps it, turning the superseded event
    into a stale heap entry.  Instances double as the read-only views
    handed to ``Policy.plan_migrations``.
    """

    job: JobSpec
    placement: Dict[int, np.ndarray]
    alpha: float
    iters_rem: float
    since: float
    epoch: int = 0


@dataclass
class SimResult:
    records: Dict[int, JobRecord] = field(default_factory=dict)
    # engine statistics (filled by ``simulate``; benchmarks/sched_scale.py)
    n_events: int = 0
    n_sched_passes: int = 0
    peak_queue_depth: int = 0
    n_migrations: int = 0
    wall_s: float = 0.0

    @property
    def total_completion_time(self) -> float:
        return sum(r.completion for r in self.records.values())

    @property
    def total_flow_time(self) -> float:
        return sum(r.completion - r.arrival for r in self.records.values())

    @property
    def makespan(self) -> float:
        # guard the empty case like mean_jct (max() raises on no records)
        if not self.records:
            return 0.0
        return max(r.completion for r in self.records.values())

    @property
    def mean_jct(self) -> float:
        return self.total_flow_time / max(len(self.records), 1)

    @property
    def events_per_sec(self) -> float:
        return self.n_events / self.wall_s if self.wall_s > 0 else float("nan")


class Policy:
    """Scheduling policy interface (see asrpt.py / baselines.py).

    ``schedule`` must ``cluster.allocate`` every returned start — the
    allocation is kept (the simulator releases it at the job's completion).
    """

    # Opt-in for the degradation migration hook: the simulator maintains
    # the straggler watchlist and calls ``plan_migrations`` only when this
    # is truthy (MigrationMixin exposes it as a constructor arg).
    migrate: bool = False

    def bind(self, cluster_spec: ClusterSpec) -> None:
        self.cluster_spec = cluster_spec

    def on_arrival(self, t: float, job: JobSpec) -> None:
        raise NotImplementedError

    def on_completion(self, t: float, job: JobSpec) -> None:
        pass

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        raise NotImplementedError

    def next_wakeup(self, t: float) -> Optional[float]:
        return None

    def plan_migrations(
        self, t: float, cluster: ClusterState, candidates: List["_Running"]
    ) -> List[Migration]:
        """Degradation hook: while any job is running on degraded
        capacity, called before every scheduling pass with those jobs as
        read-only views (so capacity freed by completions since the
        degradation event is still exploitable).  A migrating policy
        releases the old allocation, allocates the new placement, and
        returns a ``Migration`` per moved job (see migration.py); the
        default never migrates.  Only called when ``self.migrate`` is
        truthy (non-migrating policies skip the watchlist bookkeeping
        entirely); never called on clean runs.
        """
        return []

    def queue_depth(self) -> int:
        """Jobs held by the policy (pending + delayed); for engine stats."""
        return 0


def simulate(
    jobs: List[JobSpec],
    cluster_spec: ClusterSpec,
    policy: Policy,
    validate: bool = True,
    faults: Optional[Sequence[Tuple[float, int]]] = None,
    degradations: Optional[Sequence[Tuple[float, int, float]]] = None,
) -> SimResult:
    """Run ``policy`` over ``jobs``; returns per-job records + engine stats.

    ``validate=False`` skips the per-start placement re-validation (safety
    net for policy bugs) — benchmarks use it; tests keep it on.

    ``faults``: (time, server_id) failure injections — the server is marked
    down at that time (free capacity vanishes immediately; GPUs held by
    running jobs are forfeited on release, see ClusterState).  The epoch
    bump wakes incremental policies out of their settled state.  Jobs
    whose GPU demand exceeds the *degraded* cluster capacity can never
    start; the end-of-run unfinished-jobs check reports them.

    ``degradations``: (time, server_id, speed_factor) straggler events.
    ``factor`` in (0, 1) slows the server (compute + NIC stretch by
    ``1/factor``), 1.0 restores it, > 1.0 models a boost, and exactly
    0.0 is a full failure — identical to a ``faults`` entry at the same
    time (the two sequences share one event stream).  Running jobs
    touching a ``factor > 0`` change are re-timed at the event and
    offered to ``policy.plan_migrations``; a repeated factor equal to
    the server's current speed is a no-op and triggers no scheduling
    pass, so an all-1.0 schedule is bit-identical to the clean run.
    """
    import time as _time

    for job in jobs:
        if job.g > cluster_spec.total_gpus:
            raise ValueError(
                f"job {job.job_id} needs {job.g} GPUs, cluster has "
                f"{cluster_spec.total_gpus}"
            )
    policy.bind(cluster_spec)
    cluster = ClusterState(cluster_spec)
    result = SimResult()
    records = result.records

    wall0 = _time.perf_counter()
    seq = itertools.count()
    # (time, kind, seq-or-epoch, payload); kind breaks time ties
    # (completions/faults before arrivals before wakes), seq keeps sorts
    # stable.  Payload: (JobSpec, completion-epoch) for completions, the
    # JobSpec for arrivals, (server id, factor) for faults/degradations,
    # None for wakes.
    events: List[Tuple[float, int, int, object]] = [
        (job.arrival, _ARRIVAL, next(seq), job) for job in jobs
    ]
    for fault_t, server_id in faults or ():
        events.append((fault_t, _FAULT, next(seq), (server_id, 0.0)))
    track_running = False  # any factor > 0 event => re-timing bookkeeping
    for deg_t, server_id, factor in degradations or ():
        if factor < 0.0:
            raise ValueError(f"speed factor must be >= 0, got {factor}")
        if factor > 0.0:
            track_running = True
        events.append((deg_t, _FAULT, next(seq), (server_id, factor)))
    heapq.heapify(events)
    # watchlist + plan_migrations only for policies that opted in: the
    # hook of a non-migrating policy returns [] unconditionally, so the
    # per-pass candidate bookkeeping would be pure overhead
    offer_migrations = track_running and bool(
        getattr(policy, "migrate", False)
    )

    n_completed = 0
    n_events = 0
    peak_depth = 0
    n_passes = 0
    n_migrations = 0
    # job_id -> live bookkeeping (placement, remaining iterations, the
    # epoch of the one non-stale completion event).  Only maintained when
    # a factor > 0 event exists: re-timing is the sole producer of stale
    # completions, so clean/fault-only runs skip the registry entirely
    # (measured ~10-20% of the cheap baselines' event cost at 5k jobs).
    running: Dict[int, _Running] = {}
    # Jobs currently running on degraded (factor < 1) capacity: they are
    # (re-)offered to ``plan_migrations`` on every scheduling pass while
    # the set is non-empty — a saturated cluster often has nowhere to
    # migrate *at* the degradation event, but completions free capacity
    # moments later.  Empty on clean runs (the hook is never called).
    straggler_watch: set = set()
    # Single live wake: stale wake events carry an older epoch and are
    # dropped on pop without triggering a scheduling pass.
    wake_epoch = 0
    wake_time: Optional[float] = None

    heappop, heappush = heapq.heappop, heapq.heappush
    schedule = policy.schedule
    queue_depth = policy.queue_depth
    next_wakeup = policy.next_wakeup
    on_arrival = policy.on_arrival
    on_completion = policy.on_completion
    release = cluster.release
    while events:
        t = events[0][0]
        live = False  # any non-stale event at this timestamp?
        speed_changed: List[int] = []  # servers re-sped at t (factor > 0)
        downed: List[int] = []  # servers killed at t (factor == 0)
        while events and events[0][0] == t:
            _, kind, tag, payload = heappop(events)
            n_events += 1
            if kind == _COMPLETION:
                job, ep = payload
                if track_running:
                    r = running.get(job.job_id)
                    if r is None or ep != r.epoch:
                        continue  # superseded by a re-timing: stale entry
                    del running[job.job_id]
                    straggler_watch.discard(job.job_id)
                release(job.job_id)
                on_completion(t, job)
                n_completed += 1
                live = True
            elif kind == _ARRIVAL:
                on_arrival(t, payload)
                live = True
            elif kind == _FAULT:
                server_id, factor = payload
                if factor == 0.0:
                    # full failure: the PR-2 fault path verbatim (capacity
                    # forfeited; running jobs finish in place, un-re-timed)
                    cluster.mark_server_down(server_id)
                    if track_running:
                        downed.append(server_id)
                    live = True
                elif cluster.set_server_speed(server_id, factor):
                    speed_changed.append(server_id)
                    live = True
                # else: factor equals the current speed — a no-op event
                # (neither re-timing nor a scheduling pass; keeps all-1.0
                # degradation schedules identical to clean runs)
            else:  # _WAKE: no state change; just triggers a scheduling pass.
                if tag == wake_epoch:
                    wake_time = None
                    live = True
                # else: superseded wake — ignore.
        if not live:
            continue

        if downed and straggler_watch:
            # A job whose placement touches a *dead* server can never
            # checkpoint-restart (its checkpoint state lived there): drop
            # it from the watch — it finishes in place, PR-2 style.
            dead = set(downed)
            for jid in [
                j for j in straggler_watch
                if not dead.isdisjoint(running[j].placement)
            ]:
                straggler_watch.discard(jid)

        if speed_changed:
            # Re-time every running job touching a re-sped server under the
            # final (post-drain) speed map; jobs left on degraded capacity
            # join the straggler watchlist.
            changed = set(speed_changed)
            speeds = cluster.speed_factors
            down = cluster.downed_servers
            for jid, r in running.items():
                if changed.isdisjoint(r.placement):
                    continue
                if not down.isdisjoint(r.placement):
                    # straddles a dead server: it finishes in place at its
                    # last re-timed alpha (PR-2).  Re-timing here would
                    # evaluate the dead server at full speed — its _speed
                    # entry died with it — shrinking the completion.
                    continue
                if t > r.since:
                    r.iters_rem -= (t - r.since) / r.alpha
                    if r.iters_rem < 0.0:
                        r.iters_rem = 0.0
                    r.since = t
                a_new = timing.alpha(
                    r.job, r.placement, cluster_spec,
                    speeds=speeds or None,
                )
                if a_new != r.alpha:
                    r.alpha = a_new
                    r.epoch += 1
                    # r.since == t normally; for a job still inside a
                    # migration's restart window (since > t) the pending
                    # downtime is preserved, not re-counted as progress
                    completion = r.since + r.iters_rem * a_new
                    rec = records[jid]
                    rec.alpha = a_new
                    rec.completion = completion
                    heappush(
                        events,
                        (completion, _COMPLETION, next(seq), (r.job, r.epoch)),
                    )
                # (dead-straddlers never reach here — the `continue`
                # above — so no downed-server check is needed)
                if (
                    offer_migrations
                    and speeds
                    and not speeds.keys().isdisjoint(r.placement)
                ):
                    straggler_watch.add(jid)
                else:
                    straggler_watch.discard(jid)

        if straggler_watch:
            speeds = cluster.speed_factors
            if not speeds:
                # every straggler recovered or died (a downed server's jobs
                # finish in place at their last re-timed alpha — PR-2)
                straggler_watch.clear()
            else:
                candidates: List[_Running] = []
                for jid in sorted(straggler_watch):
                    r = running[jid]
                    if t > r.since:
                        # bring remaining-iteration bookkeeping to t so the
                        # stay-vs-move race compares current quantities
                        r.iters_rem -= (t - r.since) / r.alpha
                        if r.iters_rem < 0.0:
                            r.iters_rem = 0.0
                        r.since = t
                    candidates.append(r)
                for mig in policy.plan_migrations(t, cluster, candidates):
                    job = mig.job
                    if validate:
                        timing.validate_placement(job, mig.placement)
                    r = running[job.job_id]
                    r.placement = mig.placement
                    r.alpha = mig.alpha
                    r.epoch += 1
                    # computing resumes only after the restart downtime;
                    # parking ``since`` there keeps later re-timings from
                    # crediting the penalty window as iterations done
                    r.since = t + mig.penalty
                    completion = r.since + r.iters_rem * mig.alpha
                    rec = records[job.job_id]
                    rec.alpha = mig.alpha
                    rec.completion = completion
                    rec.servers = tuple(sorted(mig.placement))
                    rec.migrations += 1
                    n_migrations += 1
                    heappush(
                        events,
                        (completion, _COMPLETION, next(seq), (job, r.epoch)),
                    )
                    if speeds.keys().isdisjoint(mig.placement):
                        straggler_watch.discard(job.job_id)

        for start in schedule(t, cluster):
            job = start.job
            if validate:
                timing.validate_placement(job, start.placement)
            completion = t + job.n_iters * start.alpha
            records[job.job_id] = JobRecord(
                arrival=job.arrival,
                start=t,
                completion=completion,
                alpha=start.alpha,
                # placements never carry empty per-server vectors, so the
                # touched servers are exactly the placement keys
                servers=tuple(sorted(start.placement)),
            )
            if track_running:
                running[job.job_id] = _Running(
                    job=job,
                    placement=start.placement,
                    alpha=start.alpha,
                    iters_rem=float(job.n_iters),
                    since=t,
                )
                # a job *started* onto degraded capacity (a straggler can
                # still hold the most free GPUs) is as migratable as one
                # caught there by the event; placements never touch downed
                # servers, so no dead-server check is needed here
                if offer_migrations:
                    sp = cluster.speed_factors
                    if sp and not sp.keys().isdisjoint(start.placement):
                        straggler_watch.add(job.job_id)
            heappush(events, (completion, _COMPLETION, next(seq), (job, 0)))
        n_passes += 1
        depth = queue_depth()
        if depth > peak_depth:
            peak_depth = depth

        wake = next_wakeup(t)
        if wake is not None and wake > t and wake != wake_time:
            wake_epoch += 1
            wake_time = wake
            heappush(events, (wake, _WAKE, wake_epoch, None))

    if n_completed != len(jobs):
        missing = len(jobs) - n_completed
        raise RuntimeError(f"simulation ended with {missing} unfinished jobs")
    result.n_events = n_events
    result.n_sched_passes = n_passes
    result.peak_queue_depth = peak_depth
    result.n_migrations = n_migrations
    result.wall_s = _time.perf_counter() - wall0
    return result


# ---------------------------------------------------------------------------
# Shared helpers: per-config alpha bounds cache
# ---------------------------------------------------------------------------


class AlphaCache:
    """alpha_max / alpha-tilde_min per unique (stages, allreduce) config."""

    def __init__(self, cluster_spec: ClusterSpec):
        self.spec = cluster_spec
        self._cache: Dict[int, Tuple[float, float]] = {}

    def bounds(self, job: JobSpec) -> Tuple[float, float]:
        """Returns (alpha_max, alpha_min_tilde)."""
        key = job.config_key
        hit = self._cache.get(key)
        if hit is None:
            from . import heavy_edge as he  # local import to avoid cycle

            a_max = timing.alpha_max(job, self.spec)
            a_min = he.alpha_min_estimate(job, self.spec)
            # The consolidated estimate can only be <= the all-spread bound.
            a_max = max(a_max, a_min)
            hit = (a_max, a_min)
            self._cache[key] = hit
        return hit
