"""Heavy-Edge GPU mapping (paper Sec. IV-B, Fig. 2).

Given a job graph and a list of servers with available-GPU counts summing to
``g_i``, partition the vertices (stage replicas) into per-server groups so
that heavy communication edges stay inside a server.

Greedy procedure (faithful to the paper):
  1. sort servers by available GPUs, descending;
  2. for each server ``m`` with capacity ``c``:
     - if the remaining vertex count equals ``c``: assign all of them;
     - if ``c == 1``: assign the unassigned vertex with minimum total edge
       weight (to other unassigned vertices);
     - else: seed ``node_set`` with the heaviest remaining edge's endpoints,
       then repeatedly add the unassigned vertex connected to ``node_set`` by
       the heaviest edge; if none is connected, add an arbitrary
       (deterministically: smallest-id) unassigned vertex; stop at ``c``.

Ties are broken by vertex order for determinism.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .graph import JobGraph, Vertex, build_job_graph
from .job import ClusterSpec, JobSpec
from . import timing


def heavy_edge(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Map each vertex to a server id.

    ``server_caps``: (server_id, available_gpus) pairs; capacities must sum
    to the number of vertices.
    """
    total_cap = sum(c for _, c in server_caps)
    if total_cap != len(graph.vertices):
        raise ValueError(
            f"server capacities sum to {total_cap}, "
            f"job needs {len(graph.vertices)} GPUs"
        )
    # Descending capacity; stable on server id for determinism.
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))

    unassigned = set(graph.vertices)
    assignment: Dict[Vertex, int] = {}

    for server_id, cap in order:
        if cap <= 0:
            continue
        if cap >= len(unassigned):
            for v in sorted(unassigned):
                assignment[v] = server_id
            unassigned.clear()
            break
        if cap == 1:
            v = min(
                sorted(unassigned),
                key=lambda u: (
                    sum(
                        w
                        for nb, w in graph.neighbors(u).items()
                        if nb in unassigned
                    ),
                    u,
                ),
            )
            assignment[v] = server_id
            unassigned.discard(v)
            continue

        node_set: List[Vertex] = []
        # Seed with the heaviest edge among unassigned vertices.
        best_w, best_pair = -1.0, None
        for u in sorted(unassigned):
            for nb, w in graph.neighbors(u).items():
                if nb in unassigned and u < nb and w > best_w:
                    best_w, best_pair = w, (u, nb)
        if best_pair is None:
            node_set.append(min(unassigned))
        else:
            node_set.extend(best_pair)
        for v in node_set:
            unassigned.discard(v)

        while len(node_set) < cap and unassigned:
            best_w, best_v = -1.0, None
            for u in node_set:
                for nb, w in graph.neighbors(u).items():
                    if nb in unassigned and (
                        w > best_w or (w == best_w and (best_v is None or nb < best_v))
                    ):
                        best_w, best_v = w, nb
            if best_v is None:  # disconnected: arbitrary (smallest) vertex
                best_v = min(unassigned)
            node_set.append(best_v)
            unassigned.discard(best_v)

        for v in node_set:
            assignment[v] = server_id

    if unassigned:
        raise AssertionError("heavy_edge left vertices unassigned")
    return assignment


def refine_assignment(
    graph: JobGraph,
    assignment: Dict[Vertex, int],
    max_passes: int = 3,
) -> Dict[Vertex, int]:
    """Beyond-paper local search: best-improvement pairwise swaps.

    The paper's greedy is myopic (it can split an AllReduce ring whose
    members it seeded apart); a few swap passes repair those cases at
    O(V^2 * deg) cost — still micro-seconds at job scale.  Kept separate so
    the faithful baseline remains measurable (see benchmarks/table2).
    """
    assign = dict(assignment)

    def delta_swap(u: Vertex, v: Vertex) -> float:
        su, sv = assign[u], assign[v]
        d = 0.0
        for nb, w in graph.neighbors(u).items():
            if nb == v:
                continue
            if assign[nb] == su:
                d += w  # u leaves its server: edge becomes cut
            elif assign[nb] == sv:
                d -= w  # u joins v's server: edge becomes internal
        for nb, w in graph.neighbors(v).items():
            if nb == u:
                continue
            if assign[nb] == sv:
                d += w
            elif assign[nb] == su:
                d -= w
        return d

    verts = sorted(graph.vertices)
    for _ in range(max_passes):
        best = (0.0, None)
        for i, u in enumerate(verts):
            for v in verts[i + 1 :]:
                if assign[u] == assign[v]:
                    continue
                d = delta_swap(u, v)
                if d < best[0] - 1e-12:
                    best = (d, (u, v))
        if best[1] is None:
            break
        u, v = best[1]
        assign[u], assign[v] = assign[v], assign[u]
    return assign


def contiguous_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Second seed for the local search: fill servers in (stage, replica)
    order, which tends to keep AllReduce rings and pipeline neighbours
    together when capacities align with stage sizes."""
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))
    assign: Dict[Vertex, int] = {}
    it = iter(sorted(graph.vertices))
    for server_id, cap in order:
        for _ in range(cap):
            assign[next(it)] = server_id
    return assign


def stage_aligned_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Third seed: best-fit-decreasing bin packing of *whole stages*.

    Swap-based local search cannot relabel an entire AllReduce ring; packing
    stages as units (heaviest internal weight first, tightest-fitting server)
    finds those placements directly.  Spillover vertices fall back to the
    heaviest-connection rule.
    """
    from collections import defaultdict

    stages = defaultdict(list)
    for v in sorted(graph.vertices):
        stages[v[0]].append(v)

    def internal_weight(verts):
        vs = set(verts)
        return sum(
            w for (u, v), w in graph.edges.items() if u in vs and v in vs
        )

    order = sorted(
        stages.values(), key=lambda vs: (-internal_weight(vs), vs[0])
    )
    free = dict(server_caps)
    assign: Dict[Vertex, int] = {}
    leftovers: List[Vertex] = []
    for verts in order:
        # tightest server that fits the whole stage
        fits = [m for m, c in free.items() if c >= len(verts)]
        if fits:
            m = min(fits, key=lambda m_: (free[m_], m_))
            for v in verts:
                assign[v] = m
            free[m] -= len(verts)
        else:
            leftovers.extend(verts)
    for v in leftovers:
        # most-connected server with capacity, else any with capacity
        best_m, best_w = None, -1.0
        for m, c in free.items():
            if c <= 0:
                continue
            w = sum(
                wt for nb, wt in graph.neighbors(v).items()
                if assign.get(nb) == m
            )
            if w > best_w:
                best_w, best_m = w, m
        assign[v] = best_m
        free[best_m] -= 1
    return assign


def map_job(
    job: JobSpec,
    server_caps: Sequence[Tuple[int, int]],
    cluster: ClusterSpec,
    refine: bool = False,
) -> Tuple[Dict[int, np.ndarray], float]:
    """Run Heavy-Edge (optionally multi-start + local search).

    ``refine`` (beyond-paper): swap-based local search from three seeds
    (the paper's greedy, a contiguous fill, and whole-stage bin packing),
    keeping the placement with the lowest per-iteration time alpha.
    """
    graph = build_job_graph(job)
    assignment = heavy_edge(graph, server_caps)
    placement = timing.placement_from_assignment(job, assignment)
    best_alpha = timing.alpha(job, placement, cluster)
    if refine:
        seeds = (
            assignment,
            contiguous_assignment(graph, server_caps),
            stage_aligned_assignment(graph, server_caps),
        )
        for seed in seeds:
            cand = refine_assignment(graph, seed)
            cand_placement = timing.placement_from_assignment(job, cand)
            a = timing.alpha(job, cand_placement, cluster)
            if a < best_alpha - 1e-12:
                best_alpha, placement = a, cand_placement
    return placement, best_alpha


def consolidated_caps(job: JobSpec, cluster: ClusterSpec) -> List[Tuple[int, int]]:
    """Fewest-servers capacity profile: full servers + one remainder."""
    g = cluster.gpus_per_server
    n_full, rem = divmod(job.g, g)
    caps = [(m, g) for m in range(n_full)]
    if rem:
        caps.append((n_full, rem))
    return caps


def alpha_min_estimate(job: JobSpec, cluster: ClusterSpec) -> float:
    """alpha-tilde_i^min (paper Sec. IV-B): Heavy-Edge on the consolidated
    (fewest possible servers, fully packed) allocation."""
    _, a = map_job(job, consolidated_caps(job, cluster), cluster)
    return a


def select_servers(
    free: Mapping[int, int], g_needed: int, consolidate: bool
) -> List[Tuple[int, int]]:
    """Pick servers/GPU counts for a job (paper Alg. 1 lines 9 and 22).

    ``consolidate=True``  -> most-available-first (communication-heavy jobs);
    ``consolidate=False`` -> least-available-first (fragmentation-aware
                             placement of non-communication-heavy jobs).
    Returns (server_id, gpus_taken) or raises if capacity is insufficient.
    """
    candidates = [(m, c) for m, c in free.items() if c > 0]
    if sum(c for _, c in candidates) < g_needed:
        raise ValueError("not enough free GPUs")
    candidates.sort(key=lambda mc: (-mc[1], mc[0]) if consolidate else (mc[1], mc[0]))
    picks: List[Tuple[int, int]] = []
    remaining = g_needed
    for m, c in candidates:
        take = min(c, remaining)
        picks.append((m, take))
        remaining -= take
        if remaining == 0:
            break
    return picks
