"""Heavy-Edge GPU mapping (paper Sec. IV-B, Fig. 2).

Given a job graph and a list of servers with available-GPU counts summing to
``g_i``, partition the vertices (stage replicas) into per-server groups so
that heavy communication edges stay inside a server.

Greedy procedure (faithful to the paper):
  1. sort servers by available GPUs, descending;
  2. for each server ``m`` with capacity ``c``:
     - if the remaining vertex count equals ``c``: assign all of them;
     - if ``c == 1``: assign the unassigned vertex with minimum total edge
       weight (to other unassigned vertices);
     - else: seed ``node_set`` with the heaviest remaining edge's endpoints,
       then repeatedly add the unassigned vertex connected to ``node_set`` by
       the heaviest edge; if none is connected, add an arbitrary
       (deterministically: smallest-id) unassigned vertex; stop at ``c``.

Ties are broken by vertex order for determinism.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .graph import DenseGraph, JobGraph, Vertex, build_job_graph
from .job import ClusterSpec, JobSpec, ServerGeom
from . import timing


def heavy_edge_reference(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Pure-Python greedy (the paper's procedure, dict walks).

    Retained as the property-test reference for the array-native
    ``heavy_edge`` (tests/test_vectorized.py) and used by the reference
    engine (``map_job(..., reference=True)``).
    """
    total_cap = sum(c for _, c in server_caps)
    if total_cap != len(graph.vertices):
        raise ValueError(
            f"server capacities sum to {total_cap}, "
            f"job needs {len(graph.vertices)} GPUs"
        )
    # Descending capacity; stable on server id for determinism.
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))

    unassigned = set(graph.vertices)
    assignment: Dict[Vertex, int] = {}

    for server_id, cap in order:
        if cap <= 0:
            continue
        if cap >= len(unassigned):
            for v in sorted(unassigned):
                assignment[v] = server_id
            unassigned.clear()
            break
        if cap == 1:
            v = min(
                sorted(unassigned),
                key=lambda u: (
                    sum(
                        w
                        for nb, w in graph.neighbors(u).items()
                        if nb in unassigned
                    ),
                    u,
                ),
            )
            assignment[v] = server_id
            unassigned.discard(v)
            continue

        node_set: List[Vertex] = []
        # Seed with the heaviest edge among unassigned vertices.
        best_w, best_pair = -1.0, None
        for u in sorted(unassigned):
            for nb, w in graph.neighbors(u).items():
                if nb in unassigned and u < nb and w > best_w:
                    best_w, best_pair = w, (u, nb)
        if best_pair is None:
            node_set.append(min(unassigned))
        else:
            node_set.extend(best_pair)
        for v in node_set:
            unassigned.discard(v)

        while len(node_set) < cap and unassigned:
            best_w, best_v = -1.0, None
            for u in node_set:
                for nb, w in graph.neighbors(u).items():
                    if nb in unassigned and (
                        w > best_w or (w == best_w and (best_v is None or nb < best_v))
                    ):
                        best_w, best_v = w, nb
            if best_v is None:  # disconnected: arbitrary (smallest) vertex
                best_v = min(unassigned)
            node_set.append(best_v)
            unassigned.discard(best_v)

        for v in node_set:
            assignment[v] = server_id

    if unassigned:
        raise AssertionError("heavy_edge left vertices unassigned")
    return assignment


def _min_weight_vertex(
    graph: JobGraph, d: DenseGraph, mask: np.ndarray
) -> int:
    """Capacity-1 branch of the greedy, verbatim from the reference.

    The reference sums each candidate's edge weights in adjacency
    *insertion* order (Python float addition); replicating that exact
    accumulation vectorized would cost more than the branch is worth —
    single-GPU slots pick one vertex — so the array engine shares this
    code with the reference instead of mirroring it.
    """
    verts = d.verts
    unassigned = {verts[i] for i in np.flatnonzero(mask)}
    v = min(
        sorted(unassigned),
        key=lambda u: (
            sum(
                w
                for nb, w in graph.neighbors(u).items()
                if nb in unassigned
            ),
            u,
        ),
    )
    return d.index[v]


def _heavy_edge_positions(
    graph: JobGraph,
    d: DenseGraph,
    caps: Sequence[int],
    order: Sequence[int],
) -> np.ndarray:
    """Array-native greedy: vertex index -> position in ``caps``.

    Same procedure and tiebreaks as ``heavy_edge_reference``, expressed on
    the dense weight matrix:

    * the "heaviest remaining edge" seed is the first edge of the
      config's precomputed ``(-w, a, rank)``-sorted edge list whose
      endpoints are both unassigned (one masked ``argmax`` instead of the
      nested neighbor scan);
    * growth keeps ``maxw[v] = max edge weight from node_set to v``
      incrementally (``np.maximum`` with the newly added row) and picks
      the next vertex by masked ``argmax`` — argmax's first-max rule is
      exactly the reference's ``nb < best_v`` tiebreak, and an all-zero
      candidate row degrades to the reference's "smallest unassigned
      vertex" fallback for disconnected remainders.
    """
    n = len(d.verts)
    out = np.empty(n, dtype=np.int64)
    mask = np.ones(n, dtype=bool)
    n_un = n
    W = d.W
    ea, eb = d.edge_a, d.edge_b
    have_edges = len(ea) > 0
    for p in order:
        cap = caps[p]
        if cap <= 0:
            continue
        if cap >= n_un:
            out[mask] = p
            n_un = 0
            break
        if cap == 1:
            i0 = _min_weight_vertex(graph, d, mask)
            out[i0] = p
            mask[i0] = False
            n_un -= 1
            continue
        seeded2 = False
        if have_edges:
            ok = mask[ea] & mask[eb]
            e = int(ok.argmax())
            seeded2 = bool(ok[e])
        if seeded2:
            i0, j0 = int(ea[e]), int(eb[e])
            out[i0] = out[j0] = p
            mask[i0] = mask[j0] = False
            n_un -= 2
            count = 2
            maxw = np.maximum(W[i0], W[j0])
        else:
            i0 = int(mask.argmax())  # first unassigned == smallest vertex
            out[i0] = p
            mask[i0] = False
            n_un -= 1
            count = 1
            maxw = W[i0].copy()
        while count < cap and n_un:
            v = int(np.where(mask, maxw, -np.inf).argmax())
            out[v] = p
            mask[v] = False
            n_un -= 1
            count += 1
            if count < cap and n_un:
                np.maximum(maxw, W[v], out=maxw)
    if n_un:
        raise AssertionError("heavy_edge left vertices unassigned")
    return out


def heavy_edge(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Map each vertex to a server id (array-native greedy).

    ``server_caps``: (server_id, available_gpus) pairs; capacities must sum
    to the number of vertices.  Bit-identical to ``heavy_edge_reference``
    (property-tested in tests/test_vectorized.py).
    """
    total_cap = sum(c for _, c in server_caps)
    if total_cap != len(graph.vertices):
        raise ValueError(
            f"server capacities sum to {total_cap}, "
            f"job needs {len(graph.vertices)} GPUs"
        )
    d = graph.dense()
    ids = [m for m, _c in server_caps]
    caps = [c for _m, c in server_caps]
    order = sorted(range(len(ids)), key=lambda p: (-caps[p], ids[p]))
    pos = _heavy_edge_positions(graph, d, caps, order)
    return {v: ids[pos[i]] for i, v in enumerate(d.verts)}


def _bw_weights(
    servers: Sequence[int],
    geoms: Optional[Mapping[int, ServerGeom]],
    speeds: Optional[Mapping[int, float]] = None,
) -> Optional[np.ndarray]:
    """Normalized inverse effective-bandwidth weights over ``servers``.

    The single definition of the weight chain ``refine_assignment`` and
    ``_position_r_server`` share: inverse of ``b_inter * speed`` per
    server (bandwidth from ``geoms``, 1.0 when absent), None when the
    weights are uniform, else scale-free-normalized so the improvement
    threshold stays in the same (byte-weight) units as the unweighted
    objective.  Order of ``servers`` fixes the summation order, hence
    the exact floats — callers pass sorted ids.
    """
    if speeds and any(speeds.get(m, 1.0) != 1.0 for m in servers):
        sget = speeds.get
        if geoms is not None:
            inv = np.array(
                [1.0 / (geoms[m][1] * sget(m, 1.0)) for m in servers]
            )
        else:
            inv = np.array([1.0 / sget(m, 1.0) for m in servers])
    elif geoms is not None:
        inv = np.array([1.0 / geoms[m][1] for m in servers])
    else:
        return None
    if np.all(inv == inv[0]):
        return None
    return inv * (len(inv) / inv.sum())


def refine_assignment(
    graph: JobGraph,
    assignment: Dict[Vertex, int],
    max_passes: int = 3,
    geoms: Optional[Mapping[int, ServerGeom]] = None,
    speeds: Optional[Mapping[int, float]] = None,
) -> Dict[Vertex, int]:
    """Beyond-paper local search: best-improvement pairwise swaps.

    The paper's greedy is myopic (it can split an AllReduce ring whose
    members it seeded apart); a few swap passes repair those cases.  The
    swap deltas are evaluated for *all* vertex pairs at once on an
    adjacency matrix: with ``D[k, u]`` the total weight from vertex ``u``
    into server ``k`` and ``s`` the current assignment,

        delta(u, v) = (D[s_u,u] - D[s_v,u]) + (D[s_v,v] - D[s_u,v]) + 2 W[u,v]

    (the ``2 W[u,v]`` corrects for the u-v edge itself, which stays cut).
    Kept separate from the faithful greedy so the paper baseline remains
    measurable (see benchmarks/table2).

    ``geoms`` (heterogeneous clusters) switches the objective from the raw
    cut weight to the *bandwidth-weighted* cut: an edge crossing servers
    ``a, b`` costs ``w * (r_a + r_b)`` with ``r_k`` the inverse NIC
    bandwidth of ``k`` — cutting an AllReduce onto a slow-NIC server is
    penalized more than onto a fast one.  The weighted objective
    decomposes per vertex (``C = sum_x r[s_x] * cut_x``), so the swap
    delta stays a single vectorized expression:

        delta(u, v) =   r_u * (2 D[s_u,u] - 2 D[s_u,v] + 2 W[u,v] + T_v - T_u)
                      + r_v * (2 D[s_v,v] - 2 D[s_v,u] + 2 W[u,v] + T_u - T_v)

    with ``T_x`` the total incident weight of ``x``.  When every server's
    bandwidth is equal this reduces to exactly ``2 r`` times the
    homogeneous delta, so the unweighted formula is kept verbatim on that
    path (identical swap sequences — no behavior change).

    ``speeds`` (degraded clusters) folds each server's speed factor into
    its effective NIC bandwidth (``b_inter * f``): cutting an edge onto a
    degraded server is penalized like cutting onto a proportionally
    slower NIC.  Absent/all-1.0 factors leave the objective untouched.
    """
    d = graph.dense()
    verts = d.verts
    n = len(verts)
    if n < 2:
        return dict(assignment)
    W = d.W  # cached per config; values identical to the per-call rebuild

    servers = sorted({assignment[v] for v in verts})
    server_index = {m: k for k, m in enumerate(servers)}
    s = np.array([server_index[assignment[v]] for v in verts])
    arange = d.arange

    r_server = _bw_weights(servers, geoms, speeds)
    tot = d.incident if r_server is not None else None

    for _ in range(max_passes):
        ind = np.zeros((len(servers), n))
        ind[s, arange] = 1.0
        D = ind @ W  # D[k, u]: weight from vertex u into server k
        Ds = D[s]  # Ds[j, u] = D[s_j, u]
        d_own = Ds[arange, arange]
        if r_server is None:
            delta = (
                (d_own[:, None] - Ds.T) + (d_own[None, :] - Ds) + 2.0 * W
            )
        else:
            rv = r_server[s]
            base = (
                2.0 * d_own[:, None] - 2.0 * Ds + 2.0 * W
                + tot[None, :] - tot[:, None]
            )
            delta = rv[:, None] * base + rv[None, :] * base.T
        # only ordered pairs on different servers are candidate swaps
        invalid = (s[:, None] == s[None, :]) | d.swap_invalid
        delta[invalid] = np.inf
        flat = int(np.argmin(delta))
        i, j = divmod(flat, n)
        if delta[i, j] >= -1e-12:
            break
        s[i], s[j] = s[j], s[i]

    return {v: servers[s[i]] for i, v in enumerate(verts)}


def _refine_positions_batched(
    d: DenseGraph,
    seeds: np.ndarray,
    K: int,
    r_server: Optional[np.ndarray],
    max_passes: int = 3,
) -> np.ndarray:
    """``refine_assignment`` for a whole stack of seeds at once.

    ``seeds``: (B, n) position arrays over the same ``K`` capacity slots.
    Each row follows exactly the trajectory ``refine_assignment`` would
    (same matmul shapes per slice, same association order, same argmin
    flat-index tiebreak), so the results are bit-identical per seed while
    the numpy call count is paid once for the batch instead of per seed.
    Rows freeze as soon as their best swap stops improving; ``r_server``
    is indexed by position (see ``_position_r_server``).
    """
    B, n = seeds.shape
    W = d.W
    arange = d.arange
    S_ = seeds  # owned by this call: rows are refined in place
    tot = d.incident if r_server is not None else None
    if B == 1:
        # single distinct seed: the 2-D ops of the reference loop verbatim
        # (no batch gathers)
        s = S_[0]
        for _ in range(max_passes):
            ind = np.zeros((K, n))
            ind[s, arange] = 1.0
            D = ind @ W
            Ds = D[s]
            d_own = Ds[arange, arange]
            if r_server is None:
                delta = (
                    (d_own[:, None] - Ds.T) + (d_own[None, :] - Ds) + 2.0 * W
                )
            else:
                rv = r_server[s]
                base = (
                    2.0 * d_own[:, None] - 2.0 * Ds + 2.0 * W
                    + tot[None, :] - tot[:, None]
                )
                delta = rv[:, None] * base + rv[None, :] * base.T
            invalid = (s[:, None] == s[None, :]) | d.swap_invalid
            delta[invalid] = np.inf
            f = int(delta.argmin())
            i, j = f // n, f % n
            if delta[i, j] >= -1e-12:
                break
            s[i], s[j] = s[j], s[i]
        return S_
    act = list(range(B))  # rows still swapping; frozen rows drop out
    for _ in range(max_passes):
        b_n = len(act)
        Sa = S_[act]
        bcol = np.arange(b_n)[:, None]
        IND = np.zeros((b_n, K, n))
        IND[bcol, Sa, arange] = 1.0
        D = IND @ W  # per-slice dgemm == the reference's 2-D matmul
        Ds = D[bcol, Sa]  # Ds[b, j, u] = D[b, s_j, u]
        d_own = Ds[:, arange, arange]
        if r_server is None:
            delta = (
                (d_own[:, :, None] - Ds.transpose(0, 2, 1))
                + (d_own[:, None, :] - Ds)
                + 2.0 * W
            )
        else:
            rv = r_server[Sa]
            base = (
                2.0 * d_own[:, :, None] - 2.0 * Ds + 2.0 * W
                + tot[None, None, :] - tot[None, :, None]
            )
            delta = (
                rv[:, :, None] * base
                + rv[:, None, :] * base.transpose(0, 2, 1)
            )
        invalid = (Sa[:, :, None] == Sa[:, None, :]) | d.swap_invalid
        delta[invalid] = np.inf
        flat = delta.reshape(b_n, -1).argmin(axis=1)
        # scalar reads beat fancy gathers at this batch width (<= 3 rows)
        still = []
        for k in range(b_n):
            f = int(flat[k])
            i, j = f // n, f % n
            if delta[k, i, j] < -1e-12:
                b = act[k]
                S_[b, i], S_[b, j] = S_[b, j], S_[b, i]
                still.append(b)
        act = still
        if not act:
            break
    return S_


def _position_r_server(
    ids: Sequence[int],
    geoms: Optional[Mapping[int, ServerGeom]],
    speeds: Optional[Mapping[int, float]] = None,
) -> Optional[np.ndarray]:
    """``refine_assignment``'s bandwidth weights, permuted to positions.

    The reference normalizes over servers in sorted-id order (see
    ``_bw_weights``); summing in any other order could shift the last
    ulp, so the shared chain runs in that exact order before re-indexing
    by the caller's position layout.
    """
    servers = sorted(ids)
    r = _bw_weights(servers, geoms, speeds)
    if r is None:
        return None
    lookup = {m: r[k] for k, m in enumerate(servers)}
    return np.array([lookup[m] for m in ids])


def contiguous_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Second seed for the local search: fill servers in (stage, replica)
    order, which tends to keep AllReduce rings and pipeline neighbours
    together when capacities align with stage sizes."""
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))
    assign: Dict[Vertex, int] = {}
    it = iter(sorted(graph.vertices))
    for server_id, cap in order:
        for _ in range(cap):
            assign[next(it)] = server_id
    return assign


def stage_aligned_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Third seed: best-fit-decreasing bin packing of *whole stages*.

    Swap-based local search cannot relabel an entire AllReduce ring; packing
    stages as units (heaviest internal weight first, tightest-fitting server)
    finds those placements directly.  Spillover vertices fall back to the
    heaviest-connection rule.
    """
    from collections import defaultdict

    stages = defaultdict(list)
    for v in sorted(graph.vertices):
        stages[v[0]].append(v)

    # one pass over the edges: intra-stage weight per stage
    internal = defaultdict(float)
    for (u, v), w in graph.edges.items():
        if u[0] == v[0]:
            internal[u[0]] += w

    order = sorted(
        stages.values(), key=lambda vs: (-internal[vs[0][0]], vs[0])
    )
    free = dict(server_caps)
    assign: Dict[Vertex, int] = {}
    leftovers: List[Vertex] = []
    for verts in order:
        # tightest server that fits the whole stage
        need = len(verts)
        best = None
        for m, c in free.items():
            if c >= need and (best is None or (c, m) < best):
                best = (c, m)
        if best is not None:
            m = best[1]
            for v in verts:
                assign[v] = m
            free[m] -= need
        else:
            leftovers.extend(verts)
    for v in leftovers:
        # most-connected server with capacity, else any with capacity
        best_m, best_w = None, -1.0
        for m, c in free.items():
            if c <= 0:
                continue
            w = sum(
                wt for nb, wt in graph.neighbors(v).items()
                if assign.get(nb) == m
            )
            if w > best_w:
                best_w, best_m = w, m
        assign[v] = best_m
        free[best_m] -= 1
    return assign


def _contiguous_positions(
    d: DenseGraph, caps: Sequence[int], order: Sequence[int]
) -> np.ndarray:
    """``contiguous_assignment`` as a position array: verts are sorted and
    the fill order is exactly ``order``, so it is one ``np.repeat``."""
    return np.repeat(
        np.array(order, dtype=np.int64),
        np.array([caps[p] for p in order]),
    )


def _stage_aligned_positions(
    graph: JobGraph,
    d: DenseGraph,
    server_caps: Sequence[Tuple[int, int]],
) -> np.ndarray:
    """``stage_aligned_assignment`` as a position array.

    Bin packing and spillover run as plain Python over the dense form's
    cached structures (intra-stage weights, contiguous stage slices,
    insertion-ordered neighbor lists) — the problem sizes (vertices,
    servers, stages) are tiny, so scalar loops beat per-op numpy
    dispatch while replicating the reference's float-accumulation
    sequences and first-max-in-caps-order tiebreak exactly (positions
    enumerate ``server_caps``, the reference's ``free.items()`` order).
    """
    ids = [m for m, _c in server_caps]
    internal = d.stage_internal
    order = sorted(range(d.n_stages), key=lambda st: (-internal[st], st))
    free = [c for _m, c in server_caps]
    K = len(free)
    bounds = d.stage_bounds
    n = len(d.verts)
    pos = [0] * n
    placed = [True] * n
    spill: List[int] = []
    for st in order:
        b0, b1 = int(bounds[st]), int(bounds[st + 1])
        need = b1 - b0
        best = None
        best_p = -1
        for p in range(K):
            c = free[p]
            if c >= need and (best is None or (c, ids[p]) < best):
                best = (c, ids[p])
                best_p = p
        if best is None:
            for i in range(b0, b1):
                placed[i] = False
            spill.append(st)
            continue
        for i in range(b0, b1):
            pos[i] = best_p
        free[best_p] -= need
    if spill:
        nbr_pairs = d.nbr_pairs
        wsum = [0.0] * K
        for st in spill:
            for i in range(int(bounds[st]), int(bounds[st + 1])):
                for p in range(K):
                    wsum[p] = 0.0
                for nb, w in nbr_pairs[i]:
                    if placed[nb]:
                        wsum[pos[nb]] += w
                best_w = -1.0
                best_p = -1
                for p in range(K):
                    if free[p] > 0 and wsum[p] > best_w:
                        best_w = wsum[p]
                        best_p = p
                pos[i] = best_p
                placed[i] = True
                free[best_p] -= 1
    return np.array(pos, dtype=np.int64)


def _placement_matrices(
    d: DenseGraph, positions: np.ndarray, K: int, S: int
) -> np.ndarray:
    """(B, n) position arrays -> (B, K, S) GPU matrices via one bincount."""
    B = positions.shape[0]
    KS = K * S
    offs = (np.arange(B) * KS)[:, None]
    flat = (positions * S + d.stage_of) + offs
    return np.bincount(flat.ravel(), minlength=B * KS).reshape(B, K, S)


def map_job(
    job: JobSpec,
    server_caps: Sequence[Tuple[int, int]],
    cluster: ClusterSpec,
    refine: bool = False,
    graph: Optional[JobGraph] = None,
    geoms: Optional[Mapping[int, ServerGeom]] = None,
    reference: bool = False,
    speeds: Optional[Sequence[float]] = None,
    _het_ctx: Optional[tuple] = None,
    _seed_cache: Optional[Dict[tuple, list]] = None,
) -> Tuple[Dict[int, np.ndarray], float]:
    """Run Heavy-Edge (optionally multi-start + local search).

    ``refine`` (beyond-paper): swap-based local search from three seeds
    (the paper's greedy, a contiguous fill, and whole-stage bin packing),
    keeping the placement with the lowest per-iteration time alpha.
    ``graph``: pre-built communication graph (it depends only on the job
    config, so callers mapping recurring jobs can share one).
    ``geoms``: per-server geometry override for the alpha evaluation
    (required when ``server_caps`` uses rank labels on a heterogeneous
    cluster; see ``map_job_canonical``).
    ``reference``: run the retained pure-Python pipeline (dict-walk greedy
    + per-(server, stage) beta alpha) instead of the array engine; the two
    are bit-identical (tests/test_vectorized.py) and the reference backs
    the uncached A-SRPT engine the property tests simulate against.
    ``speeds``: per-slot degradation factors aligned with ``server_caps``
    (see timing.py) — they stretch the alpha evaluation and fold into the
    refine objective's effective bandwidths; the greedy itself is
    weight-only and unaffected.  All-1.0 (or None) is the clean path.
    ``_het_ctx``: PlacementCache-precomputed (rank geoms, geometry
    columns, r_server) for the caller's class layout, shared across every
    capacity shape with the same classes (same values as the per-call
    construction, computed once).
    ``_seed_cache``: (config, caps) -> [seeds, uniq, uniq_of, refined-by-
    bandwidth-pattern] (heterogeneous clusters): the greedy and both
    auxiliary seeds are pure functions of the config and capacity vector
    — they never read server classes — so distinct class layouts over the
    same caps share them; the batched-refine output depends on geometry
    only through the per-slot NIC-bandwidth pattern (the ``r_server``
    weights), so layouts sharing that pattern share it too.  Entries hold
    exactly the arrays recomputation would produce and are immutable.
    """
    if graph is None:
        graph = build_job_graph(job)
    if _het_ctx is not None:
        geoms = _het_ctx[0]
    elif geoms is None and cluster.is_heterogeneous:
        # caller passed physical ids on a mixed cluster: resolve their
        # geometry here so refine + alpha see the per-class bandwidths
        geoms = {m: cluster.server_geom(m) for m, _c in server_caps}
    if speeds is not None and all(f == 1.0 for f in speeds):
        speeds = None  # normalize: full speed everywhere == clean path
    speed_by_id = (
        {m: f for (m, _c), f in zip(server_caps, speeds) if f != 1.0}
        if speeds is not None
        else None
    )
    if reference:
        assignment = heavy_edge_reference(graph, server_caps)
        placement = timing.placement_from_assignment(job, assignment)
        best_alpha = timing.alpha_reference(
            job, placement, cluster, geoms=geoms, speeds=speed_by_id
        )
        if refine:
            seeds = (
                assignment,
                contiguous_assignment(graph, server_caps),
                stage_aligned_assignment(graph, server_caps),
            )
            for seed in seeds:
                cand = refine_assignment(
                    graph, seed, geoms=geoms, speeds=speed_by_id
                )
                cand_placement = timing.placement_from_assignment(job, cand)
                a = timing.alpha_reference(
                    job, cand_placement, cluster, geoms=geoms,
                    speeds=speed_by_id,
                )
                if a < best_alpha - 1e-12:
                    best_alpha, placement = a, cand_placement
        return placement, best_alpha

    # -- array-native engine -------------------------------------------------
    d = graph.dense()
    n = len(d.verts)
    total_cap = sum(c for _m, c in server_caps)
    if total_cap != n:
        raise ValueError(
            f"server capacities sum to {total_cap}, job needs {n} GPUs"
        )
    ids = [m for m, _c in server_caps]
    caps = [c for _m, c in server_caps]
    K = len(ids)
    S = job.num_stages
    if _het_ctx is not None:
        g_col, bi_col, bx_col = _het_ctx[1]
    elif geoms is not None:
        g_col, bi_col, bx_col = timing._geom_columns(ids, cluster, geoms)
    else:
        g_col, bi_col, bx_col = (
            cluster.gpus_per_server, cluster.b_inter, cluster.b_intra
        )
    f_col = np.array(speeds)[:, None] if speeds is not None else None
    if speeds is not None:
        # degraded mode is rare and speed-dependent: don't pollute the
        # speed-agnostic shared seed/refine store
        _seed_cache = None
    if K == 1:
        # single server: every seed and every swap collapses to the same
        # trivial placement, so only the alpha evaluation remains
        X = np.bincount(d.stage_of, minlength=S)[None, :]
        a = timing.alpha_matrix(job, X, g_col, bi_col, bx_col, speed=f_col)
        return {ids[0]: X[0]}, a

    def _order():
        # canonical callers (PlacementCache ranks) pass caps sorted
        # descending with ids ascending — (-cap, id) order is the identity
        if all(caps[p] >= caps[p + 1] for p in range(K - 1)) and (
            ids == sorted(ids)
        ):
            return range(K)
        return sorted(range(K), key=lambda p: (-caps[p], ids[p]))

    if not refine:
        pos_greedy = _heavy_edge_positions(graph, d, caps, _order())
        X0 = _placement_matrices(d, pos_greedy[None, :], K, S)[0]
        best_alpha = timing.alpha_matrix(
            job, X0, g_col, bi_col, bx_col, speed=f_col
        )
        best_X = X0
    else:
        ent = None
        if _seed_cache is not None:
            sc_key = (job.config_key, tuple(caps))
            ent = _seed_cache.get(sc_key)
        if ent is None:
            order = _order()
            seeds = [
                _heavy_edge_positions(graph, d, caps, order),
                _contiguous_positions(d, caps, order),
                _stage_aligned_positions(graph, d, server_caps),
            ]
            # identical seeds refine identically: batch the distinct rows
            uniq: List[np.ndarray] = []
            uniq_of: List[int] = []
            seen: Dict[bytes, int] = {}
            for s_arr in seeds:
                key = s_arr.tobytes()
                idx = seen.get(key)
                if idx is None:
                    idx = seen[key] = len(uniq)
                    uniq.append(s_arr)
                uniq_of.append(idx)
            ent = [seeds, uniq, uniq_of, {}]
            if _seed_cache is not None:
                _seed_cache[sc_key] = ent
        seeds, uniq, uniq_of = ent[0], ent[1], ent[2]
        pos_greedy = seeds[0]
        if _het_ctx is not None:
            r_server = _het_ctx[2]
            bw_key = _het_ctx[3]
        else:
            r_server = _position_r_server(ids, geoms, speed_by_id)
            bw_key = ()  # hom callers: r_server is None
        refined = ent[3].get(bw_key)
        if refined is None:
            seed_mat = np.empty((len(uniq), n), dtype=np.int64)
            for u_i, row in enumerate(uniq):
                seed_mat[u_i] = row
            refined = _refine_positions_batched(d, seed_mat, K, r_server)
            ent[3][bw_key] = refined
        # one batched alpha evaluation: the unrefined greedy placement
        # (the pre-refine incumbent) plus every distinct refined candidate
        rows = [pos_greedy] + list(refined)
        cand_uniq: List[np.ndarray] = []
        cand_of: List[int] = []
        seen2: Dict[bytes, int] = {}
        for r_arr in rows:
            key = r_arr.tobytes()
            idx = seen2.get(key)
            if idx is None:
                idx = seen2[key] = len(cand_uniq)
                cand_uniq.append(r_arr)
            cand_of.append(idx)
        cand_mat = np.empty((len(cand_uniq), n), dtype=np.int64)
        for u_i, row in enumerate(cand_uniq):
            cand_mat[u_i] = row
        Xs = _placement_matrices(d, cand_mat, K, S)
        alphas = timing.alpha_matrix(
            job, Xs, g_col, bi_col, bx_col, speed=f_col
        )
        best_u = cand_of[0]
        best_alpha = float(alphas[best_u])
        # replay the reference's sequential best-of comparison in seed order
        for c_seed in range(len(seeds)):
            u = cand_of[1 + uniq_of[c_seed]]
            a = float(alphas[u])
            if a < best_alpha - 1e-12:
                best_alpha = a
                best_u = u
        best_X = Xs[best_u]
    placement = {
        ids[p]: best_X[p] for p in range(K) if caps[p] > 0
    }
    return placement, best_alpha


def _rank_geoms(
    cluster: ClusterSpec, server_caps: Sequence[Tuple[int, int]]
) -> Optional[Dict[int, ServerGeom]]:
    """Rank -> geometry of the physical server holding that rank (het only)."""
    if not cluster.is_heterogeneous:
        return None
    return {
        i: cluster.server_geom(m) for i, (m, _c) in enumerate(server_caps)
    }


def map_job_canonical(
    job: JobSpec,
    server_caps: Sequence[Tuple[int, int]],
    cluster: ClusterSpec,
    refine: bool = False,
    reference: bool = False,
    speeds: Optional[Sequence[float]] = None,
) -> Tuple[Dict[int, np.ndarray], float]:
    """``map_job`` on rank-relabeled servers, mapped back to the caller's ids.

    Within one server *class* the mapping problem depends on server
    *capacities*, never on physical server ids: running the algorithm on
    caps relabeled 0..k-1 (in the caller's order) and substituting the real
    ids afterwards yields an equally-good placement, and makes the result a
    pure function of the (capacity, class) sequence — which is what lets
    ``PlacementCache`` share one computation across every server subset
    with the same shape.  On heterogeneous clusters each rank carries its
    physical server's class geometry into the alpha evaluation, so the
    relabeling is a *within-class* permutation: rank i may stand for any
    server of the same class with the same free capacity, never for one of
    a different class.  (For the paper's greedy the relabeling is an exact
    no-op: ``select_servers`` emits caps sorted by capacity with ids
    ascending within ties, so rank order coincides with every id tiebreak
    the greedy performs.  The ``refine`` seeds may break capacity ties
    differently than physical ids would — quality is identical by
    symmetry.)  ``speeds`` (per-slot degradation factors, aligned with
    ``server_caps``) ride along to the rank labels unchanged — the
    relabeling is then a within-(class, speed) permutation.
    """
    ranked = [(i, c) for i, (_m, c) in enumerate(server_caps)]
    geoms = _rank_geoms(cluster, server_caps)
    placement, a = map_job(
        job, ranked, cluster, refine=refine, geoms=geoms,
        reference=reference, speeds=speeds,
    )
    return {server_caps[i][0]: x for i, x in placement.items()}, a


class PlacementCache:
    """Memoized Heavy-Edge mapping: (job config, capacity shape) -> result.

    Two jobs with identical stage profiles and allreduce kind map
    identically onto identical server capacity shapes — MLaaS traces are
    dominated by recurring job configs and ``select_servers`` emits
    canonically-ordered capacity vectors, so the hit rate at trace scale
    is high.  Stores rank-labeled placements (see ``map_job_canonical``)
    and relabels to the caller's server ids per call; the numpy stage
    vectors are shared between hits and must be treated as immutable.
    LRU-bounded.

    On heterogeneous clusters the key carries each slot's server *class*
    alongside its capacity, and each rank is evaluated with its class
    geometry — so a cached entry is only ever relabeled within a class
    (equal GPUs-per-server and bandwidths), never onto a class whose
    per-server capacity or comm cost differs.  Homogeneous specs keep the
    PR-1 capacity-shape key verbatim (one class, no behavior change).
    """

    __slots__ = (
        "cluster", "refine", "maxsize", "hits", "misses", "_lru", "_graphs",
        "_het", "_class_of", "_hetctx", "_seeds", "_classes_memo", "key_log",
    )

    def __init__(
        self,
        cluster: ClusterSpec,
        refine: bool = False,
        maxsize: int = 1 << 16,
        key_log: Optional[list] = None,
    ):
        from collections import OrderedDict

        self.cluster = cluster
        self.refine = refine
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        # Optional miss recorder (fleet prewarm, repro.core.fleet): every
        # clean-path miss appends ``(job, server_caps)`` so a scout run's
        # working set can be replayed into another cache via ``warm``.
        self.key_log = key_log
        self._het = cluster.is_heterogeneous
        self._lru: "OrderedDict[tuple, Tuple[Dict[int, np.ndarray], float]]" = (
            OrderedDict()
        )
        self._graphs: Dict[int, JobGraph] = {}  # config_key -> comm graph
        if self._het:
            # bisect-free per-server lookups for the hot key construction
            self._class_of = tuple(
                cluster.class_of(m) for m in range(cluster.num_servers)
            )
        else:
            self._class_of = ()
        # class-shape tuple -> (rank geoms, geometry columns, r_server):
        # rank geometry depends only on each slot's class, so it is shared
        # across every capacity shape with the same class layout
        self._hetctx: Dict[tuple, tuple] = {}
        # (config, caps) -> seed/refine arrays shared across class layouts
        # (the seeds never read classes).  On mixed clusters most misses
        # are new class layouts over seen capacity shapes; on homogeneous
        # clusters the store mainly serves ``warm`` (entries hold exactly
        # the arrays recomputation would produce, so pre-populating them
        # is behavior-neutral).
        self._seeds: Optional[Dict[tuple, list]] = {}
        # ids tuple -> classes tuple (server subsets recur heavily)
        self._classes_memo: Dict[tuple, tuple] = {}

    def _het_context(self, classes: tuple) -> tuple:
        ctx = self._hetctx.get(classes)
        if ctx is None:
            K = len(classes)
            geoms = {
                i: self.cluster.class_geom(c) for i, c in enumerate(classes)
            }
            cols = timing._geom_columns(range(K), self.cluster, geoms)
            r_server = _position_r_server(list(range(K)), geoms)
            # refine sees geometry only through the per-slot NIC pattern
            # (None == uniform): layouts sharing it share refined seeds
            bw_key = (
                () if r_server is None
                else tuple(geoms[i][1] for i in range(K))
            )
            ctx = self._hetctx[classes] = (geoms, cols, r_server, bw_key)
        return ctx

    def map_job(
        self,
        job: JobSpec,
        server_caps: Sequence[Tuple[int, int]],
        speeds: Optional[Tuple[float, ...]] = None,
    ) -> Tuple[Dict[int, np.ndarray], float]:
        """``speeds``: per-slot degradation factors aligned with
        ``server_caps`` (``ClusterState.speeds_for``), or None while no
        server is degraded.  The cache key carries the factor tuple, so
        relabeling stays within (capacity, class, speed) — a degraded
        slot is never answered from a clean slot's entry or vice versa;
        clean calls keep the original key shape and hit the same entries
        as before.
        """
        ids, shape = zip(*server_caps)
        if speeds is not None and all(f == 1.0 for f in speeds):
            speeds = None  # clean vector: share the clean entries
        if self._het:
            classes = self._classes_memo.get(ids)
            if classes is None:
                if len(self._classes_memo) >= self.maxsize:
                    self._classes_memo.clear()  # bound the memo like _lru
                class_of = self._class_of
                classes = self._classes_memo[ids] = tuple(
                    class_of[m] for m in ids
                )
            key = (job.config_key, shape, classes)
        else:
            classes = None
            key = (job.config_key, shape)
        if speeds is not None:
            key = key + (speeds,)
        lru = self._lru
        hit = lru.get(key)
        if hit is not None:
            self.hits += 1
            if len(lru) * 2 >= self.maxsize:  # recency only matters near cap
                lru.move_to_end(key)
        else:
            self.misses += 1
            if self.key_log is not None and speeds is None:
                self.key_log.append((job, tuple(server_caps)))
            cfg_key = job.config_key
            graph = self._graphs.get(cfg_key)
            if graph is None:
                graph = self._graphs[cfg_key] = build_job_graph(job)
            if self._seeds is not None and len(self._seeds) >= self.maxsize:
                self._seeds.clear()  # bound the seed store like _lru
            if speeds is not None:
                # degraded slots: per-call geometry + speed columns (rare
                # path; the class-layout fast context is speed-agnostic)
                placement, a = map_job(
                    job,
                    list(enumerate(shape)),
                    self.cluster,
                    refine=self.refine,
                    graph=graph,
                    geoms=(
                        self._het_context(classes)[0] if self._het else None
                    ),
                    speeds=speeds,
                )
            else:
                placement, a = map_job(
                    job,
                    list(enumerate(shape)),
                    self.cluster,
                    refine=self.refine,
                    graph=graph,
                    _het_ctx=self._het_context(classes) if self._het else None,
                    _seed_cache=self._seeds,
                )
            # every cap in the vector is fully used, so ranks 0..k-1 are
            # all present; store the stage vectors in rank order
            hit = ([placement[i] for i in range(len(ids))], a)
            lru[key] = hit
            if len(lru) > self.maxsize:
                # detlint: skip=DET007(digest-safe eviction: entries are pure functions of their key — map_job and warm produce byte-identical vectors on recomputation, property-tested warm-vs-cold — so evicting only moves work, never results)
                lru.popitem(last=False)
        vectors, a = hit
        return dict(zip(ids, vectors)), a

    def warm(self, requests) -> Tuple[int, int]:
        """Pre-compute missing clean entries, batching the cold refines.

        ``requests``: iterable of ``(job, server_caps)`` pairs — typically
        another cache's ``key_log`` from a cheap scout run (see
        ``repro.core.fleet``).  Misses are grouped by ``(config,
        slot-count, NIC-bandwidth pattern)`` and every group's distinct
        seed rows — across all its capacity shapes and class layouts —
        are refined in ONE ``_refine_positions_batched`` call, instead of
        one three-seed program per ``(config, shape)`` miss.  Grouping on
        equal slot count keeps every slice's op shapes equal to the
        sequential path's, and the batched refine is bit-identical per
        row (see its docstring), so a warmed entry equals what the
        on-demand miss would compute — ``map_job`` then finishes each
        entry (candidate alpha + best-of) through its normal path against
        the pre-populated seed store.

        Returns ``(n_entries_computed, n_batched_refine_calls)``.  On a
        non-refine cache there is no batched program; entries are simply
        computed via ``map_job``.
        """
        pending: List[tuple] = []
        seen: set = set()
        lru = self._lru
        for job, server_caps in requests:
            ids, shape = zip(*server_caps)
            # mirror map_job's key construction (kept inline there for the
            # hot path)
            if self._het:
                classes = self._classes_memo.get(ids)
                if classes is None:
                    if len(self._classes_memo) >= self.maxsize:
                        self._classes_memo.clear()
                    class_of = self._class_of
                    classes = self._classes_memo[ids] = tuple(
                        class_of[m] for m in ids
                    )
                key = (job.config_key, shape, classes)
            else:
                classes = None
                key = (job.config_key, shape)
            if key in lru or key in seen:
                continue
            seen.add(key)
            pending.append((job, server_caps, shape, classes))
        if not pending:
            return 0, 0

        n_groups = 0
        if self.refine:
            # (config, K, bw pattern) -> refine work; r_server depends on
            # geometry only through the per-slot NIC bandwidths (bw_key),
            # so one group shares a single (K,)-shaped weight vector
            groups: Dict[tuple, list] = {}
            group_seen: set = set()
            for job, _sc, shape, classes in pending:
                K = len(shape)
                if K == 1:
                    continue  # single-server path has no refine work
                cfg_key = job.config_key
                graph = self._graphs.get(cfg_key)
                if graph is None:
                    graph = self._graphs[cfg_key] = build_job_graph(job)
                d = graph.dense()
                if sum(shape) != len(d.verts):
                    continue  # map_job raises the loud ValueError below
                if self._seeds is not None and (
                    len(self._seeds) >= self.maxsize
                ):
                    self._seeds.clear()
                sc_key = (cfg_key, shape)
                ent = self._seeds.get(sc_key)
                if ent is None:
                    # seed construction exactly as map_job's miss path
                    # (rank ids 0..K-1 ascending; identity order when the
                    # shape is already descending)
                    caps = list(shape)
                    if all(caps[p] >= caps[p + 1] for p in range(K - 1)):
                        order = range(K)
                    else:
                        order = sorted(
                            range(K), key=lambda p: (-caps[p], p)
                        )
                    seeds = [
                        _heavy_edge_positions(graph, d, caps, order),
                        _contiguous_positions(d, caps, order),
                        _stage_aligned_positions(
                            graph, d, list(enumerate(shape))
                        ),
                    ]
                    uniq: List[np.ndarray] = []
                    uniq_of: List[int] = []
                    sb: Dict[bytes, int] = {}
                    for s_arr in seeds:
                        bkey = s_arr.tobytes()
                        idx = sb.get(bkey)
                        if idx is None:
                            idx = sb[bkey] = len(uniq)
                            uniq.append(s_arr)
                        uniq_of.append(idx)
                    ent = [seeds, uniq, uniq_of, {}]
                    self._seeds[sc_key] = ent
                if self._het:
                    ctx = self._het_context(classes)
                    r_server, bw_key = ctx[2], ctx[3]
                else:
                    r_server, bw_key = None, ()
                if bw_key in ent[3]:
                    continue  # refined rows already known for this pattern
                mark = (id(ent), bw_key)
                if mark in group_seen:
                    continue
                group_seen.add(mark)
                groups.setdefault((cfg_key, K, bw_key), []).append(
                    (ent, r_server, bw_key)
                )
            for (cfg_key, _K, _bw), members in groups.items():
                d = self._graphs[cfg_key].dense()
                n = len(d.verts)
                slices: List[tuple] = []
                total = 0
                for ent, _rs, bw in members:
                    cnt = len(ent[1])
                    slices.append((ent, bw, total, cnt))
                    total += cnt
                seed_mat = np.empty((total, n), dtype=np.int64)
                for ent, _bw, ofs, cnt in slices:
                    for u_i, row in enumerate(ent[1]):
                        seed_mat[ofs + u_i] = row
                refined = _refine_positions_batched(
                    d, seed_mat, _K, members[0][1]
                )
                for ent, bw, ofs, cnt in slices:
                    ent[3][bw] = refined[ofs:ofs + cnt]
                n_groups += 1
        for job, server_caps, _shape, _classes in pending:
            self.map_job(job, server_caps)
        return len(pending), n_groups


def consolidated_caps(job: JobSpec, cluster: ClusterSpec) -> List[Tuple[int, int]]:
    """Fewest-servers capacity profile: full servers + one remainder.

    Heterogeneous clusters pack biggest-then-fastest-NIC servers first —
    the same most-available-first order ``select_servers`` produces on an
    empty cluster with the bandwidth tiebreak.
    """
    if not cluster.is_heterogeneous:
        g = cluster.gpus_per_server
        n_full, rem = divmod(job.g, g)
        caps = [(m, g) for m in range(n_full)]
        if rem:
            caps.append((n_full, rem))
        return caps
    starts: List[int] = []
    acc = 0
    for sc in cluster.server_classes:
        starts.append(acc)
        acc += sc.count
    order = sorted(
        range(len(cluster.server_classes)),
        key=lambda c: (
            -cluster.server_classes[c].gpus_per_server,
            -cluster.server_classes[c].b_inter,
            starts[c],
        ),
    )
    caps: List[Tuple[int, int]] = []
    remaining = job.g
    for c in order:
        sc = cluster.server_classes[c]
        for m in range(starts[c], starts[c] + sc.count):
            take = sc.gpus_per_server if sc.gpus_per_server < remaining \
                else remaining
            caps.append((m, take))
            remaining -= take
            if remaining == 0:
                return caps
    raise ValueError(
        f"job {job.job_id} needs {job.g} GPUs, cluster has "
        f"{cluster.total_gpus}"
    )


def alpha_min_estimate(job: JobSpec, cluster: ClusterSpec) -> float:
    """alpha-tilde_i^min (paper Sec. IV-B): Heavy-Edge on the consolidated
    (fewest possible servers, fully packed) allocation.  ``map_job``
    resolves the per-server geometry itself on heterogeneous clusters."""
    _, a = map_job(job, consolidated_caps(job, cluster), cluster)
    return a


def select_servers(
    free: Mapping[int, int],
    g_needed: int,
    consolidate: bool,
    spec: Optional[ClusterSpec] = None,
    buckets: Optional[Sequence[Sequence[int]]] = None,
    total_free: Optional[int] = None,
    ranks: Optional[Tuple[Sequence[int], Sequence[int]]] = None,
) -> List[Tuple[int, int]]:
    """Pick servers/GPU counts for a job (paper Alg. 1 lines 9 and 22).

    ``consolidate=True``  -> most-available-first (communication-heavy jobs);
    ``consolidate=False`` -> least-available-first (fragmentation-aware
                             placement of non-communication-heavy jobs).
    ``spec`` (heterogeneous clusters only) breaks free-count ties by NIC
    bandwidth: consolidating jobs prefer the fastest NICs among
    equally-free servers, fragmentation-aware placement prefers the
    slowest — keeping fast-NIC capacity free for the jobs that need it.
    Homogeneous specs are unaffected (one class, id tiebreak as before).
    ``ranks`` overrides the static spec ranks with *effective*-bandwidth
    ranks (``ClusterState.effective_bw_ranks``) while servers are
    degraded: among equally-free servers a straggler sorts like a
    proportionally slower NIC, so consolidating placement avoids
    degraded capacity whenever a healthy server offers the same count.
    ``buckets``/``total_free`` (hot path): ``ClusterState.free_buckets``
    maintained incrementally — skips the per-call counting sort; the
    bucket walk is identical because the maintained buckets hold exactly
    the servers the sort would produce, in the same ascending-id order.
    Returns (server_id, gpus_taken) or raises if capacity is insufficient.
    """
    if buckets is None:
        # Counting sort by capacity: free-GPU counts are bounded by the
        # server size, and dict iteration yields servers in ascending id,
        # so walking the buckets reproduces the (-cap, id) / (cap, id)
        # orderings exactly.
        counted: Dict[int, List[int]] = {}
        total = 0
        max_c = 0
        for m, c in free.items():
            if c > 0:
                b = counted.get(c)
                if b is None:
                    counted[c] = [m]
                else:
                    b.append(m)
                total += c
                if c > max_c:
                    max_c = c
        counted_get = counted.get
    else:
        total = total_free if total_free is not None else sum(
            c * len(b) for c, b in enumerate(buckets)
        )
        max_c = len(buckets) - 1
        counted_get = None

    if total < g_needed:
        raise ValueError("not enough free GPUs")
    tiebreak = ranks is not None or (
        spec is not None and spec.is_heterogeneous
    )
    order = range(max_c, 0, -1) if consolidate else range(1, max_c + 1)
    picks: List[Tuple[int, int]] = []
    remaining = g_needed
    if tiebreak:
        desc_rank, asc_rank = ranks if ranks is not None else (
            spec.bw_order_ranks
        )
        rank = desc_rank if consolidate else asc_rank
    for c in order:
        bucket = buckets[c] if counted_get is None else counted_get(c, ())
        if not bucket:
            continue
        if tiebreak and len(bucket) > 1:
            bucket = sorted(bucket, key=rank.__getitem__)
        for m in bucket:
            take = c if c < remaining else remaining
            picks.append((m, take))
            remaining -= take
            if remaining == 0:
                return picks
    return picks


class FreeCapsSnapshot:
    """One scheduling pass's sorted free-capacity structure.

    The pick *order* ``select_servers`` walks does not depend on
    ``g_needed`` — only the prefix taken does — so a pass that evaluates
    many delayed jobs against an unchanged cluster can run the counting
    sort once (over the full free capacity) and carve each job's capacity
    vector from the prefix sums.  ``caps_for`` memoizes per distinct
    demand ``g``: equal-``g`` jobs provably select the same vector, and
    the shared tuple makes the step-2 caps-equality skip an identity
    comparison in the common case.  Invalidate (drop) the snapshot after
    any allocation — the free state it sorted no longer exists.
    """

    __slots__ = ("ids", "caps", "cum", "_by_g")

    def __init__(self, picks: Sequence[Tuple[int, int]]):
        self.ids = [m for m, _c in picks]
        self.caps = [c for _m, c in picks]
        cum: List[int] = []
        acc = 0
        for c in self.caps:
            acc += c
            cum.append(acc)
        self.cum = cum
        self._by_g: Dict[int, tuple] = {}

    @classmethod
    def consolidating(
        cls,
        free: Mapping[int, int],
        total_free: int,
        spec: Optional[ClusterSpec] = None,
        buckets: Optional[Sequence[Sequence[int]]] = None,
        ranks: Optional[Tuple[Sequence[int], Sequence[int]]] = None,
    ) -> "FreeCapsSnapshot":
        return cls(
            select_servers(
                free, total_free, consolidate=True, spec=spec,
                buckets=buckets, total_free=total_free, ranks=ranks,
            )
        )

    def caps_for(self, g: int) -> tuple:
        """The tuple ``select_servers(free, g, consolidate=True)`` returns.

        Bit-identical by construction: full servers in pick order until
        the remaining demand is smaller than the next capacity, which is
        taken as the remainder (property-tested in tests/test_vectorized.py).
        """
        hit = self._by_g.get(g)
        if hit is None:
            i = bisect.bisect_left(self.cum, g)
            prev = self.cum[i - 1] if i else 0
            ids, caps = self.ids, self.caps
            hit = tuple((ids[k], caps[k]) for k in range(i)) + (
                (ids[i], g - prev),
            )
            self._by_g[g] = hit
        return hit


class ConsolidatingLadder:
    """Snapshot-or-select ladder over one ``ClusterState``'s free capacity.

    The protocol A-SRPT's step 2/3 and the migration planner share: the
    *first* consolidating demand after any allocation runs a plain
    ``select_servers`` (building the full-order snapshot for a single
    carve would cost more than it saves); from the second demand on, one
    ``FreeCapsSnapshot`` per free state serves every demand by prefix
    carving.  Call ``reset()`` after any allocation — the sorted free
    state the snapshot captured no longer exists.  ``ranks`` (effective-
    bandwidth tiebreak) is fixed at construction: allocations never
    change speed factors, so it stays valid across resets within one
    scheduling pass / migration sweep.

    ``cluster`` is duck-typed (``free``/``free_buckets``/``total_free``)
    to keep this module import-cycle-free with cluster.py.
    """

    __slots__ = ("cluster", "spec", "ranks", "_snapshot", "_selected_once")

    def __init__(self, cluster, spec: Optional[ClusterSpec], ranks=None):
        self.cluster = cluster
        self.spec = spec
        self.ranks = ranks
        self._snapshot: Optional[FreeCapsSnapshot] = None
        self._selected_once = False

    def caps_for(self, g_need: int) -> tuple:
        cluster = self.cluster
        if self._snapshot is not None:
            return self._snapshot.caps_for(g_need)
        if self._selected_once:
            self._snapshot = FreeCapsSnapshot.consolidating(
                cluster.free, cluster.total_free, self.spec,
                buckets=cluster.free_buckets, ranks=self.ranks,
            )
            return self._snapshot.caps_for(g_need)
        self._selected_once = True
        return tuple(
            select_servers(
                cluster.free, g_need,
                consolidate=True, spec=self.spec,
                buckets=cluster.free_buckets,
                total_free=cluster.total_free,
                ranks=self.ranks,
            )
        )

    def reset(self) -> None:
        self._snapshot = None
        self._selected_once = False
