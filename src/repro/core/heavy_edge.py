"""Heavy-Edge GPU mapping (paper Sec. IV-B, Fig. 2).

Given a job graph and a list of servers with available-GPU counts summing to
``g_i``, partition the vertices (stage replicas) into per-server groups so
that heavy communication edges stay inside a server.

Greedy procedure (faithful to the paper):
  1. sort servers by available GPUs, descending;
  2. for each server ``m`` with capacity ``c``:
     - if the remaining vertex count equals ``c``: assign all of them;
     - if ``c == 1``: assign the unassigned vertex with minimum total edge
       weight (to other unassigned vertices);
     - else: seed ``node_set`` with the heaviest remaining edge's endpoints,
       then repeatedly add the unassigned vertex connected to ``node_set`` by
       the heaviest edge; if none is connected, add an arbitrary
       (deterministically: smallest-id) unassigned vertex; stop at ``c``.

Ties are broken by vertex order for determinism.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .graph import JobGraph, Vertex, build_job_graph
from .job import ClusterSpec, JobSpec, ServerGeom
from . import timing


def heavy_edge(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Map each vertex to a server id.

    ``server_caps``: (server_id, available_gpus) pairs; capacities must sum
    to the number of vertices.
    """
    total_cap = sum(c for _, c in server_caps)
    if total_cap != len(graph.vertices):
        raise ValueError(
            f"server capacities sum to {total_cap}, "
            f"job needs {len(graph.vertices)} GPUs"
        )
    # Descending capacity; stable on server id for determinism.
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))

    unassigned = set(graph.vertices)
    assignment: Dict[Vertex, int] = {}

    for server_id, cap in order:
        if cap <= 0:
            continue
        if cap >= len(unassigned):
            for v in sorted(unassigned):
                assignment[v] = server_id
            unassigned.clear()
            break
        if cap == 1:
            v = min(
                sorted(unassigned),
                key=lambda u: (
                    sum(
                        w
                        for nb, w in graph.neighbors(u).items()
                        if nb in unassigned
                    ),
                    u,
                ),
            )
            assignment[v] = server_id
            unassigned.discard(v)
            continue

        node_set: List[Vertex] = []
        # Seed with the heaviest edge among unassigned vertices.
        best_w, best_pair = -1.0, None
        for u in sorted(unassigned):
            for nb, w in graph.neighbors(u).items():
                if nb in unassigned and u < nb and w > best_w:
                    best_w, best_pair = w, (u, nb)
        if best_pair is None:
            node_set.append(min(unassigned))
        else:
            node_set.extend(best_pair)
        for v in node_set:
            unassigned.discard(v)

        while len(node_set) < cap and unassigned:
            best_w, best_v = -1.0, None
            for u in node_set:
                for nb, w in graph.neighbors(u).items():
                    if nb in unassigned and (
                        w > best_w or (w == best_w and (best_v is None or nb < best_v))
                    ):
                        best_w, best_v = w, nb
            if best_v is None:  # disconnected: arbitrary (smallest) vertex
                best_v = min(unassigned)
            node_set.append(best_v)
            unassigned.discard(best_v)

        for v in node_set:
            assignment[v] = server_id

    if unassigned:
        raise AssertionError("heavy_edge left vertices unassigned")
    return assignment


def refine_assignment(
    graph: JobGraph,
    assignment: Dict[Vertex, int],
    max_passes: int = 3,
    geoms: Optional[Mapping[int, ServerGeom]] = None,
) -> Dict[Vertex, int]:
    """Beyond-paper local search: best-improvement pairwise swaps.

    The paper's greedy is myopic (it can split an AllReduce ring whose
    members it seeded apart); a few swap passes repair those cases.  The
    swap deltas are evaluated for *all* vertex pairs at once on an
    adjacency matrix: with ``D[k, u]`` the total weight from vertex ``u``
    into server ``k`` and ``s`` the current assignment,

        delta(u, v) = (D[s_u,u] - D[s_v,u]) + (D[s_v,v] - D[s_u,v]) + 2 W[u,v]

    (the ``2 W[u,v]`` corrects for the u-v edge itself, which stays cut).
    Kept separate from the faithful greedy so the paper baseline remains
    measurable (see benchmarks/table2).

    ``geoms`` (heterogeneous clusters) switches the objective from the raw
    cut weight to the *bandwidth-weighted* cut: an edge crossing servers
    ``a, b`` costs ``w * (r_a + r_b)`` with ``r_k`` the inverse NIC
    bandwidth of ``k`` — cutting an AllReduce onto a slow-NIC server is
    penalized more than onto a fast one.  The weighted objective
    decomposes per vertex (``C = sum_x r[s_x] * cut_x``), so the swap
    delta stays a single vectorized expression:

        delta(u, v) =   r_u * (2 D[s_u,u] - 2 D[s_u,v] + 2 W[u,v] + T_v - T_u)
                      + r_v * (2 D[s_v,v] - 2 D[s_v,u] + 2 W[u,v] + T_u - T_v)

    with ``T_x`` the total incident weight of ``x``.  When every server's
    bandwidth is equal this reduces to exactly ``2 r`` times the
    homogeneous delta, so the unweighted formula is kept verbatim on that
    path (identical swap sequences — no behavior change).
    """
    verts = sorted(graph.vertices)
    n = len(verts)
    if n < 2:
        return dict(assignment)
    index = {v: i for i, v in enumerate(verts)}
    W = np.zeros((n, n))
    for (u, v), w in graph.edges.items():
        i, j = index[u], index[v]
        W[i, j] += w
        W[j, i] += w

    servers = sorted({assignment[v] for v in verts})
    server_index = {m: k for k, m in enumerate(servers)}
    s = np.array([server_index[assignment[v]] for v in verts])
    arange = np.arange(n)

    r_server = None
    if geoms is not None:
        inv = np.array([1.0 / geoms[m][1] for m in servers])
        if not np.all(inv == inv[0]):
            # scale-free normalization keeps the improvement threshold in
            # the same (byte-weight) units as the unweighted objective
            r_server = inv * (len(inv) / inv.sum())
    tot = W.sum(axis=1) if r_server is not None else None

    for _ in range(max_passes):
        ind = np.zeros((len(servers), n))
        ind[s, arange] = 1.0
        D = ind @ W  # D[k, u]: weight from vertex u into server k
        Ds = D[s]  # Ds[j, u] = D[s_j, u]
        d_own = Ds[arange, arange]
        if r_server is None:
            delta = (
                (d_own[:, None] - Ds.T) + (d_own[None, :] - Ds) + 2.0 * W
            )
        else:
            rv = r_server[s]
            base = (
                2.0 * d_own[:, None] - 2.0 * Ds + 2.0 * W
                + tot[None, :] - tot[:, None]
            )
            delta = rv[:, None] * base + rv[None, :] * base.T
        # only ordered pairs on different servers are candidate swaps
        invalid = (s[:, None] == s[None, :]) | (arange[:, None] >= arange[None, :])
        delta[invalid] = np.inf
        flat = int(np.argmin(delta))
        i, j = divmod(flat, n)
        if delta[i, j] >= -1e-12:
            break
        s[i], s[j] = s[j], s[i]

    return {v: servers[s[i]] for i, v in enumerate(verts)}


def contiguous_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Second seed for the local search: fill servers in (stage, replica)
    order, which tends to keep AllReduce rings and pipeline neighbours
    together when capacities align with stage sizes."""
    order = sorted(server_caps, key=lambda mc: (-mc[1], mc[0]))
    assign: Dict[Vertex, int] = {}
    it = iter(sorted(graph.vertices))
    for server_id, cap in order:
        for _ in range(cap):
            assign[next(it)] = server_id
    return assign


def stage_aligned_assignment(
    graph: JobGraph, server_caps: Sequence[Tuple[int, int]]
) -> Dict[Vertex, int]:
    """Third seed: best-fit-decreasing bin packing of *whole stages*.

    Swap-based local search cannot relabel an entire AllReduce ring; packing
    stages as units (heaviest internal weight first, tightest-fitting server)
    finds those placements directly.  Spillover vertices fall back to the
    heaviest-connection rule.
    """
    from collections import defaultdict

    stages = defaultdict(list)
    for v in sorted(graph.vertices):
        stages[v[0]].append(v)

    # one pass over the edges: intra-stage weight per stage
    internal = defaultdict(float)
    for (u, v), w in graph.edges.items():
        if u[0] == v[0]:
            internal[u[0]] += w

    order = sorted(
        stages.values(), key=lambda vs: (-internal[vs[0][0]], vs[0])
    )
    free = dict(server_caps)
    assign: Dict[Vertex, int] = {}
    leftovers: List[Vertex] = []
    for verts in order:
        # tightest server that fits the whole stage
        need = len(verts)
        best = None
        for m, c in free.items():
            if c >= need and (best is None or (c, m) < best):
                best = (c, m)
        if best is not None:
            m = best[1]
            for v in verts:
                assign[v] = m
            free[m] -= need
        else:
            leftovers.extend(verts)
    for v in leftovers:
        # most-connected server with capacity, else any with capacity
        best_m, best_w = None, -1.0
        for m, c in free.items():
            if c <= 0:
                continue
            w = sum(
                wt for nb, wt in graph.neighbors(v).items()
                if assign.get(nb) == m
            )
            if w > best_w:
                best_w, best_m = w, m
        assign[v] = best_m
        free[best_m] -= 1
    return assign


def map_job(
    job: JobSpec,
    server_caps: Sequence[Tuple[int, int]],
    cluster: ClusterSpec,
    refine: bool = False,
    graph: Optional[JobGraph] = None,
    geoms: Optional[Mapping[int, ServerGeom]] = None,
) -> Tuple[Dict[int, np.ndarray], float]:
    """Run Heavy-Edge (optionally multi-start + local search).

    ``refine`` (beyond-paper): swap-based local search from three seeds
    (the paper's greedy, a contiguous fill, and whole-stage bin packing),
    keeping the placement with the lowest per-iteration time alpha.
    ``graph``: pre-built communication graph (it depends only on the job
    config, so callers mapping recurring jobs can share one).
    ``geoms``: per-server geometry override for the alpha evaluation
    (required when ``server_caps`` uses rank labels on a heterogeneous
    cluster; see ``map_job_canonical``).
    """
    if graph is None:
        graph = build_job_graph(job)
    if geoms is None and cluster.is_heterogeneous:
        # caller passed physical ids on a mixed cluster: resolve their
        # geometry here so refine + alpha see the per-class bandwidths
        geoms = {m: cluster.server_geom(m) for m, _c in server_caps}
    assignment = heavy_edge(graph, server_caps)
    placement = timing.placement_from_assignment(job, assignment)
    best_alpha = timing.alpha(job, placement, cluster, geoms=geoms)
    if refine:
        seeds = (
            assignment,
            contiguous_assignment(graph, server_caps),
            stage_aligned_assignment(graph, server_caps),
        )
        for seed in seeds:
            cand = refine_assignment(graph, seed, geoms=geoms)
            cand_placement = timing.placement_from_assignment(job, cand)
            a = timing.alpha(job, cand_placement, cluster, geoms=geoms)
            if a < best_alpha - 1e-12:
                best_alpha, placement = a, cand_placement
    return placement, best_alpha


def _rank_geoms(
    cluster: ClusterSpec, server_caps: Sequence[Tuple[int, int]]
) -> Optional[Dict[int, ServerGeom]]:
    """Rank -> geometry of the physical server holding that rank (het only)."""
    if not cluster.is_heterogeneous:
        return None
    return {
        i: cluster.server_geom(m) for i, (m, _c) in enumerate(server_caps)
    }


def map_job_canonical(
    job: JobSpec,
    server_caps: Sequence[Tuple[int, int]],
    cluster: ClusterSpec,
    refine: bool = False,
) -> Tuple[Dict[int, np.ndarray], float]:
    """``map_job`` on rank-relabeled servers, mapped back to the caller's ids.

    Within one server *class* the mapping problem depends on server
    *capacities*, never on physical server ids: running the algorithm on
    caps relabeled 0..k-1 (in the caller's order) and substituting the real
    ids afterwards yields an equally-good placement, and makes the result a
    pure function of the (capacity, class) sequence — which is what lets
    ``PlacementCache`` share one computation across every server subset
    with the same shape.  On heterogeneous clusters each rank carries its
    physical server's class geometry into the alpha evaluation, so the
    relabeling is a *within-class* permutation: rank i may stand for any
    server of the same class with the same free capacity, never for one of
    a different class.  (For the paper's greedy the relabeling is an exact
    no-op: ``select_servers`` emits caps sorted by capacity with ids
    ascending within ties, so rank order coincides with every id tiebreak
    the greedy performs.  The ``refine`` seeds may break capacity ties
    differently than physical ids would — quality is identical by
    symmetry.)
    """
    ranked = [(i, c) for i, (_m, c) in enumerate(server_caps)]
    geoms = _rank_geoms(cluster, server_caps)
    placement, a = map_job(job, ranked, cluster, refine=refine, geoms=geoms)
    return {server_caps[i][0]: x for i, x in placement.items()}, a


class PlacementCache:
    """Memoized Heavy-Edge mapping: (job config, capacity shape) -> result.

    Two jobs with identical stage profiles and allreduce kind map
    identically onto identical server capacity shapes — MLaaS traces are
    dominated by recurring job configs and ``select_servers`` emits
    canonically-ordered capacity vectors, so the hit rate at trace scale
    is high.  Stores rank-labeled placements (see ``map_job_canonical``)
    and relabels to the caller's server ids per call; the numpy stage
    vectors are shared between hits and must be treated as immutable.
    LRU-bounded.

    On heterogeneous clusters the key carries each slot's server *class*
    alongside its capacity, and each rank is evaluated with its class
    geometry — so a cached entry is only ever relabeled within a class
    (equal GPUs-per-server and bandwidths), never onto a class whose
    per-server capacity or comm cost differs.  Homogeneous specs keep the
    PR-1 capacity-shape key verbatim (one class, no behavior change).
    """

    __slots__ = (
        "cluster", "refine", "maxsize", "hits", "misses", "_lru", "_graphs",
        "_het",
    )

    def __init__(
        self,
        cluster: ClusterSpec,
        refine: bool = False,
        maxsize: int = 1 << 16,
    ):
        from collections import OrderedDict

        self.cluster = cluster
        self.refine = refine
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._het = cluster.is_heterogeneous
        self._lru: "OrderedDict[tuple, Tuple[Dict[int, np.ndarray], float]]" = (
            OrderedDict()
        )
        self._graphs: Dict[int, JobGraph] = {}  # config_key -> comm graph

    def map_job(
        self, job: JobSpec, server_caps: Sequence[Tuple[int, int]]
    ) -> Tuple[Dict[int, np.ndarray], float]:
        ids, shape = zip(*server_caps)
        if self._het:
            class_of = self.cluster.class_of
            key = (job.config_key, shape, tuple(class_of(m) for m in ids))
        else:
            key = (job.config_key, shape)
        lru = self._lru
        hit = lru.get(key)
        if hit is not None:
            self.hits += 1
            if len(lru) * 2 >= self.maxsize:  # recency only matters near cap
                lru.move_to_end(key)
        else:
            self.misses += 1
            cfg_key = job.config_key
            graph = self._graphs.get(cfg_key)
            if graph is None:
                graph = self._graphs[cfg_key] = build_job_graph(job)
            placement, a = map_job(
                job,
                list(enumerate(shape)),
                self.cluster,
                refine=self.refine,
                graph=graph,
                geoms=_rank_geoms(self.cluster, server_caps),
            )
            # every cap in the vector is fully used, so ranks 0..k-1 are
            # all present; store the stage vectors in rank order
            hit = ([placement[i] for i in range(len(ids))], a)
            lru[key] = hit
            if len(lru) > self.maxsize:
                lru.popitem(last=False)
        vectors, a = hit
        return dict(zip(ids, vectors)), a


def consolidated_caps(job: JobSpec, cluster: ClusterSpec) -> List[Tuple[int, int]]:
    """Fewest-servers capacity profile: full servers + one remainder.

    Heterogeneous clusters pack biggest-then-fastest-NIC servers first —
    the same most-available-first order ``select_servers`` produces on an
    empty cluster with the bandwidth tiebreak.
    """
    if not cluster.is_heterogeneous:
        g = cluster.gpus_per_server
        n_full, rem = divmod(job.g, g)
        caps = [(m, g) for m in range(n_full)]
        if rem:
            caps.append((n_full, rem))
        return caps
    starts: List[int] = []
    acc = 0
    for sc in cluster.server_classes:
        starts.append(acc)
        acc += sc.count
    order = sorted(
        range(len(cluster.server_classes)),
        key=lambda c: (
            -cluster.server_classes[c].gpus_per_server,
            -cluster.server_classes[c].b_inter,
            starts[c],
        ),
    )
    caps: List[Tuple[int, int]] = []
    remaining = job.g
    for c in order:
        sc = cluster.server_classes[c]
        for m in range(starts[c], starts[c] + sc.count):
            take = sc.gpus_per_server if sc.gpus_per_server < remaining \
                else remaining
            caps.append((m, take))
            remaining -= take
            if remaining == 0:
                return caps
    raise ValueError(
        f"job {job.job_id} needs {job.g} GPUs, cluster has "
        f"{cluster.total_gpus}"
    )


def alpha_min_estimate(job: JobSpec, cluster: ClusterSpec) -> float:
    """alpha-tilde_i^min (paper Sec. IV-B): Heavy-Edge on the consolidated
    (fewest possible servers, fully packed) allocation.  ``map_job``
    resolves the per-server geometry itself on heterogeneous clusters."""
    _, a = map_job(job, consolidated_caps(job, cluster), cluster)
    return a


def select_servers(
    free: Mapping[int, int],
    g_needed: int,
    consolidate: bool,
    spec: Optional[ClusterSpec] = None,
) -> List[Tuple[int, int]]:
    """Pick servers/GPU counts for a job (paper Alg. 1 lines 9 and 22).

    ``consolidate=True``  -> most-available-first (communication-heavy jobs);
    ``consolidate=False`` -> least-available-first (fragmentation-aware
                             placement of non-communication-heavy jobs).
    ``spec`` (heterogeneous clusters only) breaks free-count ties by NIC
    bandwidth: consolidating jobs prefer the fastest NICs among
    equally-free servers, fragmentation-aware placement prefers the
    slowest — keeping fast-NIC capacity free for the jobs that need it.
    Homogeneous specs are unaffected (one class, id tiebreak as before).
    Returns (server_id, gpus_taken) or raises if capacity is insufficient.
    """
    # Counting sort by capacity: free-GPU counts are bounded by the server
    # size, and dict iteration yields servers in ascending id, so walking
    # the buckets reproduces the (-cap, id) / (cap, id) orderings exactly.
    buckets: Dict[int, List[int]] = {}
    total = 0
    max_c = 0
    for m, c in free.items():
        if c > 0:
            b = buckets.get(c)
            if b is None:
                buckets[c] = [m]
            else:
                b.append(m)
            total += c
            if c > max_c:
                max_c = c
    if total < g_needed:
        raise ValueError("not enough free GPUs")
    het = spec is not None and spec.is_heterogeneous
    order = range(max_c, 0, -1) if consolidate else range(1, max_c + 1)
    picks: List[Tuple[int, int]] = []
    remaining = g_needed
    if het:
        desc_rank, asc_rank = spec.bw_order_ranks
        rank = desc_rank if consolidate else asc_rank
    for c in order:
        bucket = buckets.get(c, ())
        if het and len(bucket) > 1:
            bucket = sorted(bucket, key=rank.__getitem__)
        for m in bucket:
            take = c if c < remaining else remaining
            picks.append((m, take))
            remaining -= take
            if remaining == 0:
                return picks
    return picks
