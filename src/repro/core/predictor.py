"""Training-iteration predictors (paper Sec. IV-C3).

The paper predicts each job's total training iterations with a 100-tree
random-forest regression over (group id, user id) + historical job data,
retrained frequently; unseen jobs are predicted as 0 iterations so they are
treated as instantly complete in the virtual instance and scheduled ASAP.

scikit-learn is unavailable offline, so ``RandomForestRegressor`` below is a
from-scratch NumPy implementation: histogram-binned CART trees with MSE
splitting, bootstrap aggregation, and feature subsampling.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from .job import JobSpec

# --------------------------------------------------------------------------
# From-scratch random forest regression
# --------------------------------------------------------------------------


class _Tree:
    """Array-based CART regression tree on pre-binned uint16 features."""

    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self) -> None:
        self.feature: List[int] = []
        self.threshold: List[int] = []  # bin index; go left if bin <= thr
        self.left: List[int] = []
        self.right: List[int] = []
        self.value: List[float] = []

    def _new_node(self) -> int:
        self.feature.append(-1)
        self.threshold.append(0)
        self.left.append(-1)
        self.right.append(-1)
        self.value.append(0.0)
        return len(self.feature) - 1

    def fit(
        self,
        Xb: np.ndarray,
        y: np.ndarray,
        n_bins: int,
        max_depth: int,
        min_samples_leaf: int,
        max_features: int,
        rng: np.random.Generator,
        leaf: str = "mean",
    ) -> None:
        n_features = Xb.shape[1]
        stack = [(self._new_node(), np.arange(len(y)), 0)]
        while stack:
            node, idx, depth = stack.pop()
            yn = y[idx]
            self.value[node] = float(
                np.median(yn) if leaf == "median" else yn.mean()
            )
            if (
                depth >= max_depth
                or len(idx) < 2 * min_samples_leaf
                or np.all(yn == yn[0])
            ):
                continue
            feats = rng.choice(
                n_features, size=min(max_features, n_features), replace=False
            )
            best = None  # (gain, feat, thr_bin)
            total_sum, total_cnt = yn.sum(), len(yn)
            base_sse_term = (total_sum * total_sum) / total_cnt
            for f in feats:
                xb = Xb[idx, f]
                cnt = np.bincount(xb, minlength=n_bins).astype(np.float64)
                sm = np.bincount(xb, weights=yn, minlength=n_bins)
                c_cnt = np.cumsum(cnt)[:-1]
                c_sum = np.cumsum(sm)[:-1]
                valid = (c_cnt >= min_samples_leaf) & (
                    (total_cnt - c_cnt) >= min_samples_leaf
                )
                if not valid.any():
                    continue
                with np.errstate(divide="ignore", invalid="ignore"):
                    gain = (
                        c_sum**2 / c_cnt
                        + (total_sum - c_sum) ** 2 / (total_cnt - c_cnt)
                        - base_sse_term
                    )
                gain = np.where(valid, gain, -np.inf)
                b = int(np.argmax(gain))
                if gain[b] > 1e-12 and (best is None or gain[b] > best[0]):
                    best = (float(gain[b]), int(f), b)
            if best is None:
                continue
            _, f, thr = best
            mask = Xb[idx, f] <= thr
            li, ri = idx[mask], idx[~mask]
            l_node, r_node = self._new_node(), self._new_node()
            self.feature[node] = f
            self.threshold[node] = thr
            self.left[node] = l_node
            self.right[node] = r_node
            stack.append((l_node, li, depth + 1))
            stack.append((r_node, ri, depth + 1))

    def predict(self, Xb: np.ndarray) -> np.ndarray:
        feature = np.asarray(self.feature)
        threshold = np.asarray(self.threshold)
        left = np.asarray(self.left)
        right = np.asarray(self.right)
        value = np.asarray(self.value)
        out = np.empty(len(Xb), dtype=np.float64)
        node_ids = np.zeros(len(Xb), dtype=np.int64)
        active = np.arange(len(Xb))
        while len(active):
            nodes = node_ids[active]
            leaf_mask = feature[nodes] < 0
            leaf_rows = active[leaf_mask]
            out[leaf_rows] = value[nodes[leaf_mask]]
            active = active[~leaf_mask]
            if not len(active):
                break
            nodes = node_ids[active]
            go_left = (
                Xb[active, feature[nodes]] <= threshold[nodes]
            )
            node_ids[active] = np.where(go_left, left[nodes], right[nodes])
        return out


class RandomForestRegressor:
    """Bootstrap-aggregated regression trees (MSE splits), NumPy only."""

    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int = 12,
        min_samples_leaf: int = 1,
        max_features: Optional[int] = None,
        n_bins: int = 256,
        max_samples: float = 1.0,
        seed: int = 0,
        leaf: str = "mean",  # "median": robust leaves (beyond-paper; exact
        #                       on constant recurrence under kill noise)
    ):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.n_bins = n_bins
        self.max_samples = max_samples
        self.seed = seed
        self.leaf = leaf
        self._trees: List[_Tree] = []
        self._bin_edges: List[np.ndarray] = []

    def _bin(self, X: np.ndarray) -> np.ndarray:
        Xb = np.empty(X.shape, dtype=np.int64)
        for f in range(X.shape[1]):
            Xb[:, f] = np.searchsorted(self._bin_edges[f], X[:, f], side="left")
        return Xb

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if X.ndim != 2 or len(X) != len(y) or len(y) == 0:
            raise ValueError("X must be (n, f) with matching non-empty y")
        self._bin_edges = []
        for f in range(X.shape[1]):
            qs = np.quantile(
                X[:, f], np.linspace(0, 1, self.n_bins), method="nearest"
            )
            self._bin_edges.append(np.unique(qs)[1:])  # internal boundaries
        Xb = self._bin(X)
        n_bins_eff = self.n_bins + 1
        rng = np.random.default_rng(self.seed)
        max_features = self.max_features or X.shape[1]
        n_sample = max(1, int(round(self.max_samples * len(y))))
        self._trees = []
        for _ in range(self.n_estimators):
            rows = rng.integers(0, len(y), size=n_sample)
            tree = _Tree()
            tree.fit(
                Xb[rows],
                y[rows],
                n_bins_eff,
                self.max_depth,
                self.min_samples_leaf,
                max_features,
                rng,
                leaf=self.leaf,
            )
            self._trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        if not self._trees:
            raise RuntimeError("fit() before predict()")
        Xb = self._bin(np.asarray(X, dtype=np.float64))
        preds = np.stack([tree.predict(Xb) for tree in self._trees])
        if self.leaf == "median":
            return np.median(preds, axis=0)
        return preds.mean(axis=0)


# --------------------------------------------------------------------------
# Scheduler-facing predictors
# --------------------------------------------------------------------------


class IterationPredictor:
    """Online interface: observe completed jobs, predict iterations."""

    def observe(self, job: JobSpec, true_iters: int) -> None:
        raise NotImplementedError

    def predict(self, job: JobSpec) -> float:
        raise NotImplementedError


class PerfectPredictor(IterationPredictor):
    def observe(self, job: JobSpec, true_iters: int) -> None:
        pass

    def predict(self, job: JobSpec) -> float:
        return float(job.n_iters)


class _GroupStats:
    __slots__ = ("values", "n", "total", "stat_n", "stat_val")

    def __init__(self) -> None:
        self.values: Optional[List[float]] = None  # median only (O(obs))
        self.n = 0
        self.total = 0.0
        # statistic memo: recurring-group arrivals between observations
        # would otherwise recompute the same mean/median per prediction
        self.stat_n = -1
        self.stat_val = 0.0


class GroupStatPredictor(IterationPredictor):
    """Mean/median of the group's previously observed iteration counts.

    The mean statistic keeps only (count, running sum) per group — O(1)
    per group, so memory stays bounded by the group universe on
    million-job streams.  Iteration counts are integer-valued, so the
    running sum is exact (no drift vs ``np.mean``).  The median keeps
    the observation list (order statistics need it).
    """

    def __init__(self, statistic: str = "mean"):
        if statistic not in ("mean", "median"):
            raise ValueError(statistic)
        self.statistic = statistic
        self._groups: Dict[int, _GroupStats] = defaultdict(_GroupStats)

    def observe(self, job: JobSpec, true_iters: int) -> None:
        if job.group_id >= 0:
            st = self._groups[job.group_id]
            st.n += 1
            st.total += float(true_iters)
            if self.statistic == "median":
                if st.values is None:
                    st.values = []
                st.values.append(float(true_iters))

    def predict(self, job: JobSpec) -> float:
        st = self._groups.get(job.group_id)
        if job.group_id < 0 or st is None or st.n == 0:
            return 0.0  # unseen job -> treat as instantly complete
        if st.stat_n != st.n:
            if self.statistic == "mean":
                st.stat_val = st.total / st.n
            else:
                st.stat_val = float(np.median(st.values))
            st.stat_n = st.n
        return st.stat_val


class RandomForestPredictor(IterationPredictor):
    """Paper's predictor: RF regression over ids + group history features.

    Features per job: [group_id, user_id, group_count, group_mean,
    group_median, group_last].  Retrains every ``retrain_every``
    observations (the paper retrains hourly/daily; 80 s for 700 k jobs).

    ``max_history`` bounds the training window to the most recent N
    completions: with in-run online retraining (prediction_loop's
    ``OnlineForestModel``) each refit would otherwise grow linearly with
    the stream, and real cluster recurrence drifts (arXiv 2109.01313),
    so a sliding window keeps both cost bounded and the model fresh.
    """

    def __init__(
        self,
        n_estimators: int = 100,
        retrain_every: int = 1500,  # ~daily at MLaaS arrival rates
        seed: int = 0,
        max_depth: int = 16,
        n_bins: int = 1024,
        max_history: Optional[int] = None,
    ):
        self.retrain_every = retrain_every
        self.max_history = max_history
        self._rf = RandomForestRegressor(
            n_estimators=n_estimators,
            max_depth=max_depth,
            max_samples=0.6,
            seed=seed,
            leaf="median",
            n_bins=n_bins,  # group-id granularity (~#groups)
        )
        self._groups: Dict[int, List[float]] = defaultdict(list)
        self._X: List[List[float]] = []
        self._y: List[float] = []
        self._since_retrain = 0
        self._fitted = False

    def _features(self, job: JobSpec) -> List[float]:
        vals = self._groups.get(job.group_id, [])
        if vals:
            mean, med, last = (
                float(np.mean(vals)),
                float(np.median(vals)),
                vals[-1],
            )
        else:
            mean = med = last = 0.0
        return [
            float(job.group_id),
            float(job.user_id),
            float(len(vals)),
            mean,
            med,
            last,
        ]

    def observe(self, job: JobSpec, true_iters: int) -> None:
        # Record the training example with the features *as seen at
        # prediction time* (before appending this observation).
        self._X.append(self._features(job))
        self._y.append(float(true_iters))
        if job.group_id >= 0:
            self._groups[job.group_id].append(float(true_iters))
        if self.max_history is not None and len(self._y) > 2 * self.max_history:
            # amortized O(1): trim in bulk once the buffer doubles
            del self._X[: len(self._X) - self.max_history]
            del self._y[: len(self._y) - self.max_history]
        self._since_retrain += 1
        if self._since_retrain >= self.retrain_every and len(self._y) >= 32:
            self._rf.fit(np.array(self._X), np.array(self._y))
            self._fitted = True
            self._since_retrain = 0

    def warm_start(self) -> None:
        """Force a fit on everything observed so far (paper Sec. V-A.1-c:
        the predictor is trained on the first 80 % of the trace)."""
        if len(self._y) >= 32:
            self._rf.fit(np.array(self._X), np.array(self._y))
            self._fitted = True
            self._since_retrain = 0

    def predict(self, job: JobSpec) -> float:
        if job.group_id < 0 or job.group_id not in self._groups:
            return 0.0  # unseen -> instantly complete in the virtual machine
        if not self._fitted:
            vals = self._groups[job.group_id]
            return float(np.median(vals)) if vals else 0.0
        pred = float(self._rf.predict(np.array([self._features(job)]))[0])
        return max(pred, 0.0)


def make_predictor(kind: str, seed: int = 0, **kw) -> IterationPredictor:
    if kind == "perfect":
        return PerfectPredictor()
    if kind == "mean":
        return GroupStatPredictor("mean")
    if kind == "median":
        return GroupStatPredictor("median")
    if kind == "rf":
        return RandomForestPredictor(seed=seed, **kw)
    raise ValueError(f"unknown predictor kind {kind!r}")
