"""Job model for DDLwMP (distributed DL with mixed parallelisms) scheduling.

Mirrors the paper's Section III system model:

* a job ``i`` trains a DNN for ``n_i`` iterations, split into ``S_i``
  pipeline stages; stage ``s`` is replicated over ``k_{i,s}`` accelerators
  (data parallelism inside the stage), so the job needs
  ``g_i = sum_s k_{i,s}`` accelerators in total;
* per-stage profile: forward/backward compute time ``p_f``/``p_b`` (seconds
  per mini-batch on one replica), per-iteration in/out activation bytes
  ``d_in``/``d_out`` per replica, and trainable-parameter bytes ``h``.

A single-GPU job is a job with one non-replicated stage.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

RAR = "rar"  # ring all-reduce
TAR = "tar"  # (double binary) tree all-reduce

# process-wide intern table for JobSpec.config_key (see below)
_CONFIG_IDS: dict = {}


def _intern_config(key: tuple) -> int:
    cid = _CONFIG_IDS.get(key)
    if cid is None:
        cid = len(_CONFIG_IDS)
        _CONFIG_IDS[key] = cid
    return cid


@dataclass(frozen=True)
class StageSpec:
    """Profile of a single pipeline stage (see paper Sec. III-A)."""

    p_f: float  # forward time per mini-batch, seconds
    p_b: float  # backward time per mini-batch, seconds
    d_in: float  # incoming activation bytes per iteration per replica
    d_out: float  # outgoing activation/gradient bytes per iteration per replica
    h: float  # trainable parameter bytes of this stage
    k: int = 1  # number of data-parallel replicas (== GPUs for this stage)

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError(f"stage replica count must be >= 1, got {self.k}")
        for name in ("p_f", "p_b", "d_in", "d_out", "h"):
            if getattr(self, name) < 0:
                raise ValueError(f"stage field {name} must be non-negative")


@dataclass(frozen=True)
class JobSpec:
    """A DDLwMP job: model stages + arrival + (true) iteration count.

    ``n_iters`` is the *actual* number of training iterations, unknown to the
    scheduler until completion; schedulers must rely on a prediction.
    """

    job_id: int
    stages: Tuple[StageSpec, ...]
    n_iters: int
    arrival: float = 0.0
    group_id: int = -1  # recurrence group (hash of meta-info); -1 = unseen
    user_id: int = 0
    allreduce: str = RAR  # RAR or TAR intra-stage synchronization
    model_name: str = ""

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("job must have at least one stage")
        if self.n_iters < 1:
            raise ValueError("job must run at least one iteration")
        if self.allreduce not in (RAR, TAR):
            raise ValueError(f"unknown allreduce kind {self.allreduce!r}")

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @functools.cached_property
    def g(self) -> int:
        """Total accelerators required: g_i = sum_s k_{i,s}.

        cached_property writes to the instance ``__dict__`` directly, which
        is allowed on frozen dataclasses — ``g`` is read on every capacity
        check in the scheduling hot path.
        """
        return sum(st.k for st in self.stages)

    @functools.cached_property
    def config_key(self) -> int:
        """Small interned id of the *structural* config (stages, allreduce).

        Jobs with equal config ids map identically onto equal server
        capacities; caches key on this id instead of re-hashing the whole
        stage tuple on every probe (recurring MLaaS jobs share configs, so
        the intern table stays small).
        """
        return _intern_config((self.stages, self.allreduce))

    @property
    def is_single_gpu(self) -> bool:
        return self.g == 1

    def with_iters(self, n_iters: int) -> "JobSpec":
        return dataclasses.replace(self, n_iters=n_iters)

    def replica_vertices(self) -> Sequence[Tuple[int, int]]:
        """Vertices of the job graph: (stage_index, replica_index)."""
        return [
            (s, r) for s, st in enumerate(self.stages) for r in range(st.k)
        ]


@dataclass(frozen=True)
class ClusterSpec:
    """Homogeneous cluster: M servers x g accelerators (paper Sec. III)."""

    num_servers: int  # M
    gpus_per_server: int  # g
    b_inter: float  # NIC (inter-server) bidirectional bandwidth, bytes/s
    b_intra: float  # intra-server (NVLink/ICI) bandwidth, bytes/s

    def __post_init__(self) -> None:
        if self.num_servers < 1 or self.gpus_per_server < 1:
            raise ValueError("cluster must have >= 1 server and >= 1 GPU each")
        if self.b_inter <= 0 or self.b_intra <= 0:
            raise ValueError("bandwidths must be positive")

    @property
    def total_gpus(self) -> int:  # G = M * g
        return self.num_servers * self.gpus_per_server


Placement = dict  # {server_id: np.ndarray[S_i]} -- x_{i,s}^m, see timing.py
