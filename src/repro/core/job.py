"""Job model for DDLwMP (distributed DL with mixed parallelisms) scheduling.

Mirrors the paper's Section III system model:

* a job ``i`` trains a DNN for ``n_i`` iterations, split into ``S_i``
  pipeline stages; stage ``s`` is replicated over ``k_{i,s}`` accelerators
  (data parallelism inside the stage), so the job needs
  ``g_i = sum_s k_{i,s}`` accelerators in total;
* per-stage profile: forward/backward compute time ``p_f``/``p_b`` (seconds
  per mini-batch on one replica), per-iteration in/out activation bytes
  ``d_in``/``d_out`` per replica, and trainable-parameter bytes ``h``.

A single-GPU job is a job with one non-replicated stage.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
from dataclasses import dataclass
from typing import Sequence, Tuple

RAR = "rar"  # ring all-reduce
TAR = "tar"  # (double binary) tree all-reduce

# process-wide intern table for JobSpec.config_key (see below)
_CONFIG_IDS: dict = {}


def _intern_config(key: tuple) -> int:
    cid = _CONFIG_IDS.get(key)
    if cid is None:
        cid = len(_CONFIG_IDS)
        _CONFIG_IDS[key] = cid
    return cid


@dataclass(frozen=True)
class StageSpec:
    """Profile of a single pipeline stage (see paper Sec. III-A)."""

    p_f: float  # forward time per mini-batch, seconds
    p_b: float  # backward time per mini-batch, seconds
    d_in: float  # incoming activation bytes per iteration per replica
    d_out: float  # outgoing activation/gradient bytes per iteration per replica
    h: float  # trainable parameter bytes of this stage
    k: int = 1  # number of data-parallel replicas (== GPUs for this stage)

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError(f"stage replica count must be >= 1, got {self.k}")
        for name in ("p_f", "p_b", "d_in", "d_out", "h"):
            if getattr(self, name) < 0:
                raise ValueError(f"stage field {name} must be non-negative")


@dataclass(frozen=True)
class JobSpec:
    """A DDLwMP job: model stages + arrival + (true) iteration count.

    ``n_iters`` is the *actual* number of training iterations, unknown to the
    scheduler until completion; schedulers must rely on a prediction.
    """

    job_id: int
    stages: Tuple[StageSpec, ...]
    n_iters: int
    arrival: float = 0.0
    group_id: int = -1  # recurrence group (hash of meta-info); -1 = unseen
    user_id: int = 0
    allreduce: str = RAR  # RAR or TAR intra-stage synchronization
    model_name: str = ""

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("job must have at least one stage")
        if self.n_iters < 1:
            raise ValueError("job must run at least one iteration")
        if self.allreduce not in (RAR, TAR):
            raise ValueError(f"unknown allreduce kind {self.allreduce!r}")

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @functools.cached_property
    def g(self) -> int:
        """Total accelerators required: g_i = sum_s k_{i,s}.

        cached_property writes to the instance ``__dict__`` directly, which
        is allowed on frozen dataclasses — ``g`` is read on every capacity
        check in the scheduling hot path.
        """
        return sum(st.k for st in self.stages)

    @functools.cached_property
    def config_key(self) -> int:
        """Small interned id of the *structural* config (stages, allreduce).

        Jobs with equal config ids map identically onto equal server
        capacities; caches key on this id instead of re-hashing the whole
        stage tuple on every probe (recurring MLaaS jobs share configs, so
        the intern table stays small).
        """
        return _intern_config((self.stages, self.allreduce))

    @property
    def is_single_gpu(self) -> bool:
        return self.g == 1

    def with_iters(self, n_iters: int) -> "JobSpec":
        return dataclasses.replace(self, n_iters=n_iters)

    def replica_vertices(self) -> Sequence[Tuple[int, int]]:
        """Vertices of the job graph: (stage_index, replica_index)."""
        return [
            (s, r) for s, st in enumerate(self.stages) for r in range(st.k)
        ]


@dataclass(frozen=True)
class ServerClass:
    """One generation/SKU of servers in a heterogeneous cluster.

    Real GPU datacenters mix generations (mixed per-node GPU counts and NIC
    speeds — Hu et al., arXiv 2109.01313); a ``ClusterSpec`` is a sequence
    of these classes.  ``b_intra == 0`` inherits the cluster-wide intra
    bandwidth.
    """

    count: int  # servers of this class
    gpus_per_server: int
    b_inter: float  # NIC bandwidth of this class, bytes/s
    b_intra: float = 0.0  # 0.0 -> inherit ClusterSpec.b_intra
    name: str = ""  # e.g. "a100x8"

    def __post_init__(self) -> None:
        if self.count < 1 or self.gpus_per_server < 1:
            raise ValueError("server class needs >= 1 server and >= 1 GPU")
        if self.b_inter <= 0 or self.b_intra < 0:
            raise ValueError("class bandwidths must be positive")


# (gpus_per_server, b_inter, b_intra) of one server — the only attributes
# the timing model reads (the ``geom``/``geoms`` params in timing.py).
ServerGeom = Tuple[int, float, float]


def build_bw_ranks(
    bandwidths: Sequence[float],
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Per-server positions in the ``(-bw, id)`` and ``(bw, id)`` orderings.

    The one definition of the ``select_servers`` bandwidth-tiebreak rank
    construction, shared by the static ``ClusterSpec.bw_order_ranks``
    (class NIC bandwidths) and the dynamic
    ``ClusterState.effective_bw_ranks`` (bandwidth x speed factor).
    """
    n = len(bandwidths)
    desc = sorted(range(n), key=lambda m: (-bandwidths[m], m))
    asc = sorted(range(n), key=lambda m: (bandwidths[m], m))
    desc_rank = [0] * n
    asc_rank = [0] * n
    for r, m in enumerate(desc):
        desc_rank[m] = r
    for r, m in enumerate(asc):
        asc_rank[m] = r
    return tuple(desc_rank), tuple(asc_rank)


@dataclass(frozen=True)
class ClusterSpec:
    """Cluster of M servers (paper Sec. III, extended to mixed generations).

    The paper models a homogeneous cluster (one ``gpus_per_server``, one
    NIC bandwidth); that remains the default construction.  Passing
    ``server_classes`` generalizes to a heterogeneous cluster: server ids
    are laid out class by class in the order given (class 0 owns ids
    ``[0, count_0)``, class 1 the next ``count_1`` ids, ...).  For a
    heterogeneous spec ``gpus_per_server`` must be the *maximum* per-server
    count and ``b_inter`` the *minimum* NIC bandwidth (the conservative
    values every homogeneous-era formula degrades to); use
    ``ClusterSpec.heterogeneous`` to get those invariants for free.
    """

    num_servers: int  # M
    gpus_per_server: int  # g (max per-server count when heterogeneous)
    b_inter: float  # NIC bandwidth, bytes/s (min over classes when het.)
    b_intra: float  # intra-server (NVLink/ICI) bandwidth, bytes/s
    server_classes: Tuple[ServerClass, ...] = ()

    def __post_init__(self) -> None:
        if self.num_servers < 1 or self.gpus_per_server < 1:
            raise ValueError("cluster must have >= 1 server and >= 1 GPU each")
        if self.b_inter <= 0 or self.b_intra <= 0:
            raise ValueError("bandwidths must be positive")
        if self.server_classes:
            if sum(c.count for c in self.server_classes) != self.num_servers:
                raise ValueError("server class counts must sum to num_servers")
            if max(c.gpus_per_server for c in self.server_classes) != (
                self.gpus_per_server
            ):
                raise ValueError(
                    "gpus_per_server must be the max over server classes"
                )
            if min(c.b_inter for c in self.server_classes) != self.b_inter:
                raise ValueError(
                    "b_inter must be the min over server classes"
                )

    @classmethod
    def heterogeneous(
        cls, classes: Sequence[ServerClass], b_intra: float
    ) -> "ClusterSpec":
        """Build a mixed-generation spec; derives the scalar summary fields."""
        classes = tuple(classes)
        if not classes:
            raise ValueError("need at least one server class")
        return cls(
            num_servers=sum(c.count for c in classes),
            gpus_per_server=max(c.gpus_per_server for c in classes),
            b_inter=min(c.b_inter for c in classes),
            b_intra=b_intra,
            server_classes=classes,
        )

    @property
    def is_heterogeneous(self) -> bool:
        return bool(self.server_classes)

    @functools.cached_property
    def _class_bounds(self) -> Tuple[int, ...]:
        """Cumulative server-id upper bound per class (for bisect lookup)."""
        bounds = []
        acc = 0
        for c in self.server_classes:
            acc += c.count
            bounds.append(acc)
        return tuple(bounds)

    @functools.cached_property
    def _class_geoms(self) -> Tuple[ServerGeom, ...]:
        return tuple(
            (c.gpus_per_server, c.b_inter, c.b_intra or self.b_intra)
            for c in self.server_classes
        )

    def class_of(self, server_id: int) -> int:
        """Class index of server ``server_id`` (0 on homogeneous specs)."""
        if not self.server_classes:
            return 0
        return bisect.bisect_right(self._class_bounds, server_id)

    def server_gpus(self, server_id: int) -> int:
        if not self.server_classes:
            return self.gpus_per_server
        return self._class_geoms[self.class_of(server_id)][0]

    def server_geom(self, server_id: int) -> ServerGeom:
        """(gpus, b_inter, b_intra) of one server; see timing.py."""
        if not self.server_classes:
            return (self.gpus_per_server, self.b_inter, self.b_intra)
        return self._class_geoms[self.class_of(server_id)]

    def class_geom(self, class_id: int) -> ServerGeom:
        if not self.server_classes:
            return (self.gpus_per_server, self.b_inter, self.b_intra)
        return self._class_geoms[class_id]

    @functools.cached_property
    def total_gpus(self) -> int:  # G
        if self.server_classes:
            return sum(c.count * c.gpus_per_server for c in self.server_classes)
        return self.num_servers * self.gpus_per_server

    @functools.cached_property
    def bw_order_ranks(self) -> "Tuple[Tuple[int, ...], Tuple[int, ...]]":
        """Per-server positions in the ``(-b_inter, id)`` and
        ``(b_inter, id)`` orderings — the ``select_servers`` bandwidth
        tiebreaks, precomputed once so the per-event hot path sorts
        buckets on a plain indexed int key instead of a geometry lookup.
        """
        return build_bw_ranks(
            [self.server_geom(m)[1] for m in range(self.num_servers)]
        )


Placement = dict  # {server_id: np.ndarray[S_i]} -- x_{i,s}^m, see timing.py
