"""Closing the prediction loop (ISSUE 8; paper Sec. IV-C3 + ROADMAP 3).

Until this module, the benchmark scenarios ran A-SRPT with effectively
oracle iteration counts: a predictor was consulted once per arrival, but
nothing in the engine reacted when the prediction was *wrong*.  The
paper's headline is prediction-assisted scheduling, and its robustness
story has two halves:

* **Prediction plurality.**  :class:`PredictionModel` wraps any
  :class:`~repro.core.predictor.IterationPredictor` with the run-time
  contract the simulator understands: whether predicted completions
  should be *watched* (``track_overruns``) and how to re-estimate a job
  that ran past its prediction (``reestimate``).  Concrete models:
  :class:`OracleModel` (true iteration counts, nothing watched — the
  legacy engine byte for byte), :class:`OnlineForestModel` (the paper's
  random forest retraining online from completed jobs inside the run on
  a bounded cadence), :class:`ZeroColdStartModel` (every job predicted 0
  — the paper's unseen-job rule taken to its extreme), and
  :class:`NoisyModel` (controlled error injection against the true
  counts: multiplicative lognormal, sign-flipped rank order, cold-start
  fraction).

* **Mid-flight re-estimation with exponential backoff.**  A job whose
  true work exceeds its prediction reaches its *predicted* completion
  while still running.  The simulator fires a predicted-completion check
  there (``simulator._PredCheck``) and asks the policy to re-estimate;
  the default :meth:`PredictionModel.reestimate` is the classic robust
  SRPT-with-predictions move — the new predicted total is
  ``max(elapsed, floor) * backoff_factor`` — so the iterations completed
  between consecutive re-estimates grow geometrically and a job with
  ``n`` true iterations is re-estimated at most
  ``O(log(n / max(floor, n_pred)))`` times regardless of how wrong the
  initial prediction was (property-tested in
  tests/test_prediction_loop.py).  The paper's unseen -> 0 jobs are the
  extreme case: predicted instantly complete, scheduled ASAP, then
  re-estimated 1, 2, 4, ... iterations as they keep running — they
  terminate without ever starving the queue because physical completions
  are always timed with true work; predictions only steer *decisions*
  (release order, delay budgets, migration races).

Error injection is also a first-class fleet axis:
:class:`~repro.core.scenario.PredictionNoisePerturbation` installs a
seeded :class:`NoisyModel` on each fleet variant's policy through the
``Perturbation.perturb_policy`` hook, so the PR-7 Monte-Carlo machinery
sweeps prediction-error regimes exactly like it sweeps stragglers.  The
``sched_scale --predict`` benchmark turns the flow-time-vs-oracle ratios
into a CI-gated number (benchmarks/README.md).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .job import JobSpec
from .predictor import (
    IterationPredictor,
    PerfectPredictor,
    RandomForestPredictor,
)

BACKOFF_FACTOR_DEFAULT = 2.0
# New predicted totals never shrink below this many iterations: it is the
# first re-estimate of a 0-predicted (unseen) job and the growth floor
# that makes the backoff terminate in O(log n) steps.
BACKOFF_FLOOR_DEFAULT = 1.0


class PredictionModel(IterationPredictor):
    """An :class:`IterationPredictor` plus the run-time prediction-loop
    contract.

    ``track_overruns`` is what the policies forward to the simulator
    (``Policy.track_overruns``): when truthy, every start carries its
    predicted iteration count (``Allocation.n_pred``) and the simulator
    watches for the job running past ``start + n_pred * alpha``; when
    false the engine runs the pre-prediction-loop event sequence byte
    for byte (the golden fixtures pin this — an ``OracleModel`` or a
    plain unwrapped predictor is bit-identical to the legacy engine).

    The base class is a transparent pass-through over ``base``: wrapping
    any predictor with ``track_overruns=False`` changes nothing
    observable (tests/test_prediction_loop.py holds that against all 10
    golden schedules).
    """

    def __init__(
        self,
        base: IterationPredictor,
        track_overruns: bool = True,
        backoff_factor: float = BACKOFF_FACTOR_DEFAULT,
        backoff_floor: float = BACKOFF_FLOOR_DEFAULT,
    ):
        if backoff_factor <= 1.0:
            raise ValueError(
                f"backoff_factor must exceed 1.0 for the re-estimation "
                f"loop to terminate, got {backoff_factor}"
            )
        if backoff_floor <= 0.0:
            raise ValueError(
                f"backoff_floor must be positive, got {backoff_floor}"
            )
        self.base = base
        self.track_overruns = track_overruns
        self.backoff_factor = backoff_factor
        self.backoff_floor = backoff_floor

    def observe(self, job: JobSpec, true_iters: int) -> None:
        self.base.observe(job, true_iters)

    def predict(self, job: JobSpec) -> float:
        return self.base.predict(job)

    def reestimate(self, job: JobSpec, elapsed_iters: float) -> float:
        """New predicted *total* iterations for a job that has completed
        ``elapsed_iters`` and run past its last prediction.

        Exponential backoff on the elapsed work: each re-estimate at
        least multiplies the implied remaining-work window by
        ``backoff_factor - 1`` of the elapsed, so consecutive checks are
        geometrically spaced and the count is logarithmic in the true
        iteration count.  Subclasses may consult fresher model state
        instead, as long as the returned total strictly exceeds
        ``elapsed_iters`` (the simulator clamps pathological answers).
        """
        return max(elapsed_iters, self.backoff_floor) * self.backoff_factor


class OracleModel(PredictionModel):
    """True iteration counts, no overrun watching: the engine's event
    sequence — and therefore every schedule digest — is byte-identical
    to the pre-prediction-loop engine (the ``--predict`` benchmark's
    ratio-1.0 baseline)."""

    def __init__(self) -> None:
        super().__init__(PerfectPredictor(), track_overruns=False)


class OnlineForestModel(PredictionModel):
    """The paper's random-forest predictor, retrained *inside* the run.

    Wraps :class:`~repro.core.predictor.RandomForestPredictor`: every
    completed job feeds ``observe`` (recurrence is the paper's key
    observation), the forest refits every ``retrain_every`` completions
    over a ``max_history``-bounded window (bounded cadence *and* bounded
    cost on long streams), and unseen jobs predict 0 per the paper —
    the backoff re-estimator is what keeps those from being scheduling
    landmines.
    """

    def __init__(
        self,
        seed: int = 0,
        retrain_every: int = 300,
        n_estimators: int = 50,
        max_history: Optional[int] = 20_000,
        backoff_factor: float = BACKOFF_FACTOR_DEFAULT,
        backoff_floor: float = BACKOFF_FLOOR_DEFAULT,
    ):
        super().__init__(
            RandomForestPredictor(
                seed=seed,
                retrain_every=retrain_every,
                n_estimators=n_estimators,
                max_history=max_history,
            ),
            track_overruns=True,
            backoff_factor=backoff_factor,
            backoff_floor=backoff_floor,
        )

    def warm_start(self) -> None:
        """Force a fit on everything observed so far (paper Sec. V-A.1-c)."""
        self.base.warm_start()


class ZeroColdStartModel(PredictionModel):
    """Every job predicted 0 — the unseen-job rule with no learning.

    The worst case the acceptance criterion names: all jobs release ASAP
    in arrival order (zero virtual work), every job overruns
    immediately, and the backoff re-estimator alone bounds the check
    count.  ``observe`` is deliberately a no-op.
    """

    def __init__(
        self,
        backoff_factor: float = BACKOFF_FACTOR_DEFAULT,
        backoff_floor: float = BACKOFF_FLOOR_DEFAULT,
    ):
        super().__init__(
            _ZeroPredictor(),
            track_overruns=True,
            backoff_factor=backoff_factor,
            backoff_floor=backoff_floor,
        )


class _ZeroPredictor(IterationPredictor):
    def observe(self, job: JobSpec, true_iters: int) -> None:
        pass

    def predict(self, job: JobSpec) -> float:
        return 0.0


NOISE_MODES = ("lognormal", "rankflip", "coldstart")


class NoisyModel(PredictionModel):
    """Controlled prediction-error injection against the true counts.

    Three error regimes (``mode``):

    * ``"lognormal"`` — multiplicative lognormal noise,
      ``pred = true * exp(N(0, sigma^2))``: median-unbiased, heavy
      two-sided relative error (the realistic drift regime of
      arXiv 2109.01313).
    * ``"rankflip"`` — sign-flipped rank order, ``pred = scale^2 /
      max(true, 1)``: long jobs predicted short and short jobs long —
      adversarial for any SRPT-family policy, since the *ordering* is
      exactly inverted while the magnitude stays plausible.
    * ``"coldstart"`` — a ``cold_frac`` fraction of jobs predicted 0
      (the paper's unseen-job rule hitting a random subset), the rest
      exact.

    Noise is a pure function of ``(seed, job_id)`` — each job draws from
    ``numpy.random.default_rng([seed, job_id])`` — so predictions are
    deterministic and independent of call order / call count, which
    keeps noisy schedules replayable and fleet variants a pure function
    of the fleet seed.
    """

    def __init__(
        self,
        mode: str = "lognormal",
        sigma: float = 0.5,
        cold_frac: float = 0.3,
        scale: float = 400.0,
        seed: int = 0,
        backoff_factor: float = BACKOFF_FACTOR_DEFAULT,
        backoff_floor: float = BACKOFF_FLOOR_DEFAULT,
    ):
        if mode not in NOISE_MODES:
            raise ValueError(
                f"unknown noise mode {mode!r} (one of {NOISE_MODES})"
            )
        if not 0.0 <= cold_frac <= 1.0:
            raise ValueError(f"cold_frac must be in [0, 1], got {cold_frac}")
        super().__init__(
            PerfectPredictor(),
            track_overruns=True,
            backoff_factor=backoff_factor,
            backoff_floor=backoff_floor,
        )
        self.mode = mode
        self.sigma = sigma
        self.cold_frac = cold_frac
        self.scale = scale
        self.seed = seed

    def observe(self, job: JobSpec, true_iters: int) -> None:
        pass  # the injected error never "learns" away

    def predict(self, job: JobSpec) -> float:
        true = float(job.n_iters)
        if self.mode == "rankflip":
            return self.scale * self.scale / max(true, 1.0)
        rng = np.random.default_rng([self.seed, job.job_id])
        if self.mode == "coldstart":
            return 0.0 if rng.random() < self.cold_frac else true
        return true * float(np.exp(rng.normal(0.0, self.sigma)))


def make_prediction_model(kind: str, seed: int = 0, **kw) -> PredictionModel:
    """Factory mirroring ``predictor.make_predictor`` for the run-time
    models: ``oracle`` / ``forest`` / ``zero`` / ``lognormal`` /
    ``rankflip`` / ``coldstart``."""
    if kind == "oracle":
        return OracleModel()
    if kind == "forest":
        return OnlineForestModel(seed=seed, **kw)
    if kind == "zero":
        return ZeroColdStartModel(**kw)
    if kind in NOISE_MODES:
        return NoisyModel(kind, seed=seed, **kw)
    raise ValueError(f"unknown prediction model kind {kind!r}")
