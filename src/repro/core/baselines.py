"""Baseline schedulers from the paper's evaluation (Sec. V-A.1-d).

* SPJF  — shortest *predicted job* first (MLaaS [6]); strict head-of-line.
* SPWF  — shortest *predicted workload* (duration x GPUs) first (Tiresias
          [14] style); strict head-of-line.
* WCS-Duration / WCS-Workload / WCS-SubTime — work-conserving scheduler [46]:
  scan the queue in key order and start *every* job that currently fits
  (backfilling), keyed by predicted duration / predicted workload /
  submission time respectively.

All baselines use the Heavy-Edge algorithm for GPU mapping (as in the paper)
with consolidating (most-available-first) server selection.
"""
from __future__ import annotations

import bisect
from typing import Callable, List, Optional

from .cluster import ClusterState
from .heavy_edge import PlacementCache, select_servers
from .job import ClusterSpec, JobSpec
from .predictor import IterationPredictor
from .simulator import AlphaCache, Policy, Start


class QueuePolicy(Policy):
    """Priority-queue scheduler parameterized by key and work-conservation.

    The queue is kept sorted in *descending* priority-key order so the next
    job to consider sits at the end of the list: arrivals insert with
    ``bisect.insort`` (no per-event re-sort) and the strict head-of-line
    policies pop starts from the end without rebuilding the list — both
    were O(queue) per event and dominated trace-scale runs.
    """

    def __init__(
        self,
        predictor: IterationPredictor,
        key: str,
        work_conserving: bool,
    ):
        if key not in ("duration", "workload", "subtime"):
            raise ValueError(key)
        self.predictor = predictor
        self.key_kind = key
        self.work_conserving = work_conserving
        # (-key, -arrival, -job_id, job): ascending sort puts the smallest
        # (key, arrival, job_id) — the next job to schedule — at the end.
        self.waiting: List[tuple] = []

    def bind(self, cluster_spec: ClusterSpec) -> None:
        super().bind(cluster_spec)
        self.alpha_cache = AlphaCache(cluster_spec)
        self._pcache = PlacementCache(cluster_spec)

    def _key(self, job: JobSpec) -> float:
        if self.key_kind == "subtime":
            return job.arrival
        n_pred = self.predictor.predict(job)
        _, a_min = self.alpha_cache.bounds(job)
        dur = n_pred * a_min
        if self.key_kind == "duration":
            return dur
        return dur * job.g  # workload

    def on_arrival(self, t: float, job: JobSpec) -> None:
        # Key is fixed at arrival (prediction with information available now).
        bisect.insort(
            self.waiting, (-self._key(job), -job.arrival, -job.job_id, job)
        )

    def on_completion(self, t: float, job: JobSpec) -> None:
        self.predictor.observe(job, job.n_iters)

    def _start(self, job: JobSpec, cluster: ClusterState, starts) -> None:
        caps = select_servers(cluster.free, job.g, consolidate=True)
        placement, a = self._pcache.map_job(job, caps)
        starts.append(Start(job, placement, a))
        cluster.allocate(job.job_id, placement, counts=dict(caps))

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        starts: List[Start] = []
        waiting = self.waiting
        if not waiting or cluster.total_free == 0:
            return starts

        if not self.work_conserving:
            # Strict head-of-line: start from the head until one doesn't fit.
            while waiting and waiting[-1][3].g <= cluster.total_free:
                self._start(waiting.pop()[3], cluster, starts)
            return starts

        # Work-conserving: scan the whole queue in key order, starting
        # everything that fits (backfilling); stop once no GPU is free.
        started_idx = []
        for i in range(len(waiting) - 1, -1, -1):
            free = cluster.total_free
            if free == 0:
                break
            job = waiting[i][3]
            if job.g <= free:
                self._start(job, cluster, starts)
                started_idx.append(i)
        if started_idx:
            for i in started_idx:  # descending, so positions stay valid
                del waiting[i]
        return starts

    def queue_depth(self) -> int:
        return len(self.waiting)


def spjf(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=False)


def spwf(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=False)


def wcs_duration(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=True)


def wcs_workload(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=True)


def wcs_subtime(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="subtime", work_conserving=True)


BASELINES: dict[str, Callable[[IterationPredictor], Policy]] = {
    "SPJF": spjf,
    "SPWF": spwf,
    "WCS-Duration": wcs_duration,
    "WCS-Workload": wcs_workload,
    "WCS-SubTime": wcs_subtime,
}
