"""Baseline schedulers from the paper's evaluation (Sec. V-A.1-d).

* SPJF  — shortest *predicted job* first (MLaaS [6]); strict head-of-line.
* SPWF  — shortest *predicted workload* (duration x GPUs) first (Tiresias
          [14] style); strict head-of-line.
* WCS-Duration / WCS-Workload / WCS-SubTime — work-conserving scheduler [46]:
  scan the queue in key order and start *every* job that currently fits
  (backfilling), keyed by predicted duration / predicted workload /
  submission time respectively.

All baselines use the Heavy-Edge algorithm for GPU mapping (as in the paper)
with consolidating (most-available-first) server selection.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from .cluster import ClusterState
from .heavy_edge import map_job, select_servers
from .job import ClusterSpec, JobSpec
from .predictor import IterationPredictor
from .simulator import AlphaCache, Policy, Start


class QueuePolicy(Policy):
    """Priority-queue scheduler parameterized by key and work-conservation."""

    def __init__(
        self,
        predictor: IterationPredictor,
        key: str,
        work_conserving: bool,
    ):
        if key not in ("duration", "workload", "subtime"):
            raise ValueError(key)
        self.predictor = predictor
        self.key_kind = key
        self.work_conserving = work_conserving
        self.waiting: List[tuple] = []  # (key, arrival, job_id, job)

    def bind(self, cluster_spec: ClusterSpec) -> None:
        super().bind(cluster_spec)
        self.alpha_cache = AlphaCache(cluster_spec)

    def _key(self, job: JobSpec) -> float:
        if self.key_kind == "subtime":
            return job.arrival
        n_pred = self.predictor.predict(job)
        _, a_min = self.alpha_cache.bounds(job)
        dur = n_pred * a_min
        if self.key_kind == "duration":
            return dur
        return dur * job.g  # workload

    def on_arrival(self, t: float, job: JobSpec) -> None:
        # Key is fixed at arrival (prediction with information available now).
        self.waiting.append((self._key(job), job.arrival, job.job_id, job))
        self.waiting.sort()

    def on_completion(self, t: float, job: JobSpec) -> None:
        self.predictor.observe(job, job.n_iters)

    def schedule(self, t: float, cluster: ClusterState) -> List[Start]:
        starts: List[Start] = []
        kept: List[tuple] = []
        blocked = False
        for entry in self.waiting:
            job = entry[3]
            if not blocked and job.g <= cluster.total_free:
                caps = select_servers(cluster.free, job.g, consolidate=True)
                placement, a = map_job(job, caps, self.cluster_spec)
                starts.append(Start(job, placement, a))
                cluster.allocate(job.job_id, placement)
            else:
                kept.append(entry)
                if not self.work_conserving:
                    # Strict head-of-line blocking: nothing behind may pass.
                    blocked = True
        self.waiting = kept
        for s in starts:
            cluster.release(s.job.job_id)
        return starts


def spjf(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=False)


def spwf(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=False)


def wcs_duration(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=True)


def wcs_workload(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=True)


def wcs_subtime(predictor: IterationPredictor) -> QueuePolicy:
    return QueuePolicy(predictor, key="subtime", work_conserving=True)


BASELINES: dict[str, Callable[[IterationPredictor], Policy]] = {
    "SPJF": spjf,
    "SPWF": spwf,
    "WCS-Duration": wcs_duration,
    "WCS-Workload": wcs_workload,
    "WCS-SubTime": wcs_subtime,
}
