"""Baseline schedulers from the paper's evaluation (Sec. V-A.1-d).

* SPJF  — shortest *predicted job* first (MLaaS [6]); strict head-of-line.
* SPWF  — shortest *predicted workload* (duration x GPUs) first (Tiresias
          [14] style); strict head-of-line.
* WCS-Duration / WCS-Workload / WCS-SubTime — work-conserving scheduler [46]:
  scan the queue in key order and start *every* job that currently fits
  (backfilling), keyed by predicted duration / predicted workload /
  submission time respectively.

All baselines use the Heavy-Edge algorithm for GPU mapping (as in the paper)
with consolidating (most-available-first) server selection.
"""
from __future__ import annotations

import bisect
import heapq
from typing import Callable, Dict, List

from .cluster import ClusterState
from .heavy_edge import select_servers
from .job import ClusterSpec, JobSpec
from .migration import MIGRATION_PENALTY_DEFAULT, MigrationMixin
from .predictor import IterationPredictor
from .simulator import Policy, Start


class QueuePolicy(MigrationMixin, Policy):
    """Priority-queue scheduler parameterized by key and work-conservation.

    Strict head-of-line mode keeps one queue sorted in *descending*
    priority-key order so the next job to consider sits at the end of the
    list: arrivals insert with ``bisect.insort`` (no per-event re-sort) and
    starts pop from the end without rebuilding the list.

    Work-conserving mode additionally *capacity-indexes* the ready queue:
    jobs are bucketed by GPU demand ``g`` (a handful of distinct values —
    the profile configs — regardless of queue length), each bucket sorted
    the same way.  A scheduling pass merges the bucket heads through a
    small heap, visiting jobs in global key order but touching only
    buckets that still fit in the free capacity — a bucket whose demand
    exceeds the remaining free GPUs drops out of the pass wholesale
    instead of being re-scanned job by job.  Free capacity only shrinks
    within a pass, so the started set and start order are identical to the
    former full-queue backfilling scan.
    """

    def __init__(
        self,
        predictor: IterationPredictor,
        key: str,
        work_conserving: bool,
        migrate: bool = False,  # checkpoint-restart off degraded servers
        migration_penalty: float = MIGRATION_PENALTY_DEFAULT,
        # queue-aware race (migration.py); stays False — see the
        # sched_scale --guard verdict in asrpt.py
        migration_queue_guard: bool = False,
    ):
        if key not in ("duration", "workload", "subtime"):
            raise ValueError(key)
        self.predictor = predictor
        # prediction-loop opt-in (simulator.Policy / prediction_loop):
        # derived from the predictor so plain predictors keep the legacy
        # engine byte for byte
        self.track_overruns = bool(getattr(predictor, "track_overruns", False))
        self.key_kind = key
        self.work_conserving = work_conserving
        self.migrate = migrate
        self.migration_penalty = migration_penalty
        self.migration_queue_guard = migration_queue_guard
        # (-key, -arrival, -job_id, job): ascending sort puts the smallest
        # (key, arrival, job_id) — the next job to schedule — at the end.
        # Strict head-of-line uses the flat list; work-conserving buckets
        # the same tuples by job.g.
        self.waiting: List[tuple] = []
        self.waiting_by_g: Dict[int, List[tuple]] = {}
        self._n_waiting = 0

    def bind(self, cluster_spec: ClusterSpec) -> None:
        super().bind(cluster_spec)
        self.alpha_cache = self._make_alpha_cache(cluster_spec)
        self._pcache = self._make_placement_cache(cluster_spec)

    def _key(self, job: JobSpec) -> float:
        if self.key_kind == "subtime":
            return job.arrival
        n_pred = self.predictor.predict(job)
        _, a_min = self.alpha_cache.bounds(job)
        dur = n_pred * a_min
        if self.key_kind == "duration":
            return dur
        return dur * job.g  # workload

    def on_arrival(self, t: float, job: JobSpec) -> None:
        # Key is fixed at arrival (prediction with information available now).
        entry = (-self._key(job), -job.arrival, -job.job_id, job)
        if self.work_conserving:
            bucket = self.waiting_by_g.get(job.g)
            if bucket is None:
                bucket = self.waiting_by_g[job.g] = []
            bisect.insort(bucket, entry)
            self._n_waiting += 1
        else:
            bisect.insort(self.waiting, entry)

    def on_completion(self, t: float, job: JobSpec) -> None:
        self.predictor.observe(job, job.n_iters)

    def _start(self, job: JobSpec, cluster: ClusterState, starts) -> None:
        caps = select_servers(
            cluster.free, job.g, consolidate=True, spec=self.cluster_spec,
            buckets=cluster.free_buckets, total_free=cluster.total_free,
            ranks=cluster.effective_bw_ranks,
        )
        speeds = cluster.speeds_for(caps) if cluster.has_degraded else None
        placement, a = self._pcache.map_job(job, caps, speeds=speeds)
        starts.append(Start(job, placement, a, n_pred=self._n_pred(job)))
        cluster.allocate(job.job_id, placement, counts=dict(caps))

    def plan_pass(self, t: float, cluster: ClusterState) -> List[Start]:
        starts: List[Start] = []
        free = cluster.total_free
        if free == 0:
            return starts

        if not self.work_conserving:
            waiting = self.waiting
            # Strict head-of-line: start from the head until one doesn't fit.
            while waiting and waiting[-1][3].g <= cluster.total_free:
                self._start(waiting.pop()[3], cluster, starts)
            return starts

        if self._n_waiting == 0:
            return starts
        # Work-conserving backfill over the capacity-indexed queue: merge
        # the per-demand bucket heads in key order; a popped head whose
        # demand no longer fits retires its whole bucket for this pass
        # (free never grows mid-pass).
        by_g = self.waiting_by_g
        # bucket tails hold the *smallest* (key, arrival, job_id) — negate
        # the stored (-key, ...) tuples back for the min-heap merge
        heads = [
            ((-b[-1][0], -b[-1][1], -b[-1][2]), g)
            for g, b in by_g.items()
            if b and g <= free
        ]
        heapq.heapify(heads)
        while heads:
            _, g = heapq.heappop(heads)
            free = cluster.total_free
            if free == 0:
                break
            if g > free:
                continue  # whole bucket too big for the rest of the pass
            bucket = by_g[g]
            entry = bucket.pop()
            self._n_waiting -= 1
            self._start(entry[3], cluster, starts)
            if bucket:
                nxt = bucket[-1]
                heapq.heappush(heads, ((-nxt[0], -nxt[1], -nxt[2]), g))
        return starts

    def migration_queue_head(self, t: float) -> "JobSpec | None":
        """Queue-aware migration guard hook: the job the next pass would
        consider first — the tail of the strict queue, or the smallest
        (key, arrival, job_id) across the capacity-indexed bucket tails
        (a handful of buckets; same order the heap merge visits)."""
        if not self.work_conserving:
            return self.waiting[-1][3] if self.waiting else None
        best = None
        for bucket in self.waiting_by_g.values():
            if not bucket:
                continue
            e = bucket[-1]
            key = (-e[0], -e[1], -e[2])
            if best is None or key < best[0]:
                best = (key, e[3])
        return best[1] if best is not None else None

    def queue_depth(self) -> int:
        return self._n_waiting if self.work_conserving else len(self.waiting)


def spjf(predictor: IterationPredictor, **kw) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=False, **kw)


def spwf(predictor: IterationPredictor, **kw) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=False, **kw)


def wcs_duration(predictor: IterationPredictor, **kw) -> QueuePolicy:
    return QueuePolicy(predictor, key="duration", work_conserving=True, **kw)


def wcs_workload(predictor: IterationPredictor, **kw) -> QueuePolicy:
    return QueuePolicy(predictor, key="workload", work_conserving=True, **kw)


def wcs_subtime(predictor: IterationPredictor, **kw) -> QueuePolicy:
    return QueuePolicy(predictor, key="subtime", work_conserving=True, **kw)


BASELINES: dict[str, Callable[[IterationPredictor], Policy]] = {
    "SPJF": spjf,
    "SPWF": spwf,
    "WCS-Duration": wcs_duration,
    "WCS-Workload": wcs_workload,
    "WCS-SubTime": wcs_subtime,
}
