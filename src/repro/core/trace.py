"""Synthetic MLaaS-like workload trace (substitute for [6], see DESIGN.md).

The two-month Alibaba MLaaS trace is not redistributable/available offline.
This generator reproduces its published statistics that matter to the
scheduling problem:

* recurrence: ~65 % of jobs belong to groups submitted >= 5 times; group
  sizes are Zipf-heavy-tailed;
* >70 % single-GPU jobs by default (``single_gpu_frac``);
* heavy-tailed iteration counts per group (log-normal group mean), with a
  fraction of early-terminated runs (user kills / failed exploration), which
  is what makes iteration counts *uncertain* and prediction non-trivial;
* Poisson arrivals with diurnal modulation over the horizon.

Scenario-level samplers (``straggler_scenario``, ``elastic_scenario``,
``elastic_events``) bundle a sampled trace with a cluster spec and a
typed event timeline into one serializable
:class:`~repro.core.scenario.Scenario` — the simulate() input since
ISSUE 5 — so a single seed pins workload, cluster, and events, and the
whole thing replays via ``benchmarks/sched_scale.py --scenario``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .job import ClusterSpec, JobSpec, RAR, ServerClass, StageSpec, TAR
from .profiles import PAPER_MODELS, SINGLE_GPU_MODELS, build_stages, make_job
from .scenario import (
    ClusterEvent,
    Degradation,
    IterJobs,
    Scenario,
    ServerJoin,
    ServerLeave,
)

# Mixed-generation server SKUs (gpus/server, NIC B/s, intra B/s): production
# GPU clusters run several accelerator generations side by side (Hu et al.,
# arXiv 2109.01313).  Ordered newest -> oldest; bandwidths follow the
# paper's 10 GbE / NVLink magnitudes with a 100 GbE NIC on the newest SKU
# and a half-width 4-GPU node for the oldest.
GPU_GENERATIONS: tuple = (
    ("gen-a", 8, 12.5e9, 300e9),
    ("gen-b", 8, 1.25e9, 150e9),
    ("gen-c", 4, 1.25e9, 50e9),
)


def mixed_cluster_spec(
    num_servers: int = 16,
    seed: int = 0,
    n_classes: int = 2,
    b_intra: float = 300e9,
) -> ClusterSpec:
    """Sample a mixed-generation cluster (companion to ``generate_trace``).

    Draws ``n_classes`` generations from ``GPU_GENERATIONS`` (newest first)
    and splits ``num_servers`` among them with every class non-empty, so a
    trace seed pins both the workload and the cluster it runs on.
    """
    if not 1 <= n_classes <= len(GPU_GENERATIONS):
        raise ValueError(
            f"n_classes must be in [1, {len(GPU_GENERATIONS)}]"
        )
    if num_servers < n_classes:
        raise ValueError("need at least one server per class")
    rng = np.random.default_rng(seed)
    # one server guaranteed per class; the rest multinomially split
    extra = rng.multinomial(
        num_servers - n_classes, np.full(n_classes, 1.0 / n_classes)
    )
    classes = [
        ServerClass(
            count=1 + int(extra[i]),
            gpus_per_server=gpus,
            b_inter=b_inter,
            b_intra=bi,
            name=name,
        )
        for i, (name, gpus, b_inter, bi) in enumerate(
            GPU_GENERATIONS[:n_classes]
        )
    ]
    return ClusterSpec.heterogeneous(classes, b_intra=b_intra)


def straggler_events(
    num_servers: int,
    horizon: float,
    n_stragglers: int = 4,
    seed: int = 0,
    factor_low: float = 0.25,
    factor_high: float = 0.75,
    start_frac: Tuple[float, float] = (0.2, 0.6),
    duration_frac: float = 0.25,
    recover: bool = True,
) -> List[Tuple[float, int, float]]:
    """Sample timed slowdown events for ``simulate(degradations=...)``.

    Production characterization (Hu et al., arXiv 2109.01313) attributes
    most tail slowdown to *partial* degradation — thermally throttled
    GPUs, flapping NICs — rather than outright failures.  This sampler
    draws ``n_stragglers`` distinct servers, each slowing to a factor in
    ``[factor_low, factor_high]`` at a time inside
    ``start_frac * horizon`` (mid-trace, so the cluster is loaded);
    ``recover=True`` pairs every slowdown with a return-to-1.0 event
    ``duration_frac * horizon`` later (clamped inside the horizon), so a
    finish-in-place policy pays the stretch while a migrating one can
    route around it.

    Deterministic per seed; events are returned time-sorted.
    """
    if n_stragglers > num_servers:
        raise ValueError(
            f"{n_stragglers} stragglers > {num_servers} servers"
        )
    if not 0.0 < factor_low <= factor_high:
        raise ValueError("factors must satisfy 0 < low <= high")
    rng = np.random.default_rng(seed)
    servers = rng.choice(num_servers, size=n_stragglers, replace=False)
    starts = rng.uniform(
        start_frac[0] * horizon, start_frac[1] * horizon, size=n_stragglers
    )
    factors = rng.uniform(factor_low, factor_high, size=n_stragglers)
    events: List[Tuple[float, int, float]] = [
        (float(t), int(m), float(f))
        for t, m, f in zip(starts, servers, factors)
    ]
    if recover:
        dur = duration_frac * horizon
        events.extend(
            (float(min(t + dur, horizon)), int(m), 1.0)
            for t, m in zip(starts, servers)
        )
    events.sort()
    return events


def elastic_events(
    servers: Sequence[int],
    join_at: Optional[float],
    leave_at: float = 0.0,
    drain_timeout: float = 0.0,
) -> List[ClusterEvent]:
    """Elastic-capacity timeline: ``servers`` leave at ``leave_at`` and
    (unless ``join_at`` is None — permanently lost capacity) rejoin at
    ``join_at``.  ``leave_at=0.0`` with the default immediate
    ``drain_timeout=0.0`` expresses "absent from the start" — the
    ``ClusterSpec`` stays the full universe of server slots and the
    scenario carves the live subset out of it (see scenario.py).
    """
    if join_at is not None and join_at <= leave_at:
        # equality is rejected too: the canonical (t, server, kind)
        # order applies joins *before* leaves at one instant, so a
        # same-time pair would leave the servers down for good
        raise ValueError(
            f"join_at {join_at} precedes or coincides with "
            f"leave_at {leave_at}"
        )
    events: List[ClusterEvent] = [
        ServerLeave(float(leave_at), int(m), drain_timeout=drain_timeout)
        for m in servers
    ]
    if join_at is not None:
        events.extend(ServerJoin(float(join_at), int(m)) for m in servers)
    return events


def straggler_scenario(
    cfg: "TraceConfig",
    cluster: Optional[ClusterSpec] = None,
    n_stragglers: int = 4,
    event_seed: int = 0,
    name: str = "",
    **straggler_kw,
) -> Scenario:
    """Sample a full degradation scenario: trace + mixed cluster +
    ``straggler_events`` timeline, bundled as one serializable
    :class:`Scenario` (``cluster`` defaults to ``mixed_cluster_spec``
    seeded like the trace, so one seed pins everything)."""
    if cluster is None:
        cluster = mixed_cluster_spec(seed=cfg.seed)
    events = [
        Degradation(t, m, factor=f)
        for t, m, f in straggler_events(
            cluster.num_servers, cfg.horizon, n_stragglers=n_stragglers,
            seed=event_seed, **straggler_kw,
        )
    ]
    return Scenario(
        jobs=tuple(generate_trace(cfg)), cluster=cluster,
        events=tuple(events), name=name or f"straggler-{cfg.seed}",
    )


def elastic_scenario(
    cfg: "TraceConfig",
    cluster: Optional[ClusterSpec] = None,
    elastic_servers: Sequence[int] = (0, 1, 2, 3),
    join_frac: Optional[float] = 0.5,
    drain_timeout: float = 0.0,
    name: str = "",
) -> Scenario:
    """Sample an elastic-capacity scenario: ``elastic_servers`` are absent
    from the start and join at ``join_frac * cfg.horizon`` (None = never —
    the static-degraded baseline the recovered flow time is measured
    against in ``sched_scale --elastic``)."""
    if cluster is None:
        cluster = mixed_cluster_spec(seed=cfg.seed)
    join_at = None if join_frac is None else join_frac * cfg.horizon
    return Scenario(
        jobs=tuple(generate_trace(cfg)), cluster=cluster,
        events=tuple(
            elastic_events(
                elastic_servers, join_at, drain_timeout=drain_timeout
            )
        ),
        name=name or f"elastic-{cfg.seed}",
    )


@dataclass
class TraceConfig:
    n_jobs: int = 5000
    horizon: float = 60 * 24 * 3600.0  # two months, seconds
    single_gpu_frac: float = 0.7
    recur_zipf_a: float = 1.8  # group size tail exponent
    mean_iters: float = 400.0
    sigma_iters: float = 1.2  # log-normal sigma of group means
    early_kill_frac: float = 0.08  # jobs stopped early (uncertain n_i)
    # Fraction of groups whose re-submissions are internally *constant*
    # (users rerunning identical jobs — the dominant MLaaS pattern that
    # makes ~60 % of jobs exactly predictable, paper Fig. 4); the rest are
    # exploration groups with per-job variation.
    constant_group_frac: float = 0.55
    n_users: int = 120
    max_gpus_per_job: Optional[int] = None  # clamp g_i (<= cluster G)
    seed: int = 0
    # Arrival burstiness (MLaaS-like): group submissions are clustered --
    # users submit several exploratory configurations in a session and
    # resubmit after observing results.
    burst_frac: float = 0.7  # fraction of a group's jobs in its session
    session_spread: float = 1800.0  # intra-session spacing scale (s)


def generate_trace(cfg: TraceConfig) -> List[JobSpec]:
    """Generate the trace with NumPy-vectorized draws.

    All random quantities are drawn as arrays (group sizes in chunks; one
    flat array per per-group / per-job attribute, with segmented cumsums
    for the intra-session spacings), so generating 10^5+ jobs takes
    seconds — the only per-job Python work left is ``make_job``.
    """
    rng = np.random.default_rng(cfg.seed)

    # --- groups with Zipf-ish sizes until we cover n_jobs -----------------
    sizes_np = np.empty(0, dtype=np.int64)
    while int(sizes_np.sum()) < cfg.n_jobs:
        chunk = np.minimum(
            rng.zipf(cfg.recur_zipf_a, size=max(256, cfg.n_jobs // 8)), 200
        )
        sizes_np = np.concatenate([sizes_np, chunk])
    # cut at the first group crossing n_jobs, trim its overshoot
    cum = np.cumsum(sizes_np)
    n_groups = int(np.searchsorted(cum, cfg.n_jobs)) + 1
    sizes_np = sizes_np[:n_groups].copy()
    overshoot = int(cum[n_groups - 1]) - cfg.n_jobs
    if overshoot > 0:
        sizes_np[-1] -= overshoot
        if sizes_np[-1] <= 0:
            sizes_np = sizes_np[:-1]
    sizes = sizes_np.tolist()
    G = len(sizes)
    N = int(sizes_np.sum())
    starts = np.concatenate([[0], np.cumsum(sizes_np)[:-1]])
    group_of = np.repeat(np.arange(G), sizes_np)

    # --- group-level attributes (vectorized) ------------------------------
    model_names = list(PAPER_MODELS)
    single = rng.random(G) < cfg.single_gpu_frac
    single_model_idx = rng.integers(0, len(SINGLE_GPU_MODELS), size=G)
    multi_model_idx = rng.integers(0, len(model_names), size=G)
    config_u = rng.random(G)  # uniform pick within the valid config list
    user_ids = rng.integers(0, cfg.n_users, size=G)
    rar = rng.random(G) < 0.5
    group_means = np.exp(
        rng.normal(np.log(cfg.mean_iters), cfg.sigma_iters, size=G)
    )
    constant_group = rng.random(G) < cfg.constant_group_frac

    # valid multi-GPU config indices per model (respecting the clamp)
    multi_configs: dict = {}
    for name in model_names:
        profile = PAPER_MODELS[name]
        multi = [i for i, c in enumerate(profile.configs) if sum(c) > 1]
        if cfg.max_gpus_per_job is not None:
            ok = [
                i
                for i in multi
                if sum(profile.configs[i]) <= cfg.max_gpus_per_job
            ]
            multi_configs[name] = ok if ok else [0]
        else:
            multi_configs[name] = multi

    # --- arrivals ----------------------------------------------------------
    # Bursty, diurnal: a group's submissions cluster into a "session"
    # (hyper-parameter exploration burst) anchored at a business-hours
    # start; the rest spread over the horizon.  Sessions are *clamped* to
    # the horizon — wrapping them (mod horizon) would let a group's later
    # submissions arrive before its anchor, breaking the "recurring jobs
    # are observed before being predicted" premise.
    day = 24 * 3600.0
    n_day = max(1, int(cfg.horizon // day))
    anchors = (
        rng.integers(0, n_day, size=G) * day + rng.uniform(8, 20, size=G) * 3600.0
    )
    # The business-hours draw can land past a sub-day horizon (and the last
    # day's evening can overhang a multi-day one); fold the anchor back so
    # every session *starts* inside the horizon and only its tail truncates.
    anchors %= cfg.horizon
    in_session = rng.random(N) < cfg.burst_frac
    n_sess_total = int(in_session.sum())
    gaps = rng.exponential(cfg.session_spread, size=n_sess_total)
    # segmented cumsum of the session gaps (grouped by each job's group)
    sess_group = group_of[in_session]
    gap_cum = np.cumsum(gaps)
    seg_start = np.concatenate(
        [[0], np.searchsorted(sess_group, np.arange(1, G))]
    )
    base = np.zeros(G)
    has_sess = seg_start < n_sess_total
    first = seg_start[has_sess]
    base[has_sess] = gap_cum[first] - gaps[first]
    sess_times = anchors[sess_group] + (gap_cum - base[sess_group])
    arrivals = np.empty(N)
    arrivals[in_session] = np.minimum(sess_times, cfg.horizon)
    arrivals[~in_session] = rng.uniform(0, cfg.horizon, size=N - n_sess_total)

    # --- iteration counts ---------------------------------------------------
    factors = np.where(
        constant_group[group_of],
        1.0,
        rng.uniform(0.85, 1.15, size=N),  # exploration variation
    )
    killed = rng.random(N) < cfg.early_kill_frac
    factors = np.where(
        killed, factors * rng.uniform(0.05, 0.5, size=N), factors
    )
    n_iters = np.maximum(
        1, np.round(group_means[group_of] * factors)
    ).astype(np.int64)

    # --- materialize JobSpecs ----------------------------------------------
    jobs: List[JobSpec] = []
    job_id = 0
    for gid in range(G):
        size = sizes[gid]
        lo = int(starts[gid])
        if single[gid]:
            model = SINGLE_GPU_MODELS[int(single_model_idx[gid])]
            config_idx = 0  # config (1,) is first for single-GPU models
        else:
            model = model_names[int(multi_model_idx[gid])]
            ok = multi_configs[model]
            config_idx = ok[int(config_u[gid] * len(ok))]
        user_id = int(user_ids[gid])
        allreduce = RAR if rar[gid] else TAR
        order = np.argsort(arrivals[lo : lo + size], kind="stable")
        for k in order:
            i = lo + int(k)
            jobs.append(
                make_job(
                    job_id=job_id,
                    model=model,
                    config_idx=config_idx,
                    n_iters=int(n_iters[i]),
                    arrival=float(arrivals[i]),
                    group_id=gid,
                    user_id=user_id,
                    allreduce=allreduce,
                )
            )
            job_id += 1

    jobs.sort(key=lambda j: (j.arrival, j.job_id))
    for job in jobs:
        # materialize the cached derived attributes (g, interned config id)
        # at generation time, off the schedulers' hot path
        job.g
        job.config_key
    return jobs


@dataclass
class StreamTraceConfig:
    """Recipe for :func:`stream_trace` — the bounded-memory generator.

    Unlike :class:`TraceConfig` (which materializes, globally sorts, and
    segments sessions — all O(n_jobs)), the streaming recipe draws
    arrivals as a single Poisson process (exponential gaps, cumulative
    sum carried across chunks — already time-ordered, no sort) against a
    *bounded* recurrence pool of ``n_groups`` groups with Zipf-ranked
    popularity.  Everything resident is O(n_groups + chunk), so a 10^6+
    job trace streams through ``simulate`` without ever existing as a
    list.

    ``arrival_rate`` is jobs/second.  Keep the offered load (rate x mean
    GPU-seconds per job) under the cluster's GPU capacity or the live
    queue — and with it the simulator's working set — grows without
    bound; the defaults target roughly half utilization of the
    64-server / 512-GPU ``sched_scale --stream`` cluster (saturation
    sets in just past 6.5 jobs/s there).
    """

    n_jobs: int = 1_000_000
    arrival_rate: float = 6.0  # Poisson arrivals per second
    single_gpu_frac: float = 0.9
    n_groups: int = 4096  # bounded recurrence pool
    group_zipf_a: float = 1.3  # popularity tail over group ranks
    mean_iters: float = 40.0
    sigma_iters: float = 1.0  # log-normal sigma of group means
    early_kill_frac: float = 0.08
    constant_group_frac: float = 0.55
    n_users: int = 500
    max_gpus_per_job: Optional[int] = 8  # clamp g_i (<= cluster G)
    seed: int = 0
    chunk: int = 8192  # vectorized draw granularity (resident bound)


def stream_trace(cfg: StreamTraceConfig) -> Iterator[JobSpec]:
    """Yield ``cfg.n_jobs`` time-ordered jobs in O(n_groups + chunk) memory.

    Group attributes (model, config, user, allreduce, iteration-count
    mean, constant-vs-exploration) are drawn once for the bounded pool;
    per-chunk draws pick a group by Zipf popularity and sample the
    job-level variation (exploration factor, early kills).  Stage tuples
    are built once per (model, config) and shared across all their jobs.
    Deterministic per seed.
    """
    if cfg.n_jobs < 0:
        raise ValueError(f"n_jobs must be >= 0, got {cfg.n_jobs}")
    if cfg.arrival_rate <= 0.0:
        raise ValueError(f"arrival_rate must be > 0, got {cfg.arrival_rate}")
    rng = np.random.default_rng(cfg.seed)
    G = cfg.n_groups
    model_names = list(PAPER_MODELS)

    # --- bounded group pool (one vectorized draw, O(n_groups)) ------------
    single = rng.random(G) < cfg.single_gpu_frac
    single_model_idx = rng.integers(0, len(SINGLE_GPU_MODELS), size=G)
    multi_model_idx = rng.integers(0, len(model_names), size=G)
    config_u = rng.random(G)
    user_ids = rng.integers(0, cfg.n_users, size=G)
    rar = rng.random(G) < 0.5
    group_means = np.exp(
        rng.normal(np.log(cfg.mean_iters), cfg.sigma_iters, size=G)
    )
    constant_group = rng.random(G) < cfg.constant_group_frac

    # valid multi-GPU config indices per model (respecting the clamp);
    # mirrors generate_trace
    multi_configs: Dict[str, List[int]] = {}
    for name in model_names:
        profile = PAPER_MODELS[name]
        multi = [i for i, c in enumerate(profile.configs) if sum(c) > 1]
        if cfg.max_gpus_per_job is not None:
            ok = [
                i
                for i in multi
                if sum(profile.configs[i]) <= cfg.max_gpus_per_job
            ]
            multi_configs[name] = ok if ok else [0]
        else:
            multi_configs[name] = multi

    # resolve each group to (model, stages, allreduce); stage tuples are
    # memoized per (model, config_idx) and shared by every job instance
    stage_cache: Dict[Tuple[str, int], Tuple[StageSpec, ...]] = {}
    group_model: List[str] = []
    group_stages: List[Tuple[StageSpec, ...]] = []
    group_allreduce: List[str] = []
    for gid in range(G):
        if single[gid]:
            model = SINGLE_GPU_MODELS[int(single_model_idx[gid])]
            config_idx = 0  # config (1,) is first for single-GPU models
        else:
            model = model_names[int(multi_model_idx[gid])]
            ok = multi_configs[model]
            config_idx = ok[int(config_u[gid] * len(ok))]
        key = (model, config_idx)
        stages = stage_cache.get(key)
        if stages is None:
            profile = PAPER_MODELS[model]
            stages = build_stages(
                profile, profile.configs[config_idx % len(profile.configs)]
            )
            stage_cache[key] = stages
        group_model.append(model)
        group_stages.append(stages)
        group_allreduce.append(RAR if rar[gid] else TAR)

    # Zipf-ranked group popularity (heavy-tailed recurrence without an
    # unbounded group universe)
    pop = np.arange(1, G + 1, dtype=np.float64) ** -cfg.group_zipf_a
    pop /= pop.sum()

    # --- chunked job stream ------------------------------------------------
    t = 0.0
    job_id = 0
    remaining = cfg.n_jobs
    while remaining > 0:
        m = min(cfg.chunk, remaining)
        times = t + np.cumsum(
            rng.exponential(1.0 / cfg.arrival_rate, size=m)
        )
        t = float(times[-1])
        gidx = rng.choice(G, size=m, p=pop)
        factors = np.where(
            constant_group[gidx],
            1.0,
            rng.uniform(0.85, 1.15, size=m),  # exploration variation
        )
        killed = rng.random(m) < cfg.early_kill_frac
        factors = np.where(
            killed, factors * rng.uniform(0.05, 0.5, size=m), factors
        )
        n_iters = np.maximum(
            1, np.round(group_means[gidx] * factors)
        ).astype(np.int64)
        for i in range(m):
            gid = int(gidx[i])
            yield JobSpec(
                job_id=job_id,
                stages=group_stages[gid],
                n_iters=int(n_iters[i]),
                arrival=float(times[i]),
                group_id=gid,
                user_id=int(user_ids[gid]),
                allreduce=group_allreduce[gid],
                model_name=group_model[gid],
            )
            job_id += 1
        remaining -= m


def stream_trace_source(cfg: StreamTraceConfig) -> IterJobs:
    """The streaming trace as a replayable ``Scenario.jobs`` source."""
    return IterJobs(lambda: stream_trace(cfg), name=f"stream-{cfg.seed}")


def trace_stats(jobs: Sequence[JobSpec]) -> dict:
    from collections import Counter

    group_counts = Counter(j.group_id for j in jobs)
    recurrent = sum(
        1 for j in jobs if group_counts[j.group_id] >= 5
    )
    single = sum(1 for j in jobs if j.g == 1)
    return {
        "n_jobs": len(jobs),
        "frac_recurrent_ge5": recurrent / max(len(jobs), 1),
        "frac_single_gpu": single / max(len(jobs), 1),
        "n_groups": len(group_counts),
        "max_g": max(j.g for j in jobs),
    }
