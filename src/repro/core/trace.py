"""Synthetic MLaaS-like workload trace (substitute for [6], see DESIGN.md).

The two-month Alibaba MLaaS trace is not redistributable/available offline.
This generator reproduces its published statistics that matter to the
scheduling problem:

* recurrence: ~65 % of jobs belong to groups submitted >= 5 times; group
  sizes are Zipf-heavy-tailed;
* >70 % single-GPU jobs by default (``single_gpu_frac``);
* heavy-tailed iteration counts per group (log-normal group mean), with a
  fraction of early-terminated runs (user kills / failed exploration), which
  is what makes iteration counts *uncertain* and prediction non-trivial;
* Poisson arrivals with diurnal modulation over the horizon.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from .job import JobSpec, RAR, TAR
from .profiles import PAPER_MODELS, SINGLE_GPU_MODELS, make_job


@dataclass
class TraceConfig:
    n_jobs: int = 5000
    horizon: float = 60 * 24 * 3600.0  # two months, seconds
    single_gpu_frac: float = 0.7
    recur_zipf_a: float = 1.8  # group size tail exponent
    mean_iters: float = 400.0
    sigma_iters: float = 1.2  # log-normal sigma of group means
    early_kill_frac: float = 0.08  # jobs stopped early (uncertain n_i)
    # Fraction of groups whose re-submissions are internally *constant*
    # (users rerunning identical jobs — the dominant MLaaS pattern that
    # makes ~60 % of jobs exactly predictable, paper Fig. 4); the rest are
    # exploration groups with per-job variation.
    constant_group_frac: float = 0.55
    n_users: int = 120
    max_gpus_per_job: Optional[int] = None  # clamp g_i (<= cluster G)
    seed: int = 0
    # Arrival burstiness (MLaaS-like): group submissions are clustered --
    # users submit several exploratory configurations in a session and
    # resubmit after observing results.
    burst_frac: float = 0.7  # fraction of a group's jobs in its session
    session_spread: float = 1800.0  # intra-session spacing scale (s)


def generate_trace(cfg: TraceConfig) -> List[JobSpec]:
    rng = np.random.default_rng(cfg.seed)

    # --- groups with Zipf-ish sizes until we cover n_jobs -----------------
    group_sizes: List[int] = []
    while sum(group_sizes) < cfg.n_jobs:
        size = int(min(rng.zipf(cfg.recur_zipf_a), 200))
        group_sizes.append(size)
    # trim overshoot
    overshoot = sum(group_sizes) - cfg.n_jobs
    if overshoot > 0:
        group_sizes[-1] -= overshoot
        if group_sizes[-1] <= 0:
            group_sizes.pop()

    model_names = list(PAPER_MODELS)
    jobs: List[JobSpec] = []
    job_id = 0
    for gid, size in enumerate(group_sizes):
        single = rng.random() < cfg.single_gpu_frac
        if single:
            model = str(rng.choice(SINGLE_GPU_MODELS))
            config_idx = 0  # config (1,) is first for single-GPU models
        else:
            model = str(rng.choice(model_names))
            profile = PAPER_MODELS[model]
            multi = [
                i for i, c in enumerate(profile.configs) if sum(c) > 1
            ]
            config_idx = int(rng.choice(multi))
            if cfg.max_gpus_per_job is not None:
                ok = [
                    i
                    for i in multi
                    if sum(profile.configs[i]) <= cfg.max_gpus_per_job
                ]
                config_idx = int(rng.choice(ok)) if ok else 0
        user_id = int(rng.integers(0, cfg.n_users))
        allreduce = RAR if rng.random() < 0.5 else TAR
        group_mean = float(
            np.exp(rng.normal(np.log(cfg.mean_iters), cfg.sigma_iters))
        )

        # Bursty, diurnal arrivals.  A group's submissions cluster into a
        # "session" (hyper-parameter exploration burst) anchored at a
        # business-hours start; the rest spread over the horizon.
        day = 24 * 3600.0
        n_day = max(1, int(cfg.horizon // day))
        anchor_day = rng.integers(0, n_day)
        anchor = anchor_day * day + rng.uniform(8, 20) * 3600.0
        in_session = rng.random(size) < cfg.burst_frac
        n_sess = int(in_session.sum())
        sess = anchor + np.cumsum(
            rng.exponential(cfg.session_spread, size=n_sess)
        )
        rest = rng.uniform(0, cfg.horizon, size=size - n_sess)
        arrivals = np.sort(np.concatenate([sess, rest]) % cfg.horizon)

        constant_group = rng.random() < cfg.constant_group_frac
        for arr in arrivals:
            if constant_group:
                n = group_mean  # identical re-submissions
            else:
                n = group_mean * rng.uniform(0.85, 1.15)  # exploration
            if rng.random() < cfg.early_kill_frac:
                n *= rng.uniform(0.05, 0.5)  # early termination
            n_iters = max(1, int(round(n)))
            jobs.append(
                make_job(
                    job_id=job_id,
                    model=model,
                    config_idx=config_idx,
                    n_iters=n_iters,
                    arrival=float(arr),
                    group_id=gid,
                    user_id=user_id,
                    allreduce=allreduce,
                )
            )
            job_id += 1

    jobs.sort(key=lambda j: (j.arrival, j.job_id))
    return jobs


def trace_stats(jobs: Sequence[JobSpec]) -> dict:
    from collections import Counter

    group_counts = Counter(j.group_id for j in jobs)
    recurrent = sum(
        1 for j in jobs if group_counts[j.group_id] >= 5
    )
    single = sum(1 for j in jobs if j.g == 1)
    return {
        "n_jobs": len(jobs),
        "frac_recurrent_ge5": recurrent / max(len(jobs), 1),
        "frac_single_gpu": single / max(len(jobs), 1),
        "n_groups": len(group_counts),
        "max_g": max(j.g for j in jobs),
    }
