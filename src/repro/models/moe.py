"""Mixture-of-Experts FF layer: top-k router + capacity-bounded dispatch.

Dispatch is scatter/gather-based (not dense one-hot einsum) so compiled
FLOPs track *active* parameters: tokens are routed to ``[E, C, D]`` slabs
(capacity ``C = T * top_k / E * capacity_factor``), experts run as grouped
einsums, and outputs are combined with the router probabilities.  Tokens
over capacity are dropped (standard Switch-style), which the auxiliary
load-balance loss discourages.

Sharding: the expert axis ``E`` is sharded over the mesh `model` axis
(expert parallelism); the scatter/gather induce the token all-to-all.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = Dict[str, Any]


def init_moe(key: jax.Array, cfg: ArchConfig) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k1, (D, E)) * D**-0.5).astype(jnp.float32),
        "w_up": (jax.random.normal(k2, (E, D, F)) * D**-0.5).astype(dt),
        "w_gate": (jax.random.normal(k3, (E, D, F)) * D**-0.5).astype(dt),
        "w_down": (jax.random.normal(k4, (E, F, D)) * F**-0.5).astype(dt),
    }


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = int(round(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(
    p: Params, cfg: ArchConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B,S,D], aux load-balance loss scalar)."""
    from ..parallel import opt_flags

    if opt_flags.get("moe_a2a") and opt_flags.get("mesh") is not None:
        return apply_moe_shard_map(
            p, cfg, x, opt_flags.get("mesh"), opt_flags.get("batch_axes")
        )
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [T,E]
    top_p, top_i = jax.lax.top_k(probs, K)  # [T,K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (token fraction_e * mean prob_e).
    frac = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    # Position of each (token, slot) within its expert, row-major priority.
    flat_e = top_i.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*K, E]
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # [T*K, E]
    pos_in_e = jnp.sum(pos, axis=-1)  # [T*K]
    keep = pos_in_e < C

    # Dispatch tokens into [E, C, D] slabs (dropped tokens -> scattered to a
    # scratch row C which is sliced off).
    slot = jnp.where(keep, pos_in_e, C)
    buf = jnp.zeros((E, C + 1, D), dtype=x.dtype)
    token_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[flat_e, slot].add(xt[token_idx])
    buf = buf[:, :C, :]  # [E,C,D]

    from ..parallel import opt_flags

    if opt_flags.get("moe_ep"):
        # §Perf: pin the dispatch slabs to expert parallelism so the
        # scatter lowers to an all-to-all instead of gathering tokens.
        from jax.sharding import PartitionSpec as P_

        buf = jax.lax.with_sharding_constraint(buf, P_("model", None, None))

    # Expert computation (grouped SwiGLU).
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E,C,D]
    if opt_flags.get("moe_ep"):
        from jax.sharding import PartitionSpec as P_

        out = jnp.asarray(
            jax.lax.with_sharding_constraint(out, P_("model", None, None))
        )

    # Combine: gather each kept (token, slot) expert output, weight by prob.
    out_pad = jnp.concatenate(
        [out, jnp.zeros((E, 1, D), out.dtype)], axis=1
    )  # row C = zeros for dropped tokens
    gathered = out_pad[flat_e, slot]  # [T*K, D]
    weights = (top_p.reshape(T * K) * keep).astype(gathered.dtype)
    y = jnp.zeros((T, D), dtype=gathered.dtype)
    y = y.at[token_idx].add(gathered * weights[:, None])
    return y.reshape(B, S, D), aux


# --------------------------------------------------------------------------
# §Perf iteration: shard_map local dispatch (expert-parallel, no global
# cumsum / scatter all-reduce)
# --------------------------------------------------------------------------


def apply_moe_shard_map(
    p: Params, cfg: ArchConfig, x: jnp.ndarray, mesh, batch_axes
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map.

    Tokens stay data-sharded and replicated over `model`; each model rank
    routes every local token, keeps only slots destined to its own
    ``E_loc = E/TP`` experts, computes them from a *local* capacity buffer
    (local cumsum — no cross-shard prefix sum), and the combine is one
    ``psum`` of the [T_loc, D] output over `model`.  Per-layer comm drops
    from an [E, C, D] buffer all-reduce + [T*K, E] global cumsum to a
    single activation-sized psum.
    """
    from jax.sharding import PartitionSpec as P_
    from jax.experimental.shard_map import shard_map

    E, K, D = cfg.n_experts, cfg.top_k, cfg.d_model
    model_size = mesh.shape["model"]
    assert E % model_size == 0
    E_loc = E // model_size
    b_spec = P_(batch_axes, None, None)

    def local_moe(xb, router, w_up, w_gate, w_down):
        B_loc, S, _ = xb.shape
        T = B_loc * S
        C = moe_capacity(cfg, T)
        xt = xb.reshape(T, D)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        frac = (
            jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
            / (T * K)
        )
        aux = E * jnp.sum(frac * probs.mean(axis=0))
        aux = jax.lax.pmean(aux, "model")

        rank = jax.lax.axis_index("model")
        flat_e = top_i.reshape(T * K)
        local_e = flat_e - rank * E_loc
        mine = (local_e >= 0) & (local_e < E_loc)
        le = jnp.where(mine, local_e, 0)
        onehot = jax.nn.one_hot(le, E_loc, dtype=jnp.int32) * mine[:, None]
        pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
        pos_in_e = jnp.sum(pos, axis=-1)
        keep = mine & (pos_in_e < C)
        slot = jnp.where(keep, pos_in_e, C)
        token_idx = jnp.repeat(jnp.arange(T), K)
        buf = jnp.zeros((E_loc, C + 1, D), dtype=xb.dtype)
        buf = buf.at[le, slot].add(xt[token_idx] * keep[:, None].astype(xb.dtype))
        buf = buf[:, :C, :]

        up = jnp.einsum("ecd,edf->ecf", buf, w_up)
        gate = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        hh = jax.nn.silu(gate) * up
        out = jnp.einsum("ecf,efd->ecd", hh, w_down)
        out_pad = jnp.concatenate(
            [out, jnp.zeros((E_loc, 1, D), out.dtype)], axis=1
        )
        gathered = out_pad[le, slot]
        w = (top_p.reshape(T * K) * keep).astype(gathered.dtype)
        y = jnp.zeros((T, D), dtype=gathered.dtype)
        y = y.at[token_idx].add(gathered * w[:, None])
        y = jax.lax.psum(y, "model")
        return y.reshape(B_loc, S, D), aux

    y, aux = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            b_spec,
            P_(None, None),
            P_("model", None, None),
            P_("model", None, None),
            P_("model", None, None),
        ),
        out_specs=(b_spec, P_()),
        check_rep=False,
    )(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])
    return y, aux
