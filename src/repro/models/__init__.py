"""Pure-JAX model substrate."""
from .model import Model, active_params, n_params  # noqa: F401
