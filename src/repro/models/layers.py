"""Core layers: RMSNorm, RoPE, GQA attention (qk-norm / sliding-window /
streaming), gated & plain MLP.  Pure JAX, pytree params.

Conventions:
  x            [B, S, D]
  q            [B, S, H, K]      (K = head_dim)
  k, v         [B, T, G, K]      (G = kv heads)
  attn scores  [B, G, Hg, S, T]  (Hg = H // G)

All weights live in ``cfg.dtype`` (bf16 on TPU); softmax, norms and losses
accumulate in fp32.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = Dict[str, Any]

_NEG_INF = -1e30
# Above this query length the attention uses the q-chunked streaming path
# so the S x T score buffer stays bounded (flash-attention-style, pure XLA).
STREAM_THRESHOLD = 8192
STREAM_CHUNK = 1024


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Rotary embedding. x: [B,S,H,K]; positions: [S] or [B,S]."""
    K = x.shape[-1]
    half = K // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        angles = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        angles = angles[:, :, None, :]  # [1,S,1,half]
    else:
        angles = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rx1, rx2, x[..., 2 * half :]], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ArchConfig) -> Params:
    D, H, G, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_in = D ** -0.5
    scale_out = (H * K) ** -0.5
    p: Params = {
        "wq": (jax.random.normal(k1, (D, H, K)) * scale_in).astype(dt),
        "wk": (jax.random.normal(k2, (D, G, K)) * scale_in).astype(dt),
        "wv": (jax.random.normal(k3, (D, G, K)) * scale_in).astype(dt),
        "wo": (jax.random.normal(k4, (H, K, D)) * scale_out).astype(dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((K,), dtype=jnp.float32)
        p["k_norm"] = jnp.ones((K,), dtype=jnp.float32)
    return p


def _mask_bias(
    q_pos: jnp.ndarray,  # [Sq] shared, or [B,Sq] per-row
    kv_pos: jnp.ndarray,  # [T]
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """Additive mask (0 = attend, -inf = blocked): [Sq, T] for shared
    positions, [B, Sq, T] when each batch row queries its own position
    (batched serving decode)."""
    qp = q_pos[..., :, None]  # [Sq,1] or [B,Sq,1]
    # ring-buffer slots not yet written carry -1
    ok = jnp.broadcast_to(kv_pos >= 0, qp.shape[:-1] + kv_pos.shape)
    if causal:
        ok = ok & (kv_pos <= qp)
    if window is not None:
        ok = ok & (kv_pos > qp - window)
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def _attend_dense(
    q: jnp.ndarray,  # [B,Sq,G,Hg,K]
    k: jnp.ndarray,  # [B,T,G,K]
    v: jnp.ndarray,
    bias: jnp.ndarray,  # [Sq,T] or [B,Sq,T]
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5
    if bias.ndim == 2:
        bias = bias[None]
    scores = jnp.einsum(
        "bsghk,btgk->bghst", q, k, preferred_element_type=jnp.float32
    )
    scores = scores * scale + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bghst,btgk->bsghk",
        probs.astype(v.dtype),
        v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(v.dtype)


def multi_head_attention(
    q: jnp.ndarray,  # [B,Sq,H,K]
    k: jnp.ndarray,  # [B,T,G,K]
    v: jnp.ndarray,  # [B,T,G,K]
    q_pos: jnp.ndarray,  # [Sq] (or [B,Sq] per-row) query positions
    kv_pos: jnp.ndarray,  # [T]  absolute positions of the keys (-1 = empty)
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """GQA attention with optional causality/sliding window.

    Long query sequences are processed in chunks with lax.scan so the score
    buffer is O(chunk x T) rather than O(S x T) — this is the pure-XLA
    analogue of the Pallas flash-attention kernel (kernels/flash_attention).
    """
    B, Sq, H, K = q.shape
    G = k.shape[2]
    qg = q.reshape(B, Sq, G, H // G, K)

    if Sq <= STREAM_THRESHOLD:
        bias = _mask_bias(q_pos, kv_pos, causal, window)
        out = _attend_dense(qg, k, v, bias)
        return out.reshape(B, Sq, H, K)

    assert q_pos.ndim == 1, "streaming path is prefill-only (shared q_pos)"
    n_chunks = Sq // STREAM_CHUNK
    assert Sq % STREAM_CHUNK == 0, "query length must divide STREAM_CHUNK"
    qg_c = qg.reshape(B, n_chunks, STREAM_CHUNK, G, H // G, K)
    qpos_c = q_pos.reshape(n_chunks, STREAM_CHUNK)

    def body(_, inp):
        qc, qp = inp
        bias = _mask_bias(qp, kv_pos, causal, window)
        return None, _attend_dense(qc, k, v, bias)

    _, out = jax.lax.scan(
        body, None, (jnp.moveaxis(qg_c, 1, 0), qpos_c)
    )
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, K)
    return out


def apply_attention(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [B,S,D]
    q_pos: jnp.ndarray,  # [S] absolute positions
    cache: Optional[Params] = None,
    cache_index: Optional[jnp.ndarray] = None,
    self_attend: bool = True,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Attention sublayer.

    ``cache`` given + ``self_attend``  : prefill — attend over the local
        k/v (streaming path for long S) and write them into the cache.
    ``cache`` given + not self_attend  : decode — write the new k/v at
        ``cache_index`` (ring slot for SWA) and attend over the buffer.
    no cache                           : training — plain self-attention.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, q_pos, cfg.rope_theta)

    window = cfg.sliding_window
    new_cache = None
    if cache is not None:
        W = cache["k"].shape[1]  # buffer length (ring if SWA)
        S = k.shape[1]
        if cache_index is not None and cache_index.ndim == 1:
            # Per-row decode (batched serving: rows at different depths in
            # one batch).  Each row writes its single new k/v at its own
            # slot; the shared ``pos`` leaf stays consistent because with
            # no sliding window slot == absolute position for every row,
            # and different rows writing the same slot write the same
            # position value.  Ring wrap breaks that invariant, so the
            # serving engine rejects SWA configs up front.
            assert window is None, (
                "per-row cache positions require sliding_window=None"
            )
            slots = cache_index.astype(jnp.int32)  # [B]
            bidx = jnp.arange(k.shape[0])
            ck = cache["k"].at[bidx, slots].set(
                k[:, 0].astype(cache["k"].dtype)
            )
            cv = cache["v"].at[bidx, slots].set(
                v[:, 0].astype(cache["v"].dtype)
            )
            cpos = cache["pos"].at[0, slots].set(
                q_pos[:, 0].astype(jnp.int32)
            )
        elif S >= W:
            # Prefill overflowing a ring buffer: keep the last W entries.
            # Ring-slot invariant (slot == pos % W) needs S % W == 0.
            assert S % W == 0, "SWA prefill length must be a multiple of W"
            ck = k[:, -W:].astype(cache["k"].dtype)
            cv = v[:, -W:].astype(cache["v"].dtype)
            cpos = q_pos[-W:].astype(jnp.int32)[None, :]
        else:
            slot = (
                cache_index % W
                if window is not None
                else cache_index
            )
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
            )
            cpos = jax.lax.dynamic_update_slice(
                cache["pos"], q_pos.astype(jnp.int32)[None, :], (0, slot)
            )
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    if cache is None or self_attend:
        out = multi_head_attention(q, k, v, q_pos, q_pos, cfg.causal, window)
    else:
        out = multi_head_attention(
            q, new_cache["k"], new_cache["v"], q_pos, new_cache["pos"][0],
            cfg.causal, window,
        )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


def init_attn_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype
) -> Params:
    W = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    G, K = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, W, G, K), dtype=dtype),
        "v": jnp.zeros((batch, W, G, K), dtype=dtype),
        # -1 marks unwritten slots; kept 2-D [1, W] so every cache leaf has
        # a leading batch-like axis (simplifies sharding rules).
        "pos": -jnp.ones((1, W), dtype=jnp.int32),
    }


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: ArchConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {
        "w_up": (jax.random.normal(k1, (D, F)) * D**-0.5).astype(dt),
        "w_down": (jax.random.normal(k2, (F, D)) * F**-0.5).astype(dt),
    }
    if cfg.mlp_gated:
        p["w_gate"] = (jax.random.normal(k3, (D, F)) * D**-0.5).astype(dt)
    return p


def apply_mlp(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.mlp_gated:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------


def init_embedding(key: jax.Array, cfg: ArchConfig) -> Params:
    V, D = cfg.padded_vocab, cfg.d_model
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p: Params = {"tokens": (jax.random.normal(k1, (V, D)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(k2, (D, V)) * D**-0.5).astype(dt)
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tokens"], tokens, axis=0)


def unembed(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tokens"])
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"])


def cross_entropy(
    logits: jnp.ndarray,  # [B,S,V]
    labels: jnp.ndarray,  # [B,S] (-1 = ignore)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom, denom
