"""Model facade: init / loss / prefill / decode for all six families.

Layers are stacked with ``jax.lax.scan`` over *scan blocks* (hybrid archs
scan over super-blocks of ``attn_period`` sub-layers), so full-size configs
(up to 398 B params) lower and compile quickly.  Per-block activation
rematerialization (``jax.checkpoint``) bounds training memory.

Batch dicts per family (see ``input_specs`` in launch/dryrun.py):
  dense/moe/ssm/hybrid : {"tokens": [B,S] i32, "labels": [B,S] i32}
  vlm   : {"tokens": [B,S_text], "labels": [B,S_text],
           "patch_embeds": [B,T_img,frontend_dim]}   (S_text+T_img = S)
  audio : {"frames": [B,S,frontend_dim], "labels": [B,S]}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L
from . import mamba as M
from . import moe as X

Params = Dict[str, Any]


def _init_sub(key: jax.Array, cfg: ArchConfig, mixer: str, ff: str) -> Params:
    ks = jax.random.split(key, 3)
    sub: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        sub["attn"] = L.init_attention(ks[0], cfg)
    else:
        sub["mamba"] = M.init_mamba(ks[0], cfg)
    if ff == "dense":
        sub["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        sub["mlp"] = L.init_mlp(ks[1], cfg)
    elif ff == "moe":
        sub["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        sub["moe"] = X.init_moe(ks[1], cfg)
    return sub


def _constrain_sub(h: jnp.ndarray) -> jnp.ndarray:
    """Per-sublayer residual constraint (§Perf iteration 3): re-sharding the
    residual stream after *every* sublayer keeps the TP psum at
    reduce-scatter volume instead of full all-reduce (Megatron-SP)."""
    from ..parallel import opt_flags

    if opt_flags.get("sp_sub") and h.ndim == 3 and h.shape[1] > 1:
        from jax.sharding import PartitionSpec as P_

        b = opt_flags.get("batch_axes")
        h = jax.lax.with_sharding_constraint(h, P_(b, "model", None))
    return h


def _apply_sub(
    sub: Params,
    cfg: ArchConfig,
    mixer: str,
    ff: str,
    h: jnp.ndarray,
    q_pos: jnp.ndarray,
    cache: Optional[Params],
    cache_index: Optional[jnp.ndarray],
    self_attend: bool,
    decode: bool,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    y = L.rms_norm(h, sub["ln1"])
    if mixer == "attn":
        y, new_cache = L.apply_attention(
            sub["attn"], cfg, y, q_pos,
            cache=cache, cache_index=cache_index, self_attend=self_attend,
        )
    else:
        if decode:
            y, new_cache = M.apply_mamba_decode(sub["mamba"], cfg, y, cache)
        else:
            y, new_cache = M.apply_mamba(
                sub["mamba"], cfg, y, return_cache=cache is not None
            )
    h = _constrain_sub(h + y)
    if ff != "none":
        y = L.rms_norm(h, sub["ln2"])
        if ff == "dense":
            y = L.apply_mlp(sub["mlp"], cfg, y)
        else:
            y, aux = X.apply_moe(sub["moe"], cfg, y)
        h = _constrain_sub(h + y)
    return h, new_cache, aux


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()
        self.n_blocks = cfg.n_scan_blocks
        # Optional PartitionSpec applied to the [B, S, D] residual stream at
        # block boundaries (Megatron-style sequence parallelism): sharding S
        # over the tensor-parallel axis cuts per-device activation traffic
        # by the TP degree.  Set by launch/dryrun.py --opt sp (see §Perf).
        self.act_spec = None

    def _constrain(self, h: jnp.ndarray) -> jnp.ndarray:
        if self.act_spec is not None and h.ndim == 3 and h.shape[1] > 1:
            h = jax.lax.with_sharding_constraint(h, self.act_spec)
        return h

    # ---- init ----------------------------------------------------------

    def _init_block(self, key: jax.Array) -> Params:
        ks = jax.random.split(key, len(self.kinds))
        return {
            f"sub{i}": _init_sub(ks[i], self.cfg, mixer, ff)
            for i, (mixer, ff) in enumerate(self.kinds)
        }

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_blocks, k_front = jax.random.split(key, 3)
        block_keys = jax.random.split(k_blocks, self.n_blocks)
        params: Params = {
            "embed": L.init_embedding(k_embed, cfg),
            "blocks": jax.vmap(self._init_block)(block_keys),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        dt = L.dtype_of(cfg)
        if cfg.family == "vlm":
            kf1, kf2 = jax.random.split(k_front)
            F, D = cfg.frontend_dim, cfg.d_model
            params["projector"] = {
                "w1": (jax.random.normal(kf1, (F, D)) * F**-0.5).astype(dt),
                "w2": (jax.random.normal(kf2, (D, D)) * D**-0.5).astype(dt),
            }
        elif cfg.family == "audio":
            F, D = cfg.frontend_dim, cfg.d_model
            params["frontend_proj"] = (
                jax.random.normal(k_front, (F, D)) * F**-0.5
            ).astype(dt)
        return params

    def param_specs(self, key: jax.Array | None = None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ---- backbone -------------------------------------------------------

    def _backbone(
        self,
        params: Params,
        h: jnp.ndarray,
        q_pos: jnp.ndarray,
        cache: Optional[Params] = None,
        cache_index: Optional[jnp.ndarray] = None,
        self_attend: bool = True,
        decode: bool = False,
        remat: bool = False,
    ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        cfg, kinds = self.cfg, self.kinds

        def block_fn(h, block_params, block_cache):
            new_cache = {} if block_cache is not None else None
            aux_total = jnp.zeros((), jnp.float32)
            for i, (mixer, ff) in enumerate(kinds):
                sub_cache = block_cache[f"sub{i}"] if block_cache else None
                h, nc, aux = _apply_sub(
                    block_params[f"sub{i}"], cfg, mixer, ff, h, q_pos,
                    sub_cache, cache_index, self_attend, decode,
                )
                aux_total = aux_total + aux
                if new_cache is not None:
                    new_cache[f"sub{i}"] = nc
            return h, new_cache, aux_total

        if remat:
            block_fn = jax.checkpoint(
                block_fn, policy=jax.checkpoint_policies.nothing_saveable
            )

        h = self._constrain(h)
        if cache is None:
            def body(carry, block_params):
                h, nc, aux = block_fn(carry, block_params, None)
                return self._constrain(h), aux
            h, auxs = jax.lax.scan(body, h, params["blocks"])
            return h, None, jnp.sum(auxs)

        def body(carry, xs):
            block_params, block_cache = xs
            h, new_cache, aux = block_fn(carry, block_params, block_cache)
            return self._constrain(h), (new_cache, aux)

        h, (new_cache, auxs) = jax.lax.scan(
            body, h, (params["blocks"], cache)
        )
        return h, new_cache, jnp.sum(auxs)

    # ---- family-specific embedding --------------------------------------

    def _embed_inputs(
        self, params: Params, batch: Dict[str, jnp.ndarray]
    ) -> Tuple[jnp.ndarray, int]:
        """Returns (h [B,S,D], n_prefix) where n_prefix = non-text prefix."""
        cfg = self.cfg
        if cfg.family == "audio":
            h = jnp.einsum(
                "bsf,fd->bsd",
                batch["frames"].astype(L.dtype_of(cfg)),
                params["frontend_proj"],
            )
            return h, 0
        tok = L.embed_tokens(params["embed"], batch["tokens"])
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(L.dtype_of(cfg))
            proj = params["projector"]
            img = jnp.einsum("btf,fd->btd", pe, proj["w1"])
            img = jnp.einsum("btd,de->bte", jax.nn.gelu(img), proj["w2"])
            h = jnp.concatenate([img, tok], axis=1)
            return h, img.shape[1]
        return tok, 0

    # ---- public API -------------------------------------------------------

    def loss(
        self, params: Params, batch: Dict[str, jnp.ndarray]
    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        h, n_prefix = self._embed_inputs(params, batch)
        S = h.shape[1]
        q_pos = jnp.arange(S, dtype=jnp.int32)
        h, _, aux = self._backbone(params, h, q_pos, remat=True)
        h = L.rms_norm(h, params["final_norm"])
        if n_prefix:
            h = h[:, n_prefix:, :]
        logits = L.unembed(params["embed"], cfg, h)
        xent, n_tok = L.cross_entropy(logits, batch["labels"])
        loss = xent + 0.01 * aux
        return loss, {"xent": xent, "aux": aux, "n_tokens": n_tok}

    def init_cache(
        self, batch: int, max_len: int, dtype=None
    ) -> Params:
        cfg = self.cfg
        dtype = dtype or L.dtype_of(cfg)

        def block_cache() -> Params:
            out: Params = {}
            for i, (mixer, _ff) in enumerate(self.kinds):
                if mixer == "attn":
                    out[f"sub{i}"] = L.init_attn_cache(cfg, batch, max_len, dtype)
                else:
                    out[f"sub{i}"] = M.init_mamba_cache(cfg, batch, dtype)
            return out

        one = block_cache()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.n_blocks,) + a.shape),
            one,
        )

    def prefill(
        self,
        params: Params,
        batch: Dict[str, jnp.ndarray],
        cache: Optional[Params] = None,
    ) -> Tuple[jnp.ndarray, Optional[Params]]:
        """Process the prompt; returns (last-token logits, filled cache)."""
        cfg = self.cfg
        h, _ = self._embed_inputs(params, batch)
        S = h.shape[1]
        q_pos = jnp.arange(S, dtype=jnp.int32)
        h, new_cache, _ = self._backbone(
            params, h, q_pos,
            cache=cache,
            cache_index=jnp.zeros((), jnp.int32),
            self_attend=True,
        )
        h = L.rms_norm(h, params["final_norm"])
        logits = L.unembed(params["embed"], cfg, h[:, -1:, :])
        return logits, new_cache

    def decode_step(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,  # [B,1]
        pos: jnp.ndarray,  # scalar i32 (shared) or [B] i32 (per-row)
    ) -> Tuple[jnp.ndarray, Params]:
        """One decode step.  ``pos`` is the absolute position of this
        token: a scalar when the whole batch decodes in lockstep, or a
        per-row ``[B]`` vector when rows sit at different depths (the
        batched serving engine's continuous-refill loop)."""
        cfg = self.cfg
        h = L.embed_tokens(params["embed"], tokens)
        pos = pos.astype(jnp.int32)
        q_pos = pos[None] if pos.ndim == 0 else pos[:, None]
        h, new_cache, _ = self._backbone(
            params, h, q_pos,
            cache=cache, cache_index=pos,
            self_attend=False, decode=True,
        )
        h = L.rms_norm(h, params["final_norm"])
        logits = L.unembed(params["embed"], cfg, h)
        return logits, new_cache


def n_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_params(cfg: ArchConfig, params: Params) -> int:
    """Active (per-token) params: total minus inactive expert fraction."""
    total = n_params(params)
    if cfg.n_experts == 0:
        return total
    expert = 0
    blocks = params["blocks"]
    for i, (_mixer, ff) in enumerate(cfg.layer_kinds()):
        if ff == "moe":
            moe_p = blocks[f"sub{i}"]["moe"]
            expert += sum(
                moe_p[k].size for k in ("w_up", "w_gate", "w_down")
            )
    inactive = expert * (1.0 - cfg.top_k / cfg.n_experts)
    return int(total - inactive)
