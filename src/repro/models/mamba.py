"""Mamba2 (state-space duality) mixer — pure-JAX chunked SSD reference.

Recurrence (per head h, state size N, head dim P):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t        (A < 0)
    y_t = C_t . h_t + D * x_t

The chunked algorithm (Dao & Gu 2024) splits the sequence into chunks of
``Q = cfg.ssm_chunk``: an intra-chunk quadratic term plus an inter-chunk
state recurrence carried by ``lax.scan``.  The Pallas kernel
(kernels/ssd_scan.py) mirrors exactly this structure; this module is its
oracle and the dry-run lowering path.

Single B/C group (G = 1) as in the Mamba2 default.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = Dict[str, Any]


def init_mamba(key: jax.Array, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    di = cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    kconv = cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    ch = di + 2 * N  # conv channels: x ++ B ++ C
    proj_out = 2 * di + 2 * N + H  # z ++ x ++ B ++ C ++ dt
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(keys[2], (H,), minval=1e-3, maxval=1e-1)
    dt_bias = jnp.log(jnp.expm1(u))
    return {
        "in_proj": (jax.random.normal(keys[0], (D, proj_out)) * D**-0.5).astype(dt),
        "conv_w": (jax.random.normal(keys[1], (kconv, ch)) * kconv**-0.5).astype(dt),
        "conv_b": jnp.zeros((ch,), dtype=dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(keys[3], (H,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "norm": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": (jax.random.normal(keys[2], (di, D)) * di**-0.5).astype(dt),
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * N]
    dt = proj[..., di + di + 2 * N :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq. xbc: [B,S,Ch]; w: [k,Ch]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad,
        w[:, None, :],  # [k, 1, Ch]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1],
    )
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jnp.ndarray,  # [B,S,H,P]
    dt: jnp.ndarray,  # [B,S,H]  (softplus applied)
    A: jnp.ndarray,  # [H]      (negative)
    Bm: jnp.ndarray,  # [B,S,N]
    Cm: jnp.ndarray,  # [B,S,N]
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # [B,H,N,P]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    S_orig = S
    if S % chunk:
        # Zero-pad to a chunk multiple: dt == 0 entries are exact no-ops
        # (decay exp(0)=1, contribution dt*B*x = 0).
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    dA = dtc * A  # [B,nc,Q,H], negative
    cum = jnp.cumsum(dA, axis=2)  # inclusive within-chunk cumsum

    # ---- intra-chunk (quadratic, masked) --------------------------------
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [B,nc,Q,Q]
    # decay L[h,i,j] = exp(cum_i - cum_j), lower-triangular inclusive.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    W = CB[..., None] * L * dtc[:, :, None, :, :]  # [B,nc,Q(i),Q(j),H]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", W, xc)

    # ---- chunk states ----------------------------------------------------
    cum_last = cum[:, :, -1:, :]  # [B,nc,1,H]
    decay_to_end = jnp.exp(cum_last - cum)  # [B,nc,Q,H]
    S_state = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchnp", decay_to_end * dtc, Bc, xc
    )  # [B,nc,H,N,P]

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum_last[:, :, 0, :])  # [B,nc,H]
    state0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, N, P), jnp.float32)
    )

    def body(h, inp):
        s_c, dec_c, C_c, cum_c = inp
        # y from the incoming state: C_t . (exp(cum_t) h)
        y_off = jnp.einsum("bin,bhnp,bih->bihp", C_c, h, jnp.exp(cum_c))
        h = dec_c[:, :, None, None] * h + s_c
        return h, y_off

    xs = (
        jnp.moveaxis(S_state, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    final_state, y_off = jax.lax.scan(body, state0, xs)
    y = y_diag + jnp.moveaxis(y_off, 0, 1)
    return y.reshape(Bsz, S, H, P)[:, :S_orig], final_state


def apply_mamba(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [B,S,D]
    cache: Optional[Params] = None,
    return_cache: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Mamba2 block. Training/prefill path (full sequence, chunked scan).

    If ``return_cache``, also returns {"conv": [B,k-1,Ch], "ssm": [B,H,N,P]}
    for subsequent decode steps.
    """
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"]).astype(x.dtype)
    xs = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(*xs.shape[:2], H, P)
    from ..parallel import opt_flags

    if opt_flags.get("mamba_heads"):
        # §Perf: shard SSD heads over `model` so the chunked scan's big
        # [B, nc, Q, Q, H] intra-chunk buffers scale with TP degree.
        from jax.sharding import PartitionSpec as P_

        b = opt_flags.get("batch_axes")
        xh = jax.lax.with_sharding_constraint(xh, P_(b, None, "model", None))
        dt = jax.lax.with_sharding_constraint(dt, P_(b, None, "model"))
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)

    # gated RMSNorm then output projection
    from .layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    new_cache = None
    if return_cache:
        k = cfg.ssm_conv
        # conv cache holds the last k-1 *pre-conv* xBC rows
        pre = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        _, xbc_pre, _ = _split_proj(cfg, pre)
        conv_cache = xbc_pre[:, -(k - 1) :, :]
        new_cache = {"conv": conv_cache, "ssm": final_state}
    return out, new_cache


def apply_mamba_decode(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # [B,1,D]
    cache: Params,
) -> Tuple[jnp.ndarray, Params]:
    """Single-token recurrent step (O(1) in sequence length)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    k = cfg.ssm_conv
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_new, dt_raw = _split_proj(cfg, proj)

    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # [B,k,Ch]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # [B,1,Ch]

    xs = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(-1, H, P).astype(jnp.float32)  # [B,H,P]
    h = cache["ssm"].astype(jnp.float32)  # [B,H,N,P]
    decay = jnp.exp(dt * A)  # [B,H]
    delta = jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bm[:, 0].astype(jnp.float32), xh
    )
    h = decay[:, :, None, None] * h + delta
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(-1, 1, di).astype(x.dtype)

    from .layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": window[:, 1:, :], "ssm": h}


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, ch), dtype=dtype),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
    }
