"""Pallas TPU kernel: fused RMSNorm.

Single-pass fused normalize+scale: each grid step loads one [BR, D] row
block into VMEM, reduces the mean-square in fp32, and writes the scaled
output — one HBM read + one write per element (vs. separate
mean/rsqrt/mul HLOs).  BR x D tiles chosen so BR*D*4B fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [BR, D]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray,  # [..., D]
    scale: jnp.ndarray,  # [D]
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    # pad rows to a block multiple
    pad = (-R) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale.reshape(1, D))
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
