"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU-native structure: the grid is ``(batch*heads, n_chunks)`` with the
chunk axis declared "arbitrary" (sequential) — TPU executes the last grid
dimension in order, so the inter-chunk SSM state lives in a VMEM scratch
buffer that persists across chunk iterations (the standard Pallas carry
idiom).  Per chunk the kernel computes, entirely in VMEM:

  1. the intra-chunk quadratic term  (C B^T ⊙ decay) x  — MXU matmuls on
     [Q, N] x [N, Q] and [Q, Q] x [Q, P] tiles (Q = chunk = 128 aligned);
  2. the contribution of the carried state  C (exp(cum) h);
  3. the state update  h <- exp(cum_Q) h + (decay_to_end * dt * B)^T x.

One (batch, head) pair per grid row keeps the working set
(Q x max(N, P, Q) fp32 tiles + the [N, P] state) well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if _CompilerParams is None:  # fail at import with a nameable cause
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported pallas version"
    )


def _ssd_kernel(
    x_ref,  # [Q, P]
    dt_ref,  # [Q, 1]
    a_ref,  # [1, 1]
    b_ref,  # [Q, N]
    c_ref,  # [Q, N]
    y_ref,  # [Q, P] out
    state_ref,  # [N, P] out (final state; written every chunk)
    h_scratch,  # [N, P] f32 VMEM scratch (persists across chunk steps)
    *,
    n_chunks: int,
):
    ci = pl.program_id(1)
    Q, P = x_ref.shape
    N = b_ref.shape[1]

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[...].astype(jnp.float32)  # [Q,P]
    dt = dt_ref[...].astype(jnp.float32)  # [Q,1]
    A = a_ref[0, 0].astype(jnp.float32)
    Bm = b_ref[...].astype(jnp.float32)  # [Q,N]
    Cm = c_ref[...].astype(jnp.float32)

    dA = dt * A  # [Q,1]
    cum = jnp.cumsum(dA, axis=0)  # [Q,1]

    # (1) intra-chunk: W[i,j] = (C_i.B_j) exp(cum_i - cum_j) dt_j, j <= i
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q,Q]
    diff = cum - cum[:, 0][None, :]  # [Q(i),Q(j)]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = iota_j <= iota_i
    W = jnp.where(tri, CB * jnp.exp(diff) * dt[:, 0][None, :], 0.0)
    y = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q,P]

    # (2) contribution of the carried state
    h = h_scratch[...]  # [N,P]
    Cdec = Cm * jnp.exp(cum)  # [Q,N]
    y += jax.lax.dot_general(
        Cdec, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # (3) state update: h <- exp(cum_Q) h + sum_j exp(cum_Q-cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[-1, 0] - cum)  # [Q,1]
    Bw = Bm * (decay_to_end * dt)  # [Q,N]
    new_h = jnp.exp(cum[-1, 0]) * h + jax.lax.dot_general(
        Bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [N,P]
    h_scratch[...] = new_h

    y_ref[...] = y.astype(y_ref.dtype)
    state_ref[...] = new_h.astype(state_ref.dtype)


def ssd_scan_pallas(
    x: jnp.ndarray,  # [B, S, H, P]
    dt: jnp.ndarray,  # [B, S, H]
    A: jnp.ndarray,  # [H]
    Bm: jnp.ndarray,  # [B, S, N]
    Cm: jnp.ndarray,  # [B, S, N]
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple (ops.py does)"
    nc = S // chunk

    # Layout: fold (B, H) into grid axis 0; chunk axis is sequential.
    xr = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtr = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    ar = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1, 1)
    br = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    cr = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    grid = (B * H, nc)

    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, 1, 1), lambda h, c: (h, 0, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            # final state: every chunk writes the same [N,P] block; the
            # last (sequential) write wins.
            pl.BlockSpec((None, N, P), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(xr, dtr, ar, br, cr)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    state = state.reshape(B, H, N, P)
    return y, state
