"""Jit'd public wrappers around the Pallas kernels.

* ``flash_attention`` — custom_vjp: Pallas forward (TPU), recompute-based
  pure-jnp backward (flash-style: no S x T residuals saved).
* ``ssd_scan`` — chunk-padded wrapper around the SSD Pallas kernel.
* ``rmsnorm`` — fused norm wrapper.

``interpret=True`` everywhere in this container (CPU); on real TPU the same
calls run compiled (set ``repro.kernels.INTERPRET = False``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .ssd_scan import ssd_scan_pallas

INTERPRET = True  # CPU container: interpret-mode validation


# --------------------------------------------------------------------------
# flash attention (custom vjp: pallas fwd, recompute bwd)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    return flash_attention_pallas(
        q, k, v, q_pos, kv_pos, causal=causal, window=window,
        interpret=INTERPRET,
    )


def _fa_fwd(q, k, v, q_pos, kv_pos, causal, window):
    out = flash_attention(q, k, v, q_pos, kv_pos, causal, window)
    return out, (q, k, v, q_pos, kv_pos)


def _fa_bwd(causal, window, res, g):
    q, k, v, q_pos, kv_pos = res
    # Recompute-based backward through the reference (flash-style: no
    # S x T tensor was saved by the forward).
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, q_pos, kv_pos, causal=causal, window=window
        ),
        q, k, v,
    )
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_scan_pallas(
        x, dt, A, Bm, Cm, chunk=chunk, interpret=INTERPRET
    )
    return y[:, :S], state


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    return rmsnorm_pallas(x, scale, eps=eps, interpret=INTERPRET)
