"""Pallas TPU flash-attention kernel (forward).

Online-softmax attention with GQA, causal masking, and sliding-window
support.  TPU-native design (not a CUDA port):

* the grid is ``(batch*kv_heads, q_head_group, Sq/BQ)`` with the KV loop as
  a ``fori_loop`` *inside* the kernel body — keys/values stream HBM->VMEM
  one ``[BK, K]`` tile at a time while the ``[BQ, K]`` query tile and the
  fp32 accumulator stay resident in VMEM;
* block shapes are MXU-aligned: BQ/BK multiples of 128 (sublane x lane
  8x128 tiling), head_dim padded to 128 by the wrapper (ops.py);
* running max/sum are carried in SMEM-friendly [BQ, 1] fp32 tiles —
  the classic online-softmax rescaling;
* causal + window masking is computed from absolute positions so the same
  kernel serves train (full S x S), prefill and ring-buffer SWA layouts.

Validated against ref.py (pure jnp) in interpret mode; see
tests/test_kernels_flash.py for the shape/dtype sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [BQ, K]       (block of queries for one (b, g, hg))
    k_ref,  # [T, K]        (all keys for one (b, g))
    v_ref,  # [T, K]
    qpos_ref,  # [BQ, 1] i32
    kpos_ref,  # [T, 1] i32
    o_ref,  # [BQ, K]
    *,
    block_k: int,
    causal: bool,
    window: Optional[int],
    sm_scale: float,
):
    bq, head_k = q_ref.shape
    T = k_ref.shape[0]
    n_kv = T // block_k

    q = q_ref[...].astype(jnp.float32) * sm_scale
    qpos = qpos_ref[...]  # [BQ,1]

    def body(i, carry):
        acc, m_i, l_i = carry
        k = k_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        kpos = kpos_ref[pl.ds(i * block_k, block_k), :]  # [BK,1]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [BQ, BK]

        ok = (kpos[:, 0][None, :] >= 0)
        if causal:
            ok &= kpos[:, 0][None, :] <= qpos[:, 0][:, None]
        if window is not None:
            ok &= kpos[:, 0][None, :] > qpos[:, 0][:, None] - window
        s = jnp.where(ok, s, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, head_k), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Sq, H, K]
    k: jnp.ndarray,  # [B, T, G, K]
    v: jnp.ndarray,  # [B, T, G, K]
    q_pos: jnp.ndarray,  # [Sq] i32
    kv_pos: jnp.ndarray,  # [T] i32
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """pallas_call wrapper; see ops.py for padding/vmap plumbing."""
    B, Sq, H, K = q.shape
    T, G = k.shape[1], k.shape[2]
    Hg = H // G
    assert Sq % block_q == 0 and T % block_k == 0
    sm_scale = K**-0.5

    # Layout: fold (B, G, Hg) into the grid's first axis; queries blocked.
    qr = q.reshape(B, Sq, G, Hg, K).transpose(0, 2, 3, 1, 4)  # [B,G,Hg,Sq,K]
    qr = qr.reshape(B * G * Hg, Sq, K)
    kr = (
        jnp.repeat(k.transpose(0, 2, 1, 3), Hg, axis=1)
        .reshape(B * G * Hg, T, K)
    )
    vr = (
        jnp.repeat(v.transpose(0, 2, 1, 3), Hg, axis=1)
        .reshape(B * G * Hg, T, K)
    )
    qpos2 = q_pos.reshape(Sq, 1).astype(jnp.int32)
    kpos2 = kv_pos.reshape(T, 1).astype(jnp.int32)

    kernel = functools.partial(
        _flash_kernel,
        block_k=block_k,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
    )

    grid = (B * G * Hg, Sq // block_q)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, K), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, T, K), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, T, K), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((block_q, 1), lambda h, i: (i, 0)),
            pl.BlockSpec((T, 1), lambda h, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, K), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * G * Hg, Sq, K), q.dtype),
        interpret=interpret,
    )(qr, kr, vr, qpos2, kpos2)

    out = out.reshape(B, G, Hg, Sq, K).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, K)
