"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # [B, Sq, H, K]
    k: jnp.ndarray,  # [B, T, G, K]
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # [Sq]
    kv_pos: jnp.ndarray,  # [T]
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    B, Sq, H, K = q.shape
    G = k.shape[2]
    qg = q.reshape(B, Sq, G, H // G, K).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bsghk,btgk->bghst", qg, kf) * (K**-0.5)
    ok = kv_pos[None, :] >= 0
    if causal:
        ok = ok & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghst,btgk->bsghk", p, vf)
    return o.reshape(B, Sq, H, K).astype(q.dtype)


def ssd_scan_ref(
    x: jnp.ndarray,  # [B, S, H, P]
    dt: jnp.ndarray,  # [B, S, H] (softplus applied)
    A: jnp.ndarray,  # [H] negative
    Bm: jnp.ndarray,  # [B, S, N]
    Cm: jnp.ndarray,  # [B, S, N]
    init_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential (exact) SSD recurrence — O(S) scan, the ground truth."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, N, P), jnp.float32)
    )

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dt_t * A)  # [B,H]
        h = decay[:, :, None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhnp", dt_t, B_t, x_t
        )
        y = jnp.einsum("bn,bhnp->bhp", C_t, h)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
    )
    hF, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hF  # [B,S,H,P], [B,H,N,P]


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )
