"""End-to-end training driver with checkpoint/restart + fault tolerance.

CPU-runnable (reduced configs) and mesh-ready (full configs on TPU):

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised: AdamW + cosine schedule, grad clip, microbatching,
async checkpointing every --ckpt-every steps, automatic resume from the
latest complete checkpoint, simulated failure injection (--fail-at) that
kills and restarts the loop mid-run to prove restartability, and
straggler detection hooks.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..models.model import Model, n_params
from ..train import checkpoint
from ..train.data import DataLoader
from ..train.fault_tolerance import StragglerDetector
from ..train.optimizer import AdamWConfig
from ..train.train_step import init_train_state, make_train_step


def train_loop(
    arch: str,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    reduced: bool = True,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    microbatches: int = 1,
    fail_at: int | None = None,
    seed: int = 0,
    log_every: int = 10,
    lr: float = 3e-4,
) -> dict:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr_peak=lr, warmup_steps=min(20, steps // 5 + 1),
                          total_steps=steps)
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, num_microbatches=microbatches),
        donate_argnums=(0,),
    )
    loader = DataLoader(cfg, batch, seq, seed=seed)

    start_step = 0
    state = None
    writer = None
    if ckpt_dir:
        writer = checkpoint.AsyncWriter(ckpt_dir, keep=2)
        last = checkpoint.latest_step(ckpt_dir)
        if last is not None:
            template = jax.eval_shape(
                lambda k: init_train_state(model, k), jax.random.PRNGKey(seed)
            )
            state, meta = checkpoint.restore(ckpt_dir, template)
            start_step = meta["step"]
            loader.restore(meta["loader"])
            print(f"[resume] restored step {start_step} from {ckpt_dir}")
    if state is None:
        state = init_train_state(model, jax.random.PRNGKey(seed))
    print(
        f"[train] {cfg.name} ({'reduced' if reduced else 'full'}) "
        f"params={n_params(state.params):,} steps={steps}"
    )

    stragglers = StragglerDetector()
    losses = []
    for step in range(start_step, steps):
        batch_np = loader.next()
        t0 = time.time()
        state, metrics = step_fn(
            state, jax.tree.map(jnp.asarray, batch_np)
        )
        dt = time.time() - t0
        stragglers.record(host=0, step_time=dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(
                f"  step {step:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)"
            )
        if writer and (step + 1) % ckpt_every == 0:
            writer.submit(
                step + 1, state, {"loader": loader.state()}
            )
        if fail_at is not None and step + 1 == fail_at:
            if writer:
                writer.close()
            raise RuntimeError(f"injected failure at step {fail_at}")
    if writer:
        writer.submit(steps, state, {"loader": loader.state()})
        writer.close()
    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "final_step": steps,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=args.reduced, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatches=args.microbatches,
        fail_at=args.fail_at, seed=args.seed,
    )
    print(f"[done] {res}")


if __name__ == "__main__":
    main()
