"""launch subpackage."""
