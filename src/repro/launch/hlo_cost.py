"""Loop-aware cost accounting over post-optimization (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` counts each while-loop body ONCE, regardless of
trip count (verified empirically: a scan of L matmuls reports 1x body
flops).  Our models scan over layers, so XLA's numbers under-count compute,
bytes and collectives by ~n_layers.  This module re-derives per-device
roofline inputs from ``compiled.as_text()``:

  * the module is parsed into computations; operand shapes are resolved
    through a per-computation name -> result-shape map (modern HLO printing
    omits operand shapes inline);
  * FLOPs: exact for ``dot`` (contracting dims x result elements) and
    ``convolution`` (window size); 1 flop/element for elementwise and
    reduce ops (coarse — these graphs are matmul-dominated);
  * bytes: operands + results of materializing ops (fusion, dot, conv,
    copy, scatter/gather, dynamic slices, collectives, ...) — one HBM
    read/write per buffer at fusion boundaries, the TPU cost model;
  * collectives: result bytes per collective kind;
  * while loops: trip count from the ``known_trip_count`` backend config
    (fallback: the condition's compare constant); every computation's cost
    is scaled by the product of enclosing trip counts.  Fusion bodies
    contribute flops (not bytes) at their call sites' multiplier.

Validated in tests/test_hlo_cost.py against unrolled references.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# ``%name = <result types> <op>(...)``.  Result tuples may contain
# ``/*index=N*/`` comments (hence no naive [^=] matching); the op is the
# first ``name(`` token — tuple-type parens are never name-prefixed.
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+) = (.*)$")
_OPCALL_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]+)\(")
_COMP_START_RE = re.compile(
    r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->.*\{"
)
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "select", "compare", "and", "or", "xor", "not", "clamp", "sign",
    "floor", "ceil", "round-nearest-afz", "convert", "cosine", "sine",
    "logistic", "expm1", "log1p", "atan2", "erf", "remainder",
}
_MATERIALIZING = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "sort", "reduce",
    "reduce-window", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "transpose", "concatenate", "pad",
    "rng-bit-generator", "cumsum", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems, nbytes = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * nb
    return elems, nbytes


@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    cost: OpCost = field(default_factory=OpCost)
    calls: List[Tuple[str, str]] = field(default_factory=list)  # (callee, kind)
    while_bodies: List[Tuple[str, str, int]] = field(
        default_factory=list
    )  # (body, cond, trip)
    max_s32_constant: int = 1


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes: Dict[str, str] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and not line.startswith(" "):
                cur = Computation(
                    name=m.group("name"), is_entry=bool(m.group("entry"))
                )
                shapes = {}
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        lm = _LHS_RE.match(line)
        if not lm:
            continue
        name, rhs = lm.group(1), lm.group(2)
        om = _OPCALL_RE.search(rhs)
        if not om:
            continue
        result = rhs[: om.start()].strip()
        op = om.group(1)
        rest = rhs[om.end():]
        shapes[name] = result
        operand_str = rest.split(")")[0]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        elems, rbytes = _type_elems_bytes(result)

        # s32 constants (fallback trip-count recovery in loop conditions)
        if op == "constant" and result.startswith("s32"):
            cm = re.search(r"constant\((-?\d+)\)", line)
            if cm:
                cur.max_s32_constant = max(
                    cur.max_s32_constant, int(cm.group(1))
                )

        # call graph
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", line)
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else -1
            if body and cond:
                cur.while_bodies.append((body.group(1), cond.group(1), trip))
        elif op == "conditional":
            for callee in re.findall(
                r"(?:true_computation|false_computation|branch_computations)"
                r"=\{?%?([\w.\-]+)", line
            ):
                cur.calls.append((callee, "call"))
        else:
            for callee in _CALLED_RE.findall(line):
                kind = "fusion" if op == "fusion" else "call"
                cur.calls.append((callee, kind))

        # ---- flops -------------------------------------------------------
        if op == "dot":
            contract = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            lhs_type = shapes.get(operands[0], "") if operands else ""
            lm = _SHAPE_RE.search(lhs_type)
            if cm and lm:
                lhs_dims = lm.group(2).split(",") if lm.group(2) else []
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= int(lhs_dims[int(idx)])
            cur.cost.flops += 2.0 * elems * contract
        elif op == "convolution":
            wm = re.search(r"window=\{size=([0-9x]+)", line)
            ksize = 1
            if wm:
                for d in wm.group(1).split("x"):
                    ksize *= int(d)
            cur.cost.flops += 2.0 * elems * ksize
        elif op in _ELEMENTWISE:
            cur.cost.flops += float(elems)
        elif op in ("reduce", "reduce-window"):
            op_elems = 0
            for o in operands[: max(1, len(operands) // 2)]:
                e, _ = _type_elems_bytes(shapes.get(o, ""))
                op_elems += e
            cur.cost.flops += float(op_elems)

        # ---- bytes -------------------------------------------------------
        if op in _MATERIALIZING:
            if op == "dynamic-slice":
                cur.cost.bytes += 2.0 * rbytes  # read slice + write result
            elif op == "dynamic-update-slice":
                upd = shapes.get(operands[1], "") if len(operands) > 1 else ""
                _, ub = _type_elems_bytes(upd)
                cur.cost.bytes += 3.0 * ub  # in-place: r/w region + update
            elif op == "gather":
                idx = shapes.get(operands[1], "") if len(operands) > 1 else ""
                _, ib = _type_elems_bytes(idx)
                cur.cost.bytes += 2.0 * rbytes + ib
            elif op == "scatter":
                upd = shapes.get(operands[2], "") if len(operands) > 2 else ""
                _, ub = _type_elems_bytes(upd)
                cur.cost.bytes += 3.0 * ub
            else:
                obytes = 0
                for o in operands:
                    _, ob = _type_elems_bytes(shapes.get(o, ""))
                    obytes += ob
                cur.cost.bytes += rbytes + obytes

        # ---- collectives ---------------------------------------------------
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_KINDS:
            cur.cost.coll[base] = cur.cost.coll.get(base, 0.0) + rbytes

    return comps


@dataclass
class ModuleCost:
    flops: float
    bytes: float
    coll: Dict[str, float]

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))


def analyze(text: str) -> ModuleCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    mult: Dict[str, float] = {}
    bytes_excluded: set = set()

    def visit(name: str, m: float, via_fusion: bool) -> None:
        if name not in comps or m <= 0:
            return
        mult[name] = mult.get(name, 0.0) + m
        if via_fusion:
            bytes_excluded.add(name)
        c = comps[name]
        for body, cond, trip in c.while_bodies:
            if trip < 0:
                trip = comps[cond].max_s32_constant if cond in comps else 1
            visit(body, m * trip, via_fusion)
            visit(cond, m * trip, via_fusion)
        for callee, kind in c.calls:
            visit(callee, m, via_fusion or kind == "fusion")

    visit(entry.name, 1.0, False)

    flops = 0.0
    nbytes = 0.0
    coll: Dict[str, float] = {}
    for name, m in mult.items():
        c = comps[name]
        flops += c.cost.flops * m
        if name not in bytes_excluded:
            nbytes += c.cost.bytes * m
        for k, v in c.cost.coll.items():
            coll[k] = coll.get(k, 0.0) + v * m
    return ModuleCost(flops=flops, bytes=nbytes, coll=coll)
