"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run (and only the dry-run) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the installed JAX has it (>= 0.4.38-ish);
    older releases default every axis to Auto, so omitting is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def _make_mesh(shape, axes):
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
    # legacy mesh API (pre jax.make_mesh)
    import numpy as np

    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return _make_mesh((n // model, model), ("data", "model"))
