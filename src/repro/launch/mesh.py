"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run (and only the dry-run) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
