import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — without real hardware.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  Placeholder host devices stand in for the production
mesh: 16x16 = 256 chips single-pod, 2x16x16 = 512 chips across two pods.

Per cell this script:
  1. builds abstract (ShapeDtypeStruct) params/opt-state/inputs — nothing
     is allocated;
  2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``;
  3. records ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs /
     bytes) and the per-device collective bytes parsed from the HLO —
     the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k \
      [--multi-pod] [--out results/dryrun/cell.json]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import SHAPES, applicable_shapes, get_config, list_archs
from ..configs.base import ArchConfig, ShapeCell
from ..models.model import Model, active_params
from ..parallel import sharding as sh
from ..train.optimizer import AdamWConfig
from ..train.train_step import init_train_state, make_serve_steps, make_train_step
from . import analysis
from .mesh import make_production_mesh


# --------------------------------------------------------------------------
# input specs (abstract stand-ins for every model input)
# --------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for the batch of one step."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if cfg.family == "vlm":
        Ti = cfg.vlm_img_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((B, S - Ti), i32),
            "labels": jax.ShapeDtypeStruct((B, S - Ti), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, Ti, cfg.frontend_dim), jnp.bfloat16
            ),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }


def _prefill_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    specs = input_specs(cfg, cell)
    specs.pop("labels", None)
    return specs


def _token_specs(cell: ShapeCell) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)


# --------------------------------------------------------------------------
# per-cell dry run
# --------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    verbose: bool = True,
    mesh=None,
    cfg: ArchConfig | None = None,
    opts: tuple = (),
) -> dict:
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape]
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    from ..parallel import opt_flags

    opt_flags.reset()
    b = sh.batch_axes(mesh, cell.global_batch)
    opt_flags.set_flags(batch_axes=b)
    if "sp" in opts and cell.seq_len % max(mesh.shape.get("model", 1), 1) == 0:
        # §Perf: sequence-parallel residual stream (shard S over `model`)
        model.act_spec = sh.P(b, "model", None)
        opt_flags.set_flags(sp=True)
    if "mamba_heads" in opts:
        opt_flags.set_flags(mamba_heads=True)
    if "moe_ep" in opts:
        opt_flags.set_flags(moe_ep=True)
    if "moe_a2a" in opts:
        opt_flags.set_flags(moe_a2a=True, mesh=mesh)
    if "sp_sub" in opts:
        opt_flags.set_flags(sp_sub=True)

    t0 = time.time()
    with mesh:
        if cell.kind == "train":
            state_specs = jax.eval_shape(
                lambda k: init_train_state(model, k), key
            )
            p_sh = sh.param_shardings(cfg, state_specs.params, mesh)
            state_sh = type(state_specs)(
                params=p_sh,
                opt=type(state_specs.opt)(
                    step=sh.replicated(mesh),
                    m=sh.param_shardings(cfg, state_specs.opt.m, mesh),
                    v=sh.param_shardings(cfg, state_specs.opt.v, mesh),
                ),
                error_feedback=None,
            )
            batch_specs = input_specs(cfg, cell)
            b_sh = sh.batch_shardings(cfg, batch_specs, mesh)
            step_fn = make_train_step(model, AdamWConfig())
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, sh.replicated(mesh)),
                donate_argnums=(0,),
            ).lower(state_specs, batch_specs)
        else:
            params_specs = model.param_specs(key)
            p_sh = sh.param_shardings(cfg, params_specs, mesh)
            cache_specs = jax.eval_shape(
                lambda: model.init_cache(
                    cell.global_batch, cell.seq_len, dtype=jnp.bfloat16
                )
            )
            c_sh = sh.cache_shardings(cfg, cache_specs, mesh)
            prefill_step, decode_step = make_serve_steps(model)
            b = sh.batch_axes(mesh, cell.global_batch)
            logits_sh = sh.NamedSharding(
                mesh,
                sh.P(b, None, sh.maybe(mesh, cfg.padded_vocab, "model")),
            )
            if cell.kind == "prefill":
                batch_specs = _prefill_specs(cfg, cell)
                b_sh = sh.batch_shardings(cfg, batch_specs, mesh)
                lowered = jax.jit(
                    prefill_step,
                    in_shardings=(p_sh, b_sh, c_sh),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(2,),
                ).lower(params_specs, batch_specs, cache_specs)
            else:  # decode
                tok = _token_specs(cell)
                tok_sh = sh.NamedSharding(mesh, sh.P(b, None))
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jax.jit(
                    decode_step,
                    in_shardings=(p_sh, c_sh, tok_sh, sh.replicated(mesh)),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(1,),
                ).lower(params_specs, cache_specs, tok, pos)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = analysis.extract_cost(compiled)  # loop-UNAWARE, kept for ref
    from . import hlo_cost

    mc = hlo_cost.analyze(compiled.as_text())  # loop-aware per-device cost
    n_active = active_params(cfg, model.param_specs(key))
    terms = analysis.RooflineTerms(
        arch=arch,
        shape=shape,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=n_dev,
        hlo_flops=mc.flops,
        hlo_bytes=mc.bytes,
        coll_bytes=mc.coll_bytes,
        coll_breakdown={k: int(v) for k, v in mc.coll.items()},
        model_flops=analysis.model_flops_for(cfg, cell, n_active),
        peak_memory_bytes=float(
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes
        ),
    )
    result = {
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_cost_loop_unaware": xla_cost,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        **terms.to_dict(),
    }
    if verbose:
        print(json.dumps(result, indent=2, default=str))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--opt", default="",
        help="comma-separated §Perf optimizations (e.g. sp)",
    )
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    if args.all:
        outdir = Path(args.out or "results/dryrun")
        outdir.mkdir(parents=True, exist_ok=True)
        mesh_tag = "multi" if args.multi_pod else "single"
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in applicable_shapes(cfg):
                path = outdir / f"{arch}__{shape}__{mesh_tag}.json"
                if args.skip_existing and path.exists():
                    print(f"skip {path}")
                    continue
                print(f"=== {arch} x {shape} x {mesh_tag} ===", flush=True)
                try:
                    res = run_cell(arch, shape, args.multi_pod, verbose=False,
                                   opts=opts)
                except Exception as e:  # record failures for triage
                    res = {
                        "ok": False,
                        "error": repr(e),
                        "traceback": traceback.format_exc(),
                    }
                    print(f"FAILED: {e!r}", flush=True)
                path.write_text(json.dumps(res, indent=2, default=str))
                print(
                    f"-> {path} ok={res.get('ok')} "
                    f"compile={res.get('compile_s')}s",
                    flush=True,
                )
        return

    res = run_cell(args.arch, args.shape, args.multi_pod, opts=opts)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(res, indent=2, default=str))


if __name__ == "__main__":
    main()
