"""Roofline-term extraction from compiled dry-run artifacts.

Hardware constants (TPU v5e-class, per assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

``compiled.cost_analysis()`` provides HLO FLOPs and bytes of the *per-device*
(SPMD-partitioned) module; collective bytes are not in cost_analysis, so we
parse the HLO text and sum result-shape bytes of every collective op.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one result tensor, e.g. ``bf16[8,128]{1,0}`` or scalar ``f32[]``
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from (S)HLO text."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            # match ``= <result shapes> <op>(``  — avoids fused ops and the
            # "-start/-done" duplication (count only the -start or plain op).
            marker = f" {op}("
            marker_start = f" {op}-start("
            if marker in line or marker_start in line:
                lhs = line.split("=", 1)
                if len(lhs) != 2:
                    continue
                result = lhs[1].split(op, 1)[0]
                for dtype, dims in _SHAPE_RE.findall(result):
                    out[op] += _shape_bytes(dtype, dims)
                break
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    coll_bytes: float  # per device
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0  # 6*N*D or 2*N*D (useful flops, whole step)
    peak_memory_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs summed over devices)."""
        total = self.hlo_flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs peak, if the dominant term binds."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops / self.n_devices / t) / PEAK_FLOPS

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def extract_cost(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


def model_flops_for(cfg, cell, n_active_params: int) -> float:
    """Useful-FLOPs floor: 6*N*tokens (train) / 2*N*tokens (inference)."""
    if cell.kind == "train":
        return 6.0 * n_active_params * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active_params * cell.global_batch * cell.seq_len
    # decode: one token per sequence per step
    return 2.0 * n_active_params * cell.global_batch
