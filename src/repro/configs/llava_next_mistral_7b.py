"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (anyres tiles), projected by a
trainable 2-layer MLP into the mistral-7b backbone.
"""
from .base import ArchConfig
from .registry import register


@register
def llava_next_mistral_7b() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        frontend_dim=1024,  # CLIP-large patch embedding dim
        vlm_img_tokens=1152,  # anyres: base 576 + half-tile thumbnails
        rope_theta=1e6,
    )
