"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as w2v2  [arXiv:2106.07447; unverified]

The CNN waveform frontend is a STUB per the assignment: ``input_specs()``
provides precomputed 512-dim frame features; a trainable projection maps
them into the encoder. Loss is masked-frame cluster prediction over the
504 k-means targets (the HuBERT objective).
"""
from .base import ArchConfig
from .registry import register


@register
def hubert_xlarge() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,  # d_model / n_heads
        d_ff=5120,
        vocab_size=504,
        mlp_gated=False,  # w2v2 MLP is up/down GeLU
        causal=False,  # bidirectional encoder
        frontend_dim=512,
    )
