"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA  [arXiv:2401.16818; unverified]"""
from .base import ArchConfig
from .registry import register


@register
def h2o_danube3_4b() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,  # d_model / n_heads
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,  # mistral-style SWA -> sub-quadratic long ctx
        rope_theta=1e4,
    )
