"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code  [arXiv:2405.04324; hf]

gpt_bigcode lineage: non-gated (2-matrix) MLP, multi-query attention.
"""
from .base import ArchConfig
from .registry import register


@register
def granite_34b() -> ArchConfig:
    return ArchConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        mlp_gated=False,  # gpt_bigcode MLP (up/down, GeLU)
        rope_theta=1e4,
    )
