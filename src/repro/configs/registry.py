"""The 10 assigned architectures (exact configs from the assignment)."""
from __future__ import annotations

from typing import Callable, Dict

from .base import ArchConfig

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]) -> Callable[[], ArchConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ArchConfig:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def reduced_config(name: str, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    cfg = get_config(name)
    small = dict(
        n_layers=len(cfg.layer_kinds()) * 2,  # two scan blocks
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=32 if cfg.sliding_window else None,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state or cfg.family == "hybrid" else 64,
        ssm_chunk=16,
        frontend_dim=32 if cfg.frontend_dim else 0,
        vlm_img_tokens=8 if cfg.vlm_img_tokens else 0,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
