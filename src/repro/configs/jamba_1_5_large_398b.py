"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf]

Super-block of 8 layers: attention at position 4, Mamba elsewhere
(1:7); MoE replaces the dense MLP on every 2nd layer (Jamba's published
e=2 MoE period). Total params ~= 398B, active ~= 94B.
"""
from .base import ArchConfig
from .registry import register


@register
def jamba_1_5_large_398b() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        n_experts=16,
        top_k=2,
        attn_period=8,
        moe_period=2,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        rope_theta=1e6,
    )
