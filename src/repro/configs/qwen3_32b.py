"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA  [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig
from .registry import register


@register
def qwen3_32b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,  # Qwen3 uses decoupled head_dim=128 (attn_dim 8192)
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
    )
