"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=163840, MoE 64e top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf]

Note: with the assigned 48 layers the total parameter count is ~27B
(the HF Moonlight model uses 27 layers for its "16B" total); we keep the
assigned config verbatim.
"""
from .base import ArchConfig
from .registry import register


@register
def moonshot_v1_16b_a3b() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,  # per-expert hidden
        vocab_size=163840,
        n_experts=64,
        top_k=6,
        rope_theta=5e4,
    )
