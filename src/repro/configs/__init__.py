"""Assigned-architecture configs (+ shapes)."""
from .base import ArchConfig, ShapeCell, SHAPES, applicable_shapes  # noqa: F401
from .registry import get_config, list_archs, reduced_config  # noqa: F401

# Import config modules so they register themselves.
from . import (  # noqa: F401,E402
    qwen3_32b,
    deepseek_7b,
    granite_34b,
    h2o_danube3_4b,
    moonshot_v1_16b_a3b,
    qwen3_moe_30b_a3b,
    llava_next_mistral_7b,
    mamba2_370m,
    jamba_1_5_large_398b,
    hubert_xlarge,
)

ALL_ARCHS = list_archs()
