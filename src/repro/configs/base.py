"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (see ``repro/configs/<id>.py``)
plus the four input-shape cells.  ``family`` selects the block structure:

* dense   — attention + (gated) MLP every layer
* moe     — attention + top-k mixture-of-experts MLP
* vlm     — dense backbone; frontend is a patch-embedding stub
* ssm     — Mamba2 (SSD) mixer only, no MLP
* hybrid  — Jamba-style 1:7 attention:mamba interleave, MoE every 2nd layer
* audio   — encoder-only (bidirectional) transformer, frame-embedding stub
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int  # query heads (0 for attention-free archs)
    n_kv_heads: int
    d_ff: int  # dense MLP hidden (for moe: per-expert hidden)
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA window (tokens)
    mlp_gated: bool = True  # SwiGLU vs plain GeLU MLP
    causal: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (Jamba): one attention layer per `attn_period` layers, MoE on
    # every `moe_period`-th layer.
    attn_period: int = 0
    moe_period: int = 0
    # frontend stubs
    frontend_dim: int = 0  # audio frame / vision patch embedding dim
    vlm_img_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    # notes recorded for DESIGN.md fidelity bookkeeping
    notes: str = ""

    def __post_init__(self) -> None:
        if self.family not in ("dense", "moe", "vlm", "ssm", "hybrid", "audio"):
            raise ValueError(f"unknown family {self.family}")
        if self.family in ("moe", "hybrid") and not (
            self.n_experts > 0 and self.top_k > 0
        ):
            raise ValueError("MoE family needs n_experts/top_k")

    # ---- derived sizes -----------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over `model`."""
        return _round_up(self.vocab_size, 256)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1  # single B/C group (Mamba2 default)

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, ff) kind per layer within one scan block.

        dense/moe/vlm/audio: one (attn, ff) layer per scan step.
        ssm: one (mamba, none) layer per scan step.
        hybrid: the scan step is a super-block of ``attn_period`` layers.
        """
        if self.family in ("dense", "vlm", "audio"):
            return (("attn", "dense"),)
        if self.family == "moe":
            return (("attn", "moe"),)
        if self.family == "ssm":
            return (("mamba", "none"),)
        # hybrid: attention in the middle of the super-block, MoE on odd
        # positions (Jamba's published 1:7 interleave, MoE every 2 layers).
        kinds = []
        for i in range(self.attn_period):
            mixer = "attn" if i == self.attn_period // 2 else "mamba"
            ff = "moe" if (i % self.moe_period == self.moe_period - 1) else "dense"
            kinds.append((mixer, ff))
        return tuple(kinds)

    @property
    def n_scan_blocks(self) -> int:
        per_block = len(self.layer_kinds())
        if self.n_layers % per_block != 0:
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not divisible by "
                f"super-block size {per_block}"
            )
        return self.n_layers // per_block


@dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) evaluation cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Which shape cells apply to this arch (skips recorded in DESIGN.md)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.causal:  # encoder-only archs have no autoregressive decode
        out.append("decode_32k")
        # long_500k needs sub-quadratic attention: SSM, hybrid, or SWA.
        if (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window is not None
        ):
            out.append("long_500k")
    return out
