"""parallel subpackage."""
