"""Trace-time optimization flags for §Perf iterations.

Set by launch/dryrun.py (--opt a,b,c) before lowering; read by model code
at trace time.  Flags:

  sp           — sequence-parallel residual stream (model.py)
  mamba_heads  — shard SSD head dim over `model` inside the mamba mixer
  moe_ep       — expert-parallel sharding constraints on MoE dispatch
  batch_axes   — mesh axes the batch dim is sharded over (set automatically)
"""
from __future__ import annotations

_FLAGS = {
    "sp": False,
    "mamba_heads": False,
    "moe_ep": False,
    "moe_a2a": False,  # shard_map local-dispatch MoE (§Perf iteration 3)
    "sp_sub": False,  # per-sublayer resharding (REFUTED, kept for ablation)
    "batch_axes": None,
    "mesh": None,
}


def set_flags(**kw) -> None:
    for k, v in kw.items():
        if k not in _FLAGS:
            raise KeyError(k)
        _FLAGS[k] = v


def reset() -> None:
    set_flags(sp=False, mamba_heads=False, moe_ep=False, moe_a2a=False,
              sp_sub=False, batch_axes=None, mesh=None)


def get(name: str):
    return _FLAGS[name]
