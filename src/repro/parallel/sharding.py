"""Sharding rules: parameter/activation PartitionSpecs per architecture.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.

Strategy (baseline; §Perf iterates):
* 2-D param sharding — tensor-parallel dims (heads, ff, experts, vocab) on
  `model`; the other large dim on `data` (FSDP/ZeRO-3 style). XLA inserts
  the all-gathers for FSDP params and reduce-scatters for grads.
* activations: batch on ('pod', 'data') when divisible; attention heads /
  expert dim on `model`.
* KV caches: batch on ('pod','data') when divisible, else sequence on
  'data'; kv-head dim on `model` only when divisible (MQA replicates kv).

Every rule degrades to replication when a dim isn't divisible — so every
(arch x shape x mesh) cell lowers, and the dry-run exposes the cost.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(mesh: Mesh, dim: int, *axes: str) -> bool:
    size = 1
    for a in axes:
        size *= axis_size(mesh, a)
    return size > 1 and dim % size == 0


def maybe(mesh: Mesh, dim: int, *axes: str):
    """Return the axis (tuple) if the dim divides, else None (replicate)."""
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    if _fits(mesh, dim, *axes):
        return axes if len(axes) > 1 else axes[0]
    # try a prefix (e.g. ('pod','data') -> ('data',))
    for i in range(len(axes) - 1, 0, -1):
        if _fits(mesh, dim, *axes[i:]):
            sub = axes[i:]
            return sub if len(sub) > 1 else sub[0]
    return None


def batch_axes(mesh: Mesh, batch: int):
    return maybe(mesh, batch, "pod", "data")


# --------------------------------------------------------------------------
# parameter sharding
# --------------------------------------------------------------------------


def _param_spec(path: Tuple[str, ...], leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter; `path` is the key path (strings)."""
    name = path[-1]
    scanned = "blocks" in path  # leading n_blocks axis
    shape = leaf.shape[1:] if scanned else leaf.shape

    def spec(*axes) -> P:
        return P(*( (None,) + axes if scanned else axes ))

    if name == "tokens":  # [V, D]
        s = spec(maybe(mesh, shape[0], "model"), maybe(mesh, shape[1], "data"))
    elif name == "unembed":  # [D, V]
        s = spec(maybe(mesh, shape[0], "data"), maybe(mesh, shape[1], "model"))
    elif name == "wq":  # [D, H, K]
        s = spec(maybe(mesh, shape[0], "data"), maybe(mesh, shape[1], "model"), None)
    elif name in ("wk", "wv"):  # [D, G, K] — G may be < model size (MQA)
        s = spec(maybe(mesh, shape[0], "data"), maybe(mesh, shape[1], "model"), None)
    elif name == "wo":  # [H, K, D]
        s = spec(maybe(mesh, shape[0], "model"), None, maybe(mesh, shape[2], "data"))
    elif name in ("w_up", "w_gate", "w_down") and len(shape) == 3:
        # MoE experts [E, D, F] / [E, F, D]: expert parallel on `model`.
        s = spec(maybe(mesh, shape[0], "model"), maybe(mesh, shape[1], "data"), None)
    elif name in ("w_up", "w_gate"):  # [D, F]
        s = spec(maybe(mesh, shape[0], "data"), maybe(mesh, shape[1], "model"))
    elif name == "w_down":  # [F, D]
        s = spec(maybe(mesh, shape[0], "model"), maybe(mesh, shape[1], "data"))
    elif name == "router":  # [D, E]
        s = spec(maybe(mesh, shape[0], "data"), None)
    elif name == "in_proj":  # mamba [D, Proj]
        s = spec(maybe(mesh, shape[0], "data"), maybe(mesh, shape[1], "model"))
    elif name == "out_proj":  # mamba [d_inner, D]
        s = spec(maybe(mesh, shape[0], "model"), maybe(mesh, shape[1], "data"))
    elif name in ("w1", "w2", "frontend_proj"):  # frontend projections
        s = spec(None, maybe(mesh, shape[1], "data"))
    elif leaf.ndim - (1 if scanned else 0) <= 1:
        s = spec(*(None,) * len(shape))  # norms, biases, A_log, ... replicate
    else:
        s = spec(*(None,) * len(shape))
    return s


def param_shardings(cfg: ArchConfig, params_tree: Any, mesh: Mesh):
    """NamedShardings matching the (possibly abstract) params pytree."""

    def one(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        return NamedSharding(mesh, _param_spec(keys, leaf, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, params_tree)


# --------------------------------------------------------------------------
# activation / batch / cache sharding
# --------------------------------------------------------------------------


def batch_shardings(cfg: ArchConfig, batch_specs: Any, mesh: Mesh):
    """Input batch: shard the leading batch dim over ('pod','data')."""

    def one(leaf):
        b = batch_axes(mesh, leaf.shape[0])
        rest = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(b, *rest))

    return jax.tree.map(one, batch_specs)


def cache_shardings(cfg: ArchConfig, cache_tree: Any, mesh: Mesh):
    """KV/SSM cache sharding (leaves have leading n_blocks axis).

    attn k/v [n, B, W, G, K]: batch over ('pod','data') if divisible else
    W over 'data'; G over 'model' if divisible.
    mamba ssm [n, B, H, N, P]: batch over ('pod','data') else H on 'model'.
    """

    def one(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        name = keys[-1]
        shape = leaf.shape[1:]  # strip n_blocks
        if name in ("k", "v"):
            b = batch_axes(mesh, shape[0])
            g = maybe(mesh, shape[2], "model")
            w = None if b is not None else maybe(mesh, shape[1], "data")
            return NamedSharding(mesh, P(None, b, w, g, None))
        if name == "pos":  # [n, 1, W]
            return NamedSharding(mesh, P(None, None, None))
        if name == "ssm":  # [n, B, H, N, P]
            b = batch_axes(mesh, shape[0])
            h = maybe(mesh, shape[1], "model")
            return NamedSharding(mesh, P(None, b, h, None, None))
        if name == "conv":  # [n, B, k-1, Ch]
            b = batch_axes(mesh, shape[0])
            ch = maybe(mesh, shape[2], "model")
            return NamedSharding(mesh, P(None, b, None, ch))
        raise ValueError(f"unknown cache leaf {keys}")

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
