"""Batched serving engine: continuous prefill + decode over a request queue.

Small-scale (CPU-runnable) but structured like a production server: a
fixed-width decode batch is continuously refilled from a pending-request
queue — each incoming request is prefilled *solo* (exact prompt length, no
padding), its KV cache scattered into a free batch row, and the decode
loop samples every live row per step, retiring rows on EOS/max-tokens and
refilling them from the queue.

Correctness properties (tests/test_serve_batched.py):

* **Batch isolation** — a request's greedy output is bit-identical whether
  it is served alone or batched with arbitrary batch-mates.  Solo prefill
  assigns true positions ``0..len(prompt)-1`` (no pad tokens ever enter a
  cache), and decode runs with *per-row* positions (`Model.decode_step`
  with a ``[B]`` pos vector): each row attends only over its own written
  slots — other rows' writes land at strictly higher slots, blocked by the
  causal mask, and contribute exactly-0.0 softmax probabilities.
* **Budget validation** — ``len(prompt) + max_new_tokens`` over
  ``max_len`` raises up front (default) or explicitly marks the request
  ``truncated`` (``overflow="truncate"``), never a silently short answer.
* **EOS exclusion** — a sampled EOS terminates the request and is *not*
  included in ``generated``.

The per-row position path needs the slot == position invariant, so the
engine rejects sliding-window (ring-buffer) configs at construction.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.model import Model


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    generated: List[int] = field(default_factory=list)
    done: bool = False
    truncated: bool = False  # budget was capped (overflow="truncate")


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_len: int = 512,
        eos_id: Optional[int] = None,
        seed: int = 0,
        batch_size: int = 8,
        overflow: str = "error",  # or "truncate"
    ):
        if cfg.sliding_window is not None:
            raise NotImplementedError(
                "ServeEngine's per-row decode positions require "
                "sliding_window=None (ring wrap breaks the slot == "
                "position invariant)"
            )
        if overflow not in ("error", "truncate"):
            raise ValueError(
                f"overflow must be 'error' or 'truncate', got {overflow!r}"
            )
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.batch_size = batch_size
        self.overflow = overflow
        self._rng = np.random.default_rng(seed)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        logits = np.asarray(logits, dtype=np.float64)
        logits[self.cfg.vocab_size :] = -1e30  # mask padded vocab
        if temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def _cache_dtype(self):
        return (
            jnp.float32 if self.cfg.dtype == "float32" else jnp.bfloat16
        )

    def _budget(self, r: Request) -> int:
        """Validated per-request token budget (satellite: no silent
        truncation).  Raises on over-budget requests unless the engine was
        built with ``overflow="truncate"``, which caps the budget and
        marks the request."""
        if not r.prompt:
            raise ValueError(f"request {r.request_id}: empty prompt")
        if r.max_new_tokens < 1:
            raise ValueError(
                f"request {r.request_id}: max_new_tokens must be >= 1"
            )
        if len(r.prompt) >= self.max_len:
            raise ValueError(
                f"request {r.request_id}: prompt length {len(r.prompt)} "
                f"leaves no room to generate within max_len={self.max_len}"
            )
        budget = r.max_new_tokens
        if len(r.prompt) + budget > self.max_len:
            if self.overflow == "error":
                raise ValueError(
                    f"request {r.request_id}: prompt ({len(r.prompt)}) + "
                    f"max_new_tokens ({budget}) exceeds "
                    f"max_len={self.max_len}; shorten the request or build "
                    f"the engine with overflow='truncate'"
                )
            budget = self.max_len - len(r.prompt)
            r.truncated = True
        return budget

    def _insert_row(self, cache, row_cache, row: int):
        """Scatter a solo-prefilled (B=1) cache into batch row ``row``.

        k/v and mamba leaves carry ``[n_blocks, B, ...]`` — the whole row
        is replaced, clearing any previous occupant.  The shared attention
        ``pos`` leaf ([n_blocks, 1, W]) merges by max: values are
        slot-index-or--1, and every row writes position == slot.
        """

        def merge(path, b, r):
            if getattr(path[-1], "key", None) == "pos":
                return jnp.maximum(b, r)
            return b.at[:, row].set(r[:, 0])

        return jax.tree_util.tree_map_with_path(merge, cache, row_cache)

    def generate(
        self, requests: List[Request], batch_size: Optional[int] = None
    ) -> Dict[int, List[int]]:
        """Serve requests to completion with continuous batch refill."""
        if not requests:
            return {}
        budgets = {i: self._budget(r) for i, r in enumerate(requests)}
        pending = deque(range(len(requests)))
        B = max(1, min(batch_size or self.batch_size, len(requests)))
        dt = self._cache_dtype()
        cache = self.model.init_cache(B, self.max_len, dtype=dt)
        row_req: List[Optional[int]] = [None] * B  # request index per row
        row_pos = np.zeros(B, dtype=np.int64)  # next write position
        tok = np.zeros((B, 1), dtype=np.int32)
        last: List[Optional[np.ndarray]] = [None] * B

        while True:
            # Refill retired/empty rows: solo prefill (exact length, true
            # positions — the padding/position-leakage fix), then scatter
            # the row cache into the batch.
            for b in range(B):
                if row_req[b] is None and pending:
                    ri = pending.popleft()
                    r = requests[ri]
                    logits, row_cache = self._prefill(
                        self.params,
                        {"tokens": jnp.asarray([r.prompt], jnp.int32)},
                        self.model.init_cache(1, self.max_len, dtype=dt),
                    )
                    cache = self._insert_row(cache, row_cache, b)
                    last[b] = np.asarray(logits)[0, 0]
                    row_req[b] = ri
                    row_pos[b] = len(r.prompt)
            live = [b for b in range(B) if row_req[b] is not None]
            if not live:
                break

            for b in live:
                ri = row_req[b]
                r = requests[ri]
                t = self._sample(last[b], r.temperature)
                if self.eos_id is not None and t == self.eos_id:
                    r.done = True  # EOS consumed, not returned
                    row_req[b] = None
                    continue
                r.generated.append(t)
                tok[b, 0] = t
                if len(r.generated) >= budgets[ri]:
                    r.done = True
                    row_req[b] = None

            if all(ri is None for ri in row_req) and not pending:
                break
            # Retired rows ride along as dummies (their stale token at a
            # clamped position): writes stay confined to their own cache
            # row and are replaced wholesale on refill.
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(tok),
                jnp.asarray(
                    np.minimum(row_pos, self.max_len - 1), jnp.int32
                ),
            )
            arr = np.asarray(logits)[:, 0, :]
            for b in range(B):
                if row_req[b] is not None:
                    last[b] = arr[b]
                    row_pos[b] += 1
        return {r.request_id: r.generated for r in requests}
