"""Batched serving engine: continuous prefill + decode over a request queue.

Small-scale (CPU-runnable) but structured like a production server:
requests are padded into a fixed decode batch, prefill fills each row's KV
cache, and the decode loop samples until EOS/max-tokens, retiring and
refilling rows as they finish.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.model import Model


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_len: int = 512,
        eos_id: Optional[int] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self._rng = np.random.default_rng(seed)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        logits = np.asarray(logits, dtype=np.float64)
        logits[self.cfg.vocab_size :] = -1e30  # mask padded vocab
        if temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a batch of requests to completion (single decode batch)."""
        B = len(requests)
        max_prompt = max(len(r.prompt) for r in requests)
        # left-pad prompts to a common length with token 0 (masked by pos 0
        # duplication being harmless for synthetic serving workloads)
        toks = np.zeros((B, max_prompt), dtype=np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt

        cache = self.model.init_cache(B, self.max_len, dtype=jnp.float32
                                      if self.cfg.dtype == "float32"
                                      else jnp.bfloat16)
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache
        )
        pos = max_prompt
        live = list(range(B))
        last = np.asarray(logits)[:, 0, :]
        while live and pos < self.max_len:
            next_tokens = np.zeros((B, 1), dtype=np.int32)
            for i in live:
                r = requests[i]
                t = self._sample(last[i], r.temperature)
                r.generated.append(t)
                next_tokens[i, 0] = t
                if (
                    (self.eos_id is not None and t == self.eos_id)
                    or len(r.generated) >= r.max_new_tokens
                ):
                    r.done = True
            live = [i for i in live if not requests[i].done]
            if not live:
                break
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(next_tokens),
                jnp.asarray(pos, jnp.int32),
            )
            last = np.asarray(logits)[:, 0, :]
            pos += 1
        return {r.request_id: r.generated for r in requests}
