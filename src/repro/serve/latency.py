"""Batched-serving latency curve: the timing bridge engine -> simulator.

The scheduling stack prices a serving replica's work with an affine
per-decode-step cost ``base + per_req * batch`` — the shape a batched
transformer decode actually has (a fixed per-step launch/readback floor
plus a per-row cost while the batch stays under the arithmetic-intensity
knee).  :func:`calibrate` measures that curve from a live
:class:`~repro.serve.engine.ServeEngine` (timed decode steps at several
batch sizes, least-squares fit), so the simulator's request lane runs on
an engine-derived curve, not an invented constant.

``DEFAULT_SERVE_MODEL`` is the committed calibration artifact (see the
constants' comment for provenance) — the default service-time curve a
:class:`~repro.core.scenario.RequestStream` carries when the scenario
author doesn't override it.  Like the benchmark baselines it is refreshed
by re-running the calibration, not edited by hand.

This module is importable without jax (the scheduling stack and the
numpy-only CI serve gate read the committed curve); only
:func:`calibrate` touches the engine.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class BatchLatencyModel:
    """Affine decode-step latency: ``step_time(b) = base + per_req * b``.

    ``base``/``per_req`` are seconds per decode *step*; a request costs
    ``tokens_per_request`` steps, so a batch of ``b`` requests occupies
    its replica for ``service_time(b) = tokens_per_request * step_time(b)``
    seconds and sustains ``throughput(b) = b / service_time(b)``
    requests/s.
    """

    base: float
    per_req: float
    tokens_per_request: int = 32

    def __post_init__(self) -> None:
        if not (self.base >= 0.0 and math.isfinite(self.base)):
            raise ValueError(f"base must be finite >= 0, got {self.base}")
        if not (self.per_req > 0.0 and math.isfinite(self.per_req)):
            raise ValueError(
                f"per_req must be finite > 0, got {self.per_req}"
            )
        if self.tokens_per_request < 1:
            raise ValueError(
                f"tokens_per_request must be >= 1, got "
                f"{self.tokens_per_request}"
            )

    def step_time(self, batch: int) -> float:
        """Seconds for one decode step over a batch of ``batch`` rows."""
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        return self.base + self.per_req * batch

    def service_time(self, batch: int) -> float:
        """Seconds to serve a batch of ``batch`` requests to completion."""
        return self.tokens_per_request * self.step_time(batch)

    def throughput(self, batch: int) -> float:
        """Sustained requests/s of one replica at batch size ``batch``."""
        return batch / self.service_time(batch)

    @property
    def batch_base(self) -> float:
        """Per-batch fixed cost in seconds (the RequestStream ``svc_base``
        default): the step floor over a full request's decode."""
        return self.tokens_per_request * self.base

    @property
    def batch_per_req(self) -> float:
        """Per-request marginal cost in seconds (``svc_per_req``)."""
        return self.tokens_per_request * self.per_req


def calibrate(
    engine,
    batch_sizes: Sequence[int] = (1, 8, 32, 128),
    steps: int = 24,
    tokens_per_request: int = 32,
) -> BatchLatencyModel:
    """Fit the affine decode-step curve from a live ``ServeEngine``.

    For each batch size: build a fresh cache, run one untimed decode step
    (jit compile for that batch shape), then time ``steps`` further steps
    and take the mean.  The (batch, latency) samples are least-squares
    fit to ``base + per_req * batch``; a fit driven under the noise floor
    is clamped so the curve stays increasing.  ``engine.max_len`` must
    exceed ``steps`` (every step writes the next cache slot).
    """
    import jax.numpy as jnp  # deferred: only calibration needs the engine

    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if engine.max_len <= steps:
        raise ValueError(
            f"max_len={engine.max_len} must exceed steps={steps}"
        )
    lat = []
    dt = engine._cache_dtype()
    for b in batch_sizes:
        cache = engine.model.init_cache(b, engine.max_len, dtype=dt)
        tok = jnp.zeros((b, 1), jnp.int32)
        logits, cache = engine._decode(
            engine.params, cache, tok, jnp.zeros(b, jnp.int32)
        )
        logits.block_until_ready()  # compile outside the timed window
        t0 = time.perf_counter()
        for i in range(1, steps + 1):
            logits, cache = engine._decode(
                engine.params, cache, tok, jnp.full((b,), i, jnp.int32)
            )
        logits.block_until_ready()
        lat.append((time.perf_counter() - t0) / steps)
    bs = np.asarray(batch_sizes, dtype=np.float64)
    ys = np.asarray(lat, dtype=np.float64)
    design = np.stack([np.ones_like(bs), bs], axis=1)
    (base, per_req), *_ = np.linalg.lstsq(design, ys, rcond=None)
    return BatchLatencyModel(
        base=max(float(base), 0.0),
        per_req=max(float(per_req), 1e-9),
        tokens_per_request=tokens_per_request,
    )


# Committed calibration artifact: `calibrate(ServeEngine(reduced_config(
# "deepseek-7b"), params, max_len=64))` on the reference container (CPU
# jax, reduced config) — measured base=4.21e-4, per_req=4.43e-5.
# Refresh by re-running the calibration (see benchmarks/README.md,
# "--serve"), not by hand-editing.
DEFAULT_SERVE_MODEL = BatchLatencyModel(
    base=4.2e-4, per_req=4.4e-5, tokens_per_request=32
)
