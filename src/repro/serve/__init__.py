"""serve subpackage."""
