"""detlint: an AST-based determinism & invariant linter for the scheduling core.

Every CI gate in this repo ultimately rests on one property: schedule
sha256s are bit-identical across runs, machines, and the
fleet/streaming/serve fast paths.  The conventions that make that hold
are enforced dynamically (golden fixtures, fleet digest checks) — a
regression is found only *after* a golden fails.  detlint checks the
conventions statically, at lint time:

=======  ==============================================================
rule     convention it guards
=======  ==============================================================
DET001   no observable iteration over ``set``/``frozenset`` (or a
         dict built from one via ``dict.fromkeys``): set order is
         hash/ASLR-dependent and must never feed ordering-sensitive
         sinks (heap pushes, list materialization, schedule
         construction).  Wrap in ``sorted()`` with a total key.
DET002   no unseeded or global-state RNG: ``random.*``,
         ``np.random.<fn>`` convenience calls, and bare
         ``default_rng()`` are banned — core code draws from explicit
         ``default_rng([seed, ...])`` substreams.
DET003   no wall-clock reads (``time.time``/``perf_counter``/
         ``datetime.now`` ...) inside simulator/policy logic: the core
         is virtual-time-only.  Reporting-only instrumentation (the
         ``wall_s`` sites) is allowlisted in ``[tool.detlint]``.
DET004   no unordered filesystem enumeration (``os.listdir``,
         ``glob.glob``, ``Path.iterdir`` ...) without ``sorted()``:
         directory order is filesystem-dependent.
DET005   no plain ``sum()``/``+=`` float accumulation inside
         digest-bearing scopes (config ``digest_scopes`` or an inline
         ``# detlint: digest-path`` marker): use ``math.fsum`` or the
         Shewchuk-partials helpers so streaming == materialized.
DET006   no ``id()``/``hash()`` as a sort or grouping key: CPython
         object ids are allocation-order- and ASLR-dependent.
DET007   bounded-cache eviction (``.popitem()``) changes *which*
         entries are recomputed; it is digest-safe only when
         recomputation is bit-identical to the cached value — document
         that with a ``skip`` reason at the site.
=======  ==============================================================

(POL001/POL002 — SchedulingPolicy dispatch contract and
frozen-dataclass mutation — ride the same walker; see
``repro.analysis.policy_rules``.)

Suppressions and markers
------------------------
``# detlint: skip=DET003(reason)`` on the finding's line (or on a
comment-only line immediately above it) suppresses that rule there; the
reason is mandatory — a bare ``skip=DET003`` or empty parens is itself
a finding (DET900).  Multiple directives:
``# detlint: skip=DET001(why), DET004(why)``.  ``# detlint:
digest-path`` on (or directly above) a ``def``/``class`` line marks the
scope digest-bearing for DET005.

Configuration (``[tool.detlint]`` in pyproject.toml)
----------------------------------------------------
``paths``/``exclude``/``ignore``/``select`` scope the run;
``[tool.detlint.det005] digest_scopes`` lists ``path::qualname``
digest-bearing scopes; ``[tool.detlint.per_rule_exclude]`` maps rule id
-> file globs; ``[[tool.detlint.allow]]`` entries (``rule``, ``path``,
optional ``context`` = enclosing def/class name, mandatory ``reason``)
form the structured allowlist — matching findings are reported as
allowed, not failures.  Python 3.11+ parses the file with ``tomllib``;
on 3.10 a strict mini-parser reads only the ``tool.detlint`` sections
(anything it cannot parse there fails loudly).

CLI
---
``python -m repro.analysis.detlint [paths] [--format=text|json|github]``
Exit codes are stable: 0 = no unsuppressed findings, 1 = unsuppressed
findings (or malformed suppressions), 2 = usage/config error.
"""

from __future__ import annotations

import argparse
import ast
import fnmatch
import io
import json
import re
import sys
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "AllowEntry",
    "Config",
    "Finding",
    "Report",
    "Rule",
    "all_rules",
    "lint_paths",
    "load_config",
    "main",
    "register",
]


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclass
class Finding:
    """One rule violation at ``path:line:col`` (1-based line, 0-based col,
    matching ``ast`` and the GitHub annotation format)."""

    rule: str
    path: str  # config-root-relative posix path (or the path as given)
    line: int
    col: int
    message: str
    hint: str = ""
    qualname: str = ""  # enclosing def/class chain, e.g. "SimResult.add"
    suppressed: bool = False
    suppression: str = ""  # "inline" | "allowlist" | ""
    reason: str = ""

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "hint": self.hint,
            "qualname": self.qualname,
            "suppressed": self.suppressed,
            "suppression": self.suppression,
            "reason": self.reason,
        }


@dataclass
class Report:
    """Everything one ``lint_paths`` run produced."""

    findings: List[Finding]
    n_files: int

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def failed(self) -> bool:
        return bool(self.unsuppressed)


class UsageError(Exception):
    """Bad CLI arguments or configuration — exit code 2, never 1."""


# ---------------------------------------------------------------------------
# Inline directives: suppressions and markers
# ---------------------------------------------------------------------------

_DIRECTIVE_LINE = re.compile(r"#\s*detlint:\s*(?P<body>.*)$")
_SKIP_DIRECTIVE = re.compile(
    r"skip=(?P<rule>[A-Z]+\d+)\s*(?:\(\s*(?P<reason>[^()]*?)\s*\))?"
)
_MARKERS = frozenset({"digest-path"})

# Engine-level pseudo-rule for malformed/unrecognized directives: a
# suppression that cannot be parsed must fail the run, not silently
# suppress nothing.
DET900 = "DET900"
_DET900_SUMMARY = "malformed or unrecognized `# detlint:` directive"
_DET900_HINT = (
    "write `# detlint: skip=RULEID(reason)` — the reason is mandatory — "
    "or the scope marker `# detlint: digest-path`"
)


def _iter_comments(source: str) -> Iterator[Tuple[int, str]]:
    """Yield ``(lineno, text)`` for every real comment token — directives
    inside string literals/docstrings (e.g. this linter documenting its
    own syntax) must not parse as directives."""
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except tokenize.TokenizeError:  # pragma: no cover - ast.parse passed
        return


def _parse_directives(
    source: str,
) -> Tuple[Dict[int, Dict[str, str]], Dict[int, str], List[Tuple[int, str]]]:
    """Scan source comments for ``# detlint:`` directives.

    Returns ``(skips, markers, errors)``: ``skips`` maps lineno ->
    {rule: reason}, ``markers`` maps lineno -> marker name, ``errors``
    lists ``(lineno, message)`` for malformed directives.
    """
    skips: Dict[int, Dict[str, str]] = {}
    markers: Dict[int, str] = {}
    errors: List[Tuple[int, str]] = []
    for lineno, text in _iter_comments(source):
        m = _DIRECTIVE_LINE.search(text)
        if m is None:
            continue
        body = m.group("body").strip()
        if body in _MARKERS:
            markers[lineno] = body
            continue
        found = list(_SKIP_DIRECTIVE.finditer(body))
        if not found or not body.startswith("skip="):
            errors.append(
                (lineno, f"unrecognized detlint directive {body!r}")
            )
            continue
        per_line: Dict[str, str] = {}
        for d in found:
            rule, reason = d.group("rule"), d.group("reason")
            if reason is None or not reason.strip():
                errors.append(
                    (
                        lineno,
                        f"suppression for {rule} is missing its mandatory "
                        f"reason — write skip={rule}(why this is safe)",
                    )
                )
                continue
            per_line[rule] = reason.strip()
        if per_line:
            skips[lineno] = per_line
    return skips, markers, errors


# ---------------------------------------------------------------------------
# Per-module context shared by all rules
# ---------------------------------------------------------------------------

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the dotted module path they were imported as.

    ``import numpy as np`` -> ``{"np": "numpy"}``; ``from numpy import
    random as npr`` -> ``{"npr": "numpy.random"}``; ``from time import
    perf_counter`` -> ``{"perf_counter": "time.perf_counter"}``.  Only
    absolute imports are tracked — the banned modules (time, random,
    numpy, os, glob, datetime) are never relative.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".", 1)[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


class ModuleContext:
    """One linted file: source, AST, parent links, alias map, config."""

    def __init__(
        self, path: Path, rel_path: str, source: str, tree: ast.Module,
        config: "Config",
    ) -> None:
        self.path = path
        self.rel_path = rel_path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.config = config
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.aliases = _import_aliases(tree)
        self.skips, self.markers, self.directive_errors = _parse_directives(
            source
        )

    # -- structure queries ---------------------------------------------------

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(node)

    def qualname(self, node: ast.AST) -> str:
        """Enclosing def/class chain of ``node`` (excluding ``node``
        itself unless it is nested), e.g. ``"SimResult.add"``."""
        parts: List[str] = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, _SCOPE_NODES):
                parts.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(parts))

    def enclosing_function(
        self, node: ast.AST
    ) -> Optional[ast.FunctionDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def has_marker(self, node: ast.AST, marker: str) -> bool:
        """True when ``marker`` sits on the node's first line or on the
        line directly above it (the conventional spot above a ``def``)."""
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return False
        return (
            self.markers.get(lineno) == marker
            or self.markers.get(lineno - 1) == marker
        )

    # -- name resolution -----------------------------------------------------

    def resolve_call(self, func: ast.AST) -> Optional[str]:
        """Dotted path of a call target *through the import aliases* —
        ``None`` when the root is a local name (so ``rng.random()`` on a
        Generator instance never resolves to ``random.random``)."""
        parts: List[str] = []
        node = func
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id)
        if base is None:
            return None
        parts.append(base)
        return ".".join(reversed(parts))

    def raw_name(self, func: ast.AST) -> Optional[str]:
        """Bare callable name for builtins (``sorted``, ``list`` ...)."""
        if isinstance(func, ast.Name):
            return func.id
        return None

    def consumer_call(self, node: ast.AST) -> Optional[str]:
        """Name of the call consuming ``node`` as a direct argument
        (``sorted`` for ``sorted(<node>)``), else None."""
        parent = self.parents.get(node)
        if isinstance(parent, ast.Call) and any(
            arg is node for arg in parent.args
        ):
            return self.raw_name(parent.func) or self.resolve_call(
                parent.func
            )
        return None


# ---------------------------------------------------------------------------
# Rule base + registry
# ---------------------------------------------------------------------------


class Rule:
    """One lint rule.  Subclasses set ``id``/``summary``/``hint`` and the
    AST ``node_types`` they want dispatched; ``visit`` yields ``(node,
    message)`` pairs.  ``begin_module`` runs once per file for rules
    needing a module-level pre-analysis (symbol tables etc.)."""

    id: str = ""
    summary: str = ""
    hint: str = ""
    node_types: Tuple[type, ...] = ()

    def begin_module(self, ctx: ModuleContext) -> None:  # pragma: no cover
        pass

    def visit(
        self, node: ast.AST, ctx: ModuleContext
    ) -> Iterator[Tuple[ast.AST, str]]:
        raise NotImplementedError


_REGISTRY: Dict[str, type] = {}


def register(cls: type) -> type:
    """Class decorator adding a Rule to the global registry (the plug-in
    point: any module may register rules before ``lint_paths`` runs)."""
    if not issubclass(cls, Rule) or not cls.id:
        raise TypeError(f"{cls!r} is not a Rule with an id")
    if cls.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id}")
    _REGISTRY[cls.id] = cls
    return cls


def all_rules() -> Dict[str, type]:
    """All registered rules (importing the sibling passes first)."""
    from . import policy_rules  # noqa: F401  (registers POL001/POL002)

    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# DET001 — unordered-container iteration
# ---------------------------------------------------------------------------

# Consumers whose result cannot observe iteration order.  ``sum`` is
# order-insensitive only for exact (int-like) elements — float addition
# rounds per add, so Det001SetIteration gates it on _int_like.
_ORDER_INSENSITIVE_CONSUMERS = frozenset(
    {"set", "frozenset", "len", "any", "all", "min", "max", "sum", "sorted"}
)
_SET_ANNOTATIONS = frozenset(
    {"set", "frozenset", "Set", "FrozenSet", "AbstractSet", "MutableSet"}
)


def _is_set_ctor(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    )


def _annotation_is_set(node: ast.AST) -> bool:
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):  # typing.Set etc.
        return node.attr in _SET_ANNOTATIONS
    return isinstance(node, ast.Name) and node.id in _SET_ANNOTATIONS


def _ref_key(node: ast.AST) -> Optional[str]:
    """Tracking key for a name: ``"x"`` or ``"self.x"``."""
    if isinstance(node, ast.Name):
        return node.id
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return f"self.{node.attr}"
    return None


@register
class Det001SetIteration(Rule):
    id = "DET001"
    summary = "iteration over an unordered set (or set-built dict)"
    hint = (
        "wrap in sorted() with a total, value-based key (or restructure "
        "so the order is never observable)"
    )
    node_types = (ast.For, ast.ListComp, ast.GeneratorExp, ast.Call)

    def begin_module(self, ctx: ModuleContext) -> None:
        # Flow-insensitive symbol table: any name (or self-attribute)
        # ever bound to a set constructor — or annotated as a set — is
        # treated as set-typed everywhere in the module.  Second phase
        # picks up dicts built from a tracked set via dict.fromkeys.
        tracked: set = set()
        assigns: List[Tuple[ast.AST, Optional[ast.AST]]] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    assigns.append((tgt, node.value))
            elif isinstance(node, ast.AnnAssign):
                key = _ref_key(node.target)
                if key and _annotation_is_set(node.annotation):
                    tracked.add(key)
                if node.value is not None:
                    assigns.append((node.target, node.value))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for arg in (
                    *args.posonlyargs, *args.args, *args.kwonlyargs
                ):
                    if arg.annotation is not None and _annotation_is_set(
                        arg.annotation
                    ):
                        tracked.add(arg.arg)
        for tgt, value in assigns:
            key = _ref_key(tgt)
            if key and value is not None and _is_set_ctor(value):
                tracked.add(key)
        for tgt, value in assigns:  # dict.fromkeys(<tracked set>)
            key = _ref_key(tgt)
            if (
                key
                and isinstance(value, ast.Call)
                and isinstance(value.func, ast.Attribute)
                and value.func.attr == "fromkeys"
                and isinstance(value.func.value, ast.Name)
                and value.func.value.id == "dict"
                and value.args
                and self._unordered_expr(value.args[0], tracked)
            ):
                tracked.add(key)
        self._tracked = tracked

    @staticmethod
    def _unordered_expr(node: ast.AST, tracked: set) -> bool:
        if _is_set_ctor(node):
            return True
        key = _ref_key(node)
        if key is not None and key in tracked:
            return True
        # s.keys()/.values()/.items() of a tracked (set-built) dict, or
        # .keys() of a tracked set-typed mapping-like name
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("keys", "values", "items")
            and not node.args
        ):
            inner = _ref_key(node.func.value)
            return inner is not None and inner in tracked
        return False

    def _unordered(self, node: ast.AST) -> bool:
        return self._unordered_expr(node, self._tracked)

    def visit(self, node, ctx):
        if isinstance(node, ast.For):
            if self._unordered(node.iter):
                yield node.iter, (
                    "for-loop iterates an unordered set: iteration order "
                    "is hash- and ASLR-dependent"
                )
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            consumer = ctx.consumer_call(node)
            if consumer in _ORDER_INSENSITIVE_CONSUMERS and (
                consumer != "sum" or _int_like(node.elt)
            ):
                return
            kind = (
                "list comprehension"
                if isinstance(node, ast.ListComp)
                else "generator"
            )
            for gen in node.generators:
                if self._unordered(gen.iter):
                    yield gen.iter, (
                        f"{kind} materializes unordered set iteration "
                        "into an ordered sequence"
                    )
        elif isinstance(node, ast.Call):
            name = ctx.raw_name(node.func)
            if name in ("list", "tuple", "enumerate") and node.args:
                if self._unordered(node.args[0]):
                    if ctx.consumer_call(node) == "sorted":
                        return
                    yield node, (
                        f"{name}() materializes unordered set iteration "
                        "into an ordered sequence"
                    )


# ---------------------------------------------------------------------------
# DET002 — unseeded / global-state RNG
# ---------------------------------------------------------------------------

# Explicit-state constructors under numpy.random that are fine to call.
_NP_RANDOM_OK = frozenset(
    {
        "default_rng",
        "Generator",
        "SeedSequence",
        "BitGenerator",
        "PCG64",
        "PCG64DXSM",
        "Philox",
        "MT19937",
        "SFC64",
    }
)


@register
class Det002GlobalRng(Rule):
    id = "DET002"
    summary = "unseeded or global-state RNG"
    hint = (
        "draw from an explicit numpy substream: "
        "rng = np.random.default_rng([seed, ...]); rng.<fn>(...)"
    )
    node_types = (ast.Call,)

    def visit(self, node, ctx):
        resolved = ctx.resolve_call(node.func)
        if resolved is None:
            return
        if resolved == "numpy.random.default_rng":
            if not node.args and not node.keywords:
                yield node, (
                    "bare default_rng() is OS-entropy-seeded: every run "
                    "draws a different stream"
                )
            return
        if resolved.startswith("numpy.random."):
            leaf = resolved.rsplit(".", 1)[1]
            if leaf not in _NP_RANDOM_OK:
                yield node, (
                    f"np.random.{leaf}() uses numpy's hidden global "
                    "RandomState: call order anywhere in the process "
                    "shifts the draws"
                )
            return
        if resolved == "random" or resolved.startswith("random."):
            leaf = resolved.rsplit(".", 1)[1] if "." in resolved else resolved
            if leaf == "Random":
                return  # explicit seeded instance is fine
            yield node, (
                f"random.{leaf}() uses the stdlib global (or OS-entropy) "
                "RNG state"
            )


# ---------------------------------------------------------------------------
# DET003 — wall-clock reads
# ---------------------------------------------------------------------------

_WALL_CLOCK = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.process_time",
        "time.process_time_ns",
        "time.clock_gettime",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.date.today",
    }
)


@register
class Det003WallClock(Rule):
    id = "DET003"
    summary = "wall-clock read inside virtual-time core code"
    hint = (
        "the core is virtual-time-only — thread simulated time through; "
        "reporting-only instrumentation belongs in the [tool.detlint] "
        "allowlist with a reason"
    )
    node_types = (ast.Call,)

    def visit(self, node, ctx):
        resolved = ctx.resolve_call(node.func)
        if resolved in _WALL_CLOCK:
            yield node, (
                f"{resolved}() reads the wall clock: results become "
                "machine- and load-dependent"
            )


# ---------------------------------------------------------------------------
# DET004 — unordered filesystem enumeration
# ---------------------------------------------------------------------------

_FS_ENUM = frozenset(
    {"os.listdir", "os.scandir", "os.walk", "glob.glob", "glob.iglob"}
)
_FS_ENUM_METHODS = frozenset({"iterdir", "glob", "rglob"})


@register
class Det004FsOrder(Rule):
    id = "DET004"
    summary = "unordered filesystem enumeration"
    hint = "wrap the enumeration in sorted(): directory order is fs-dependent"
    node_types = (ast.Call,)

    def visit(self, node, ctx):
        resolved = ctx.resolve_call(node.func)
        name = None
        if resolved in _FS_ENUM:
            name = resolved
        elif (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _FS_ENUM_METHODS
            and resolved is None  # Path-like instance method
        ):
            name = f".{node.func.attr}"
        if name is None:
            return
        if ctx.consumer_call(node) == "sorted":
            return
        # also fine when it feeds a comprehension that sorted() consumes
        parent = ctx.parent(node)
        if (
            isinstance(parent, ast.comprehension)
            and ctx.consumer_call(ctx.parent(parent)) == "sorted"
        ):
            return
        yield node, (
            f"{name}() yields entries in filesystem order, which differs "
            "across machines and runs"
        )


# ---------------------------------------------------------------------------
# DET005 — naive float accumulation in digest-bearing scopes
# ---------------------------------------------------------------------------


def _int_like(node: ast.AST) -> bool:
    """Expressions that cannot introduce float rounding: int literals,
    len() calls, and unary +/- of those (counters, not accumulators)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and not isinstance(
            node.value, bool
        )
    if isinstance(node, ast.UnaryOp):
        return _int_like(node.operand)
    if isinstance(node, ast.Call):
        return isinstance(node.func, ast.Name) and node.func.id == "len"
    return False


@register
class Det005FloatAccumulation(Rule):
    id = "DET005"
    summary = "plain float accumulation in a digest-bearing scope"
    hint = (
        "use math.fsum / the Shewchuk-partials helpers (_msum_add) so the "
        "aggregate is an order-independent correctly-rounded sum"
    )
    node_types = (ast.Call, ast.AugAssign)

    def _in_digest_scope(self, node: ast.AST, ctx: ModuleContext) -> bool:
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, _SCOPE_NODES) and ctx.has_marker(
                cur, "digest-path"
            ):
                return True
            cur = ctx.parents.get(cur)
        qn = ctx.qualname(node)
        for scope in ctx.config.digest_scopes:
            path_pat, _, qual = scope.partition("::")
            if not fnmatch.fnmatch(ctx.rel_path, path_pat):
                continue
            if not qual or qn == qual or qn.startswith(qual + "."):
                return True
        return False

    def visit(self, node, ctx):
        if isinstance(node, ast.Call):
            if ctx.raw_name(node.func) != "sum" or not node.args:
                return
            arg = node.args[0]
            if isinstance(arg, (ast.GeneratorExp, ast.ListComp)) and _int_like(
                arg.elt
            ):
                return  # sum(1 for ...) / sum(len(x) for ...): a counter
            if all(_int_like(a) for a in node.args):
                return
            if self._in_digest_scope(node, ctx):
                yield node, (
                    "builtin sum() accumulates left-to-right with per-add "
                    "rounding: the result depends on operand order"
                )
        else:  # AugAssign
            if not isinstance(node.op, ast.Add):
                return
            if _int_like(node.value):
                return  # += 1 style counters are exact
            if self._in_digest_scope(node, ctx):
                yield node, (
                    "+= float accumulation rounds per add: fold through "
                    "Shewchuk partials instead"
                )


# ---------------------------------------------------------------------------
# DET006 — id()/hash() as sort or grouping key
# ---------------------------------------------------------------------------

_KEYED_CALLABLES = frozenset(
    {"sorted", "min", "max", "nsmallest", "nlargest", "groupby", "sort"}
)


def _contains_id_or_hash(node: ast.AST) -> Optional[str]:
    for sub in ast.walk(node):
        if (
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Name)
            and sub.func.id in ("id", "hash")
        ):
            return sub.func.id
    if isinstance(node, ast.Name) and node.id in ("id", "hash"):
        return node.id  # key=id / key=hash passed directly
    return None


@register
class Det006IdentityKey(Rule):
    id = "DET006"
    summary = "id()/hash() used as a sort or grouping key"
    hint = (
        "key on a stable value (job_id, name, tuple of fields): object "
        "ids are allocation-order- and ASLR-dependent"
    )
    node_types = (ast.Call, ast.Subscript)

    def visit(self, node, ctx):
        if isinstance(node, ast.Call):
            name = ctx.raw_name(node.func)
            if name is None and isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name not in _KEYED_CALLABLES:
                return
            for kw in node.keywords:
                if kw.arg == "key":
                    fn = _contains_id_or_hash(kw.value)
                    if fn:
                        yield kw.value, (
                            f"{name}(key=...{fn}()...) orders by object "
                            "identity, which varies across runs"
                        )
        else:  # Subscript: d[id(x)] grouping
            sl = node.slice
            if (
                isinstance(sl, ast.Call)
                and isinstance(sl.func, ast.Name)
                and sl.func.id in ("id", "hash")
            ):
                yield node, (
                    f"container keyed by {sl.func.id}(): entry identity "
                    "varies across runs"
                )


# ---------------------------------------------------------------------------
# DET007 — bounded-cache eviction
# ---------------------------------------------------------------------------


@register
class Det007CacheEviction(Rule):
    id = "DET007"
    summary = "bounded-cache eviction (.popitem()) in schedule-feeding code"
    hint = (
        "eviction changes which entries are recomputed — digest-safe only "
        "when recomputation is bit-identical to the cached value; document "
        "that with a skip=DET007(reason) at the site"
    )
    node_types = (ast.Call,)

    def visit(self, node, ctx):
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "popitem"
        ):
            yield node, (
                "cache eviction via popitem(): safe only if a later "
                "recomputation reproduces the evicted entry byte for byte"
            )


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclass
class AllowEntry:
    """One structured-allowlist entry from ``[[tool.detlint.allow]]``."""

    rule: str
    path: str  # fnmatch glob over config-root-relative posix paths
    reason: str
    context: str = ""  # enclosing def/class name; "" matches anywhere

    def matches(self, finding: Finding) -> bool:
        if finding.rule != self.rule:
            return False
        if not fnmatch.fnmatch(finding.path, self.path):
            return False
        if not self.context:
            return True
        return self.context in finding.qualname.split(".")


@dataclass
class Config:
    root: Path = field(default_factory=Path.cwd)
    paths: List[str] = field(default_factory=list)
    exclude: List[str] = field(default_factory=list)
    ignore: List[str] = field(default_factory=list)
    select: List[str] = field(default_factory=list)
    per_rule_exclude: Dict[str, List[str]] = field(default_factory=dict)
    digest_scopes: List[str] = field(default_factory=list)
    allow: List[AllowEntry] = field(default_factory=list)


# -- TOML loading -----------------------------------------------------------


def _strip_toml_comment(line: str) -> str:
    out: List[str] = []
    quote = ""
    escaped = False
    for ch in line:
        if escaped:
            out.append(ch)
            escaped = False
            continue
        if quote == '"' and ch == "\\":
            out.append(ch)
            escaped = True
            continue
        if quote:
            if ch == quote:
                quote = ""
        elif ch in ('"', "'"):
            quote = ch
        elif ch == "#":
            break
        out.append(ch)
    return "".join(out)


_TOML_STRING = re.compile(r'"((?:[^"\\]|\\.)*)"|\'([^\']*)\'')


def _toml_unescape(s: str) -> str:
    return (
        s.replace("\\\\", "\x00")
        .replace('\\"', '"')
        .replace("\\n", "\n")
        .replace("\\t", "\t")
        .replace("\x00", "\\")
    )


def _split_toml_array(inner: str) -> List[str]:
    """Split array body on top-level commas, respecting quoted strings."""
    parts: List[str] = []
    buf: List[str] = []
    quote = ""
    escaped = False
    for ch in inner:
        if escaped:
            buf.append(ch)
            escaped = False
            continue
        if quote == '"' and ch == "\\":
            buf.append(ch)
            escaped = True
            continue
        if quote:
            buf.append(ch)
            if ch == quote:
                quote = ""
        elif ch in ('"', "'"):
            quote = ch
            buf.append(ch)
        elif ch == ",":
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    parts.append("".join(buf))
    return parts


def _parse_toml_value(text: str) -> object:
    text = text.strip()
    if text.startswith("["):
        if not text.endswith("]"):
            raise UsageError(
                f"unterminated array in [tool.detlint] config: {text!r}"
            )
        vals: List[str] = []
        for part in _split_toml_array(text[1:-1]):
            part = part.strip()
            if not part:  # trailing comma
                continue
            m = _TOML_STRING.fullmatch(part)
            if m is None:
                raise UsageError(
                    f"unsupported TOML array element in [tool.detlint] "
                    f"config: {part!r} (the 3.10 mini-parser supports "
                    "string arrays only)"
                )
            vals.append(
                _toml_unescape(m.group(1)) if m.group(1) is not None
                else m.group(2)
            )
        return vals
    m = _TOML_STRING.fullmatch(text)
    if m:
        return (
            _toml_unescape(m.group(1)) if m.group(1) is not None
            else m.group(2)
        )
    if text in ("true", "false"):
        return text == "true"
    if re.fullmatch(r"[+-]?\d+", text):
        return int(text)
    raise UsageError(
        f"unsupported TOML value in [tool.detlint] config: {text!r} "
        "(the 3.10 mini-parser reads strings, string arrays, booleans "
        "and integers)"
    )


def _parse_detlint_toml(text: str) -> Dict[str, object]:
    """Strict mini-parser for the ``tool.detlint`` sections of a
    pyproject.toml (the Python 3.10 fallback when ``tomllib`` is
    absent).  Sections outside ``tool.detlint`` are skipped verbatim;
    unsupported constructs *inside* it fail loudly."""
    root: Dict[str, object] = {}
    cur: Optional[Dict[str, object]] = None
    pending_key: Optional[str] = None
    pending_val = ""

    def open_section(name: str, is_array: bool) -> Optional[Dict[str, object]]:
        if name != "tool.detlint" and not name.startswith("tool.detlint."):
            return None
        node: Dict[str, object] = root
        parts = name.split(".")
        for part in parts[:-1]:
            node = node.setdefault(part, {})  # type: ignore[assignment]
        leaf = parts[-1]
        if is_array:
            lst = node.setdefault(leaf, [])
            if not isinstance(lst, list):
                raise UsageError(f"[[{name}]] conflicts with earlier table")
            entry: Dict[str, object] = {}
            lst.append(entry)
            return entry
        tbl = node.setdefault(leaf, {})
        if not isinstance(tbl, dict):
            raise UsageError(f"[{name}] conflicts with earlier array")
        return tbl

    for raw in text.splitlines():
        line = _strip_toml_comment(raw).strip()
        if pending_key is not None:
            pending_val += " " + line
            if pending_val.count("[") <= pending_val.count("]"):
                assert cur is not None
                cur[pending_key] = _parse_toml_value(pending_val)
                pending_key = None
                pending_val = ""
            continue
        if not line:
            continue
        if line.startswith("[["):
            cur = open_section(line.strip("[]").strip(), is_array=True)
            continue
        if line.startswith("["):
            cur = open_section(line.strip("[]").strip(), is_array=False)
            continue
        if cur is None:
            continue
        key, eq, value = line.partition("=")
        if not eq:
            raise UsageError(
                f"unparseable line in [tool.detlint] config: {raw!r}"
            )
        key = key.strip().strip('"')
        value = value.strip()
        if value.startswith("[") and value.count("[") > value.count("]"):
            pending_key, pending_val = key, value
            continue
        cur[key] = _parse_toml_value(value)
    if pending_key is not None:
        raise UsageError(
            f"unterminated array for key {pending_key!r} in [tool.detlint]"
        )
    return root


def _load_toml(path: Path) -> Dict[str, object]:
    try:
        import tomllib  # Python 3.11+
    except ImportError:  # pragma: no cover - exercised on 3.10 only
        return _parse_detlint_toml(path.read_text(encoding="utf-8"))
    with path.open("rb") as fh:
        return tomllib.load(fh)


_KNOWN_KEYS = frozenset(
    {
        "paths",
        "exclude",
        "ignore",
        "select",
        "allow",
        "det005",
        "per_rule_exclude",
    }
)


def _str_list(section: Dict[str, object], key: str) -> List[str]:
    val = section.get(key, [])
    if not isinstance(val, list) or not all(
        isinstance(v, str) for v in val
    ):
        raise UsageError(f"[tool.detlint] {key} must be a list of strings")
    return list(val)


def config_from_dict(data: Dict[str, object], root: Path) -> Config:
    """Build (and strictly validate) a :class:`Config` from parsed
    pyproject data.  Unknown keys and reason-less allow entries fail
    loudly — a typo must never silently disable a gate."""
    section = data.get("tool", {})
    section = section.get("detlint", {}) if isinstance(section, dict) else {}
    if not isinstance(section, dict):
        raise UsageError("[tool.detlint] must be a table")
    unknown = sorted(set(section) - _KNOWN_KEYS)
    if unknown:
        raise UsageError(
            f"unknown [tool.detlint] key(s): {', '.join(unknown)} "
            f"(known: {', '.join(sorted(_KNOWN_KEYS))})"
        )
    known_rules = set(all_rules()) | {DET900}

    def check_rules(ids: Iterable[str], where: str) -> None:
        bad = sorted(set(ids) - known_rules)
        if bad:
            raise UsageError(
                f"unknown rule id(s) in {where}: {', '.join(bad)}"
            )

    cfg = Config(
        root=root,
        paths=_str_list(section, "paths"),
        exclude=_str_list(section, "exclude"),
        ignore=_str_list(section, "ignore"),
        select=_str_list(section, "select"),
    )
    check_rules(cfg.ignore, "ignore")
    check_rules(cfg.select, "select")

    det005 = section.get("det005", {})
    if not isinstance(det005, dict) or set(det005) - {"digest_scopes"}:
        raise UsageError(
            "[tool.detlint.det005] supports exactly one key: digest_scopes"
        )
    cfg.digest_scopes = _str_list(det005, "digest_scopes")

    pre = section.get("per_rule_exclude", {})
    if not isinstance(pre, dict):
        raise UsageError("[tool.detlint.per_rule_exclude] must be a table")
    check_rules(pre, "per_rule_exclude")
    for rule_id, globs in pre.items():
        if not isinstance(globs, list) or not all(
            isinstance(g, str) for g in globs
        ):
            raise UsageError(
                f"per_rule_exclude.{rule_id} must be a list of globs"
            )
        cfg.per_rule_exclude[rule_id] = list(globs)

    allow = section.get("allow", [])
    if not isinstance(allow, list):
        raise UsageError("[[tool.detlint.allow]] must be an array of tables")
    for i, entry in enumerate(allow):
        if not isinstance(entry, dict) or set(entry) - {
            "rule",
            "path",
            "context",
            "reason",
        }:
            raise UsageError(
                f"allow entry #{i}: keys are rule, path, reason[, context]"
            )
        rule_id = entry.get("rule", "")
        path = entry.get("path", "")
        reason = str(entry.get("reason", "")).strip()
        check_rules([rule_id], f"allow entry #{i}")
        if not path:
            raise UsageError(f"allow entry #{i} ({rule_id}): path required")
        if not reason:
            raise UsageError(
                f"allow entry #{i} ({rule_id}, {path}): a reason is "
                "mandatory — say why the site is digest-safe"
            )
        cfg.allow.append(
            AllowEntry(
                rule=str(rule_id),
                path=str(path),
                reason=reason,
                context=str(entry.get("context", "")),
            )
        )
    return cfg


def load_config(
    config_path: Optional[Path] = None, no_config: bool = False
) -> Config:
    """Locate and parse ``[tool.detlint]``.  ``config_path`` points at a
    pyproject.toml; otherwise the nearest one upward from cwd is used.
    ``no_config`` (or no pyproject found) yields pure defaults."""
    if no_config:
        return Config()
    if config_path is None:
        cur = Path.cwd()
        for candidate in [cur, *cur.parents]:
            if (candidate / "pyproject.toml").is_file():
                config_path = candidate / "pyproject.toml"
                break
        if config_path is None:
            return Config()
    config_path = Path(config_path)
    if not config_path.is_file():
        raise UsageError(f"config file not found: {config_path}")
    data = _load_toml(config_path)
    return config_from_dict(data, root=config_path.resolve().parent)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def _collect_files(paths: Sequence[str], config: Config) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        if not pp.is_absolute():
            pp = config.root / pp
        if pp.is_dir():
            files.extend(sorted(pp.rglob("*.py")))
        elif pp.is_file():
            files.append(pp)
        else:
            raise UsageError(f"no such file or directory: {p}")
    out: List[Path] = []
    for f in dict.fromkeys(files):
        rel = _rel_path(f, config)
        if any(fnmatch.fnmatch(rel, pat) for pat in config.exclude):
            continue
        out.append(f)
    return out


def _rel_path(path: Path, config: Config) -> str:
    try:
        return path.resolve().relative_to(config.root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def _active_rules(
    config: Config,
    select: Optional[Sequence[str]],
    ignore: Optional[Sequence[str]],
) -> List[Rule]:
    registry = all_rules()
    selected = list(select or config.select) or sorted(registry)
    ignored = set(ignore or ()) | set(config.ignore)
    bad = sorted(set(selected) - set(registry))
    if bad:
        raise UsageError(f"unknown rule id(s): {', '.join(bad)}")
    return [
        registry[rid]() for rid in selected if rid not in ignored
    ]


def _lint_file(
    path: Path, config: Config, rules: Sequence[Rule]
) -> List[Finding]:
    rel = _rel_path(path, config)
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError) as exc:
        raise UsageError(f"cannot parse {rel}: {exc}") from exc
    ctx = ModuleContext(path, rel, source, tree, config)

    findings: List[Finding] = []
    for lineno, msg in ctx.directive_errors:
        findings.append(
            Finding(
                rule=DET900, path=rel, line=lineno, col=0,
                message=msg, hint=_DET900_HINT,
            )
        )

    active: List[Rule] = []
    dispatch: Dict[type, List[Rule]] = {}
    for rule in rules:
        globs = config.per_rule_exclude.get(rule.id, ())
        if any(fnmatch.fnmatch(rel, pat) for pat in globs):
            continue
        active.append(rule)
        for nt in rule.node_types:
            dispatch.setdefault(nt, []).append(rule)
    for rule in active:
        rule.begin_module(ctx)
    for node in ast.walk(tree):
        for rule in dispatch.get(type(node), ()):
            for target, message in rule.visit(node, ctx):
                findings.append(
                    Finding(
                        rule=rule.id,
                        path=rel,
                        line=getattr(target, "lineno", 0),
                        col=getattr(target, "col_offset", 0),
                        message=message,
                        hint=rule.hint,
                        qualname=ctx.qualname(target),
                    )
                )

    # Apply inline suppressions (same line, or a comment-only line just
    # above), then the structured allowlist.
    for f in findings:
        if f.rule == DET900:
            continue
        reason = _inline_reason(ctx, f)
        if reason is not None:
            f.suppressed = True
            f.suppression = "inline"
            f.reason = reason
            continue
        for entry in config.allow:
            if entry.matches(f):
                f.suppressed = True
                f.suppression = "allowlist"
                f.reason = entry.reason
                break
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def _inline_reason(ctx: ModuleContext, f: Finding) -> Optional[str]:
    direct = ctx.skips.get(f.line, {})
    if f.rule in direct:
        return direct[f.rule]
    above = ctx.skips.get(f.line - 1, {})
    if f.rule in above:
        prev = ctx.lines[f.line - 2].strip() if f.line >= 2 else ""
        if prev.startswith("#"):  # comment-only line
            return above[f.rule]
    return None


def lint_paths(
    paths: Sequence[str],
    config: Optional[Config] = None,
    select: Optional[Sequence[str]] = None,
    ignore: Optional[Sequence[str]] = None,
) -> Report:
    """Lint ``paths`` (files or trees) and return a :class:`Report`."""
    config = config or Config()
    rules = _active_rules(config, select, ignore)
    files = _collect_files(paths, config)
    findings: List[Finding] = []
    for path in files:
        findings.extend(_lint_file(path, config, rules))
    return Report(findings=findings, n_files=len(files))


# ---------------------------------------------------------------------------
# Output formats + CLI
# ---------------------------------------------------------------------------


def _emit_text(report: Report, show_suppressed: bool, out) -> None:
    for f in report.findings:
        if f.suppressed and not show_suppressed:
            continue
        tag = f" [suppressed: {f.suppression}]" if f.suppressed else ""
        print(f"{f.path}:{f.line}:{f.col}: {f.rule} {f.message}{tag}", file=out)
        if f.hint and not f.suppressed:
            print(f"    hint: {f.hint}", file=out)
    n = len(report.findings)
    bad = len(report.unsuppressed)
    print(
        f"detlint: {bad} finding(s) ({n - bad} suppressed/allowed) "
        f"in {report.n_files} file(s)",
        file=out,
    )


def _emit_json(report: Report, out) -> None:
    doc = {
        "version": 1,
        "n_files": report.n_files,
        "counts": {
            "total": len(report.findings),
            "unsuppressed": len(report.unsuppressed),
            "suppressed": len(report.findings) - len(report.unsuppressed),
        },
        "findings": [f.to_dict() for f in report.findings],
    }
    json.dump(doc, out, indent=2, sort_keys=True)
    out.write("\n")


def _emit_github(report: Report, out) -> None:
    """GitHub Actions workflow annotations (one ``::error`` per
    unsuppressed finding, shown inline on the PR diff)."""
    for f in report.unsuppressed:
        msg = f.message + (f" — {f.hint}" if f.hint else "")
        msg = msg.replace("%", "%25").replace("\n", "%0A")
        # Annotation columns are 1-based; Finding.col is an ast
        # col_offset (0-based).
        print(
            f"::error file={f.path},line={f.line},col={f.col + 1},"
            f"title=detlint {f.rule}::{msg}",
            file=out,
        )
    print(
        f"detlint: {len(report.unsuppressed)} finding(s) in "
        f"{report.n_files} file(s)",
        file=out,
    )


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.detlint",
        description=(
            "Determinism & invariant linter for the scheduling core "
            "(see docs/DETERMINISM.md).  Exit codes: 0 clean, 1 findings, "
            "2 usage/config error."
        ),
    )
    parser.add_argument(
        "paths", nargs="*",
        help="files or directories to lint (default: [tool.detlint] paths)",
    )
    parser.add_argument(
        "--format", choices=("text", "json", "github"), default="text",
        help="output format (github = workflow annotations)",
    )
    parser.add_argument(
        "--config", type=Path, default=None, metavar="PYPROJECT",
        help="pyproject.toml to read [tool.detlint] from "
        "(default: nearest upward from cwd)",
    )
    parser.add_argument(
        "--no-config", action="store_true",
        help="ignore pyproject.toml entirely (pure rule defaults)",
    )
    parser.add_argument(
        "--select", default="", metavar="IDS",
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--ignore", default="", metavar="IDS",
        help="comma-separated rule ids to skip",
    )
    parser.add_argument(
        "--show-suppressed", action="store_true",
        help="also print suppressed/allowed findings (text format)",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print every registered rule and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid, cls in sorted(all_rules().items()):
            print(f"{rid}  {cls.summary}")
        print(f"{DET900}  {_DET900_SUMMARY} (engine-level)")
        return 0

    try:
        config = load_config(args.config, no_config=args.no_config)
        paths = list(args.paths) or list(config.paths)
        if not paths:
            raise UsageError(
                "no paths given and no [tool.detlint] paths configured"
            )
        report = lint_paths(
            paths,
            config=config,
            select=[s for s in args.select.split(",") if s] or None,
            ignore=[s for s in args.ignore.split(",") if s] or None,
        )
    except UsageError as exc:
        print(f"detlint: error: {exc}", file=sys.stderr)
        return 2

    if args.format == "json":
        _emit_json(report, sys.stdout)
    elif args.format == "github":
        _emit_github(report, sys.stdout)
    else:
        _emit_text(report, args.show_suppressed, sys.stdout)
    return 1 if report.failed else 0


if __name__ == "__main__":
    # Under ``python -m repro.analysis.detlint`` this module object is
    # registered only as ``__main__``; ``all_rules()``'s
    # ``from . import policy_rules`` would then re-import detlint under
    # its canonical name, and the POL rules would register into that
    # second copy's registry instead of this one.  Alias the canonical
    # name to this module (or, if a canonical copy somehow already
    # exists, delegate to it) so there is exactly one registry.
    _canonical = sys.modules.setdefault(
        "repro.analysis.detlint", sys.modules[__name__]
    )
    sys.exit(_canonical.main())
