"""repro.analysis: static analysis for the scheduling core.

The schedules this repo gates on (golden sha256s, fleet digests, the
serve/streaming fast paths) are bit-identical across runs and machines
only because the core follows a handful of conventions — explicit
``default_rng([seed, ...])`` substreams, no observable set-iteration
order, Shewchuk-partials accumulation in digest-bearing aggregates, a
virtual-time-only event loop.  This package checks those conventions
*statically*, before a golden fixture ever has to fail:

* :mod:`repro.analysis.detlint` — the determinism linter (rules
  DET001-DET007) plus the pluggable AST rule engine it is built on.
  CLI: ``python -m repro.analysis.detlint [paths] --format=text|json|github``.
* :mod:`repro.analysis.policy_rules` — a second pass on the same
  walker: ``SchedulingPolicy`` dispatch-contract and frozen-dataclass
  invariants (rules POL001/POL002).

The invariants themselves are documented in ``docs/DETERMINISM.md``,
each cross-referenced to its rule id.

(Import :mod:`repro.analysis.detlint` directly — the package init stays
empty so ``python -m repro.analysis.detlint`` does not double-import
the module it is about to execute.)
"""
