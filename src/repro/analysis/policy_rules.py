"""Policy-invariant lint pass: a second rule set on the detlint walker.

Two structural contracts the scheduling engine relies on, checked
statically alongside the determinism rules (same engine, same
suppressions, same CLI):

POL001 — the PR-5 dispatch contract.  The simulator's canonical pass
entry is ``plan_pass``; ``schedule`` survives only as the pre-protocol
(PR 1-4) name, and the engine binds *through ``schedule``* exactly when
a subclass overrides it.  That makes two shapes hazardous:

* a class overriding **both** ``schedule`` and ``plan_pass`` where
  ``schedule`` never delegates to ``self.plan_pass`` — the engine
  dispatches through ``schedule``, silently shadowing the ``plan_pass``
  override (the in-tree ``Policy`` base passes because its ``schedule``
  is exactly the delegation alias);
* a class overriding **only** ``schedule`` — legacy-supported but the
  wrong entry point for new code, and invisible to tooling that targets
  the protocol name.

POL002 — frozen-dataclass mutation.  ``object.__setattr__`` is the
sanctioned escape hatch *inside* ``__init__``/``__post_init__`` (how
``Scenario.__post_init__`` canonicalizes its event timeline); anywhere
else it mutates a value every reader assumes immutable — hashes, cached
``to_dict`` forms, and fleet-shared state go stale silently.
"""

from __future__ import annotations

import ast
from typing import Iterator, Tuple

from .detlint import Rule, register

__all__ = ["Pol001ScheduleDispatch", "Pol002FrozenMutation"]


def _is_policy_class(node: ast.ClassDef) -> bool:
    """Heuristic: the class, or any syntactic base, is Policy-named
    (``Policy``, ``SchedulingPolicy``, ``ASRPTPolicy`` ...) or the
    migration mixin that composes with them."""
    names = [node.name]
    for base in node.bases:
        if isinstance(base, ast.Name):
            names.append(base.id)
        elif isinstance(base, ast.Attribute):
            names.append(base.attr)
    return any(n.endswith("Policy") or n == "MigrationMixin" for n in names)


def _delegates_to_plan_pass(fn: ast.FunctionDef) -> bool:
    for sub in ast.walk(fn):
        if (
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and sub.func.attr == "plan_pass"
            and isinstance(sub.func.value, ast.Name)
            and sub.func.value.id == "self"
        ):
            return True
    return False


@register
class Pol001ScheduleDispatch(Rule):
    id = "POL001"
    summary = "schedule() override outside the PR-5 dispatch contract"
    hint = (
        "override plan_pass() (the SchedulingPolicy protocol entry); keep "
        "schedule() only as a delegation alias calling self.plan_pass()"
    )
    node_types = (ast.ClassDef,)

    def visit(
        self, node: ast.ClassDef, ctx
    ) -> Iterator[Tuple[ast.AST, str]]:
        if not _is_policy_class(node):
            return
        methods = {
            stmt.name: stmt
            for stmt in node.body
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        sched = methods.get("schedule")
        if sched is None:
            return
        if "plan_pass" in methods:
            if not _delegates_to_plan_pass(sched):
                yield sched, (
                    f"{node.name} overrides both schedule() and "
                    "plan_pass() but schedule() never calls "
                    "self.plan_pass(): the engine dispatches through "
                    "schedule(), silently shadowing the plan_pass() "
                    "override"
                )
        else:
            yield sched, (
                f"{node.name} overrides only schedule(), the pre-protocol "
                "(PR 1-4) pass entry"
            )


@register
class Pol002FrozenMutation(Rule):
    id = "POL002"
    summary = "object.__setattr__ outside __init__/__post_init__"
    hint = (
        "frozen dataclasses may only be written during construction; "
        "derive a new instance (dataclasses.replace) instead of mutating"
    )
    node_types = (ast.Call,)

    def visit(self, node: ast.Call, ctx) -> Iterator[Tuple[ast.AST, str]]:
        if not (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "__setattr__"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "object"
        ):
            return
        fn = ctx.enclosing_function(node)
        if fn is not None and fn.name in ("__init__", "__post_init__"):
            return
        where = f"inside {fn.name}()" if fn is not None else "at module scope"
        yield node, (
            f"object.__setattr__ {where} mutates a frozen value after "
            "construction: every reader (hashes, cached serializations, "
            "fleet-shared state) assumes it is immutable"
        )
