"""POL002 positive fixture: frozen-dataclass mutation after construction."""
from dataclasses import dataclass


@dataclass(frozen=True)
class Spec:
    name: str
    gpus: int

    def rename(self, new_name: str) -> None:
        object.__setattr__(self, "name", new_name)  # mutates a frozen value
