"""DET005 negative fixture: Shewchuk/fsum accumulation in a digest scope."""
import math


# detlint: digest-path
class FlowAggregate:
    def __init__(self) -> None:
        self._parts = []
        self.n_jobs = 0

    def add(self, flow: float) -> None:
        self._parts.append(flow)  # folded via fsum: order-independent
        self.n_jobs += 1

    @property
    def total_flow(self) -> float:
        return math.fsum(self._parts)


def unmarked_total(flows) -> float:
    return sum(flows)  # outside any digest scope: not DET005's business
