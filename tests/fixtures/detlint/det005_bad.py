"""DET005 positive fixture: naive float accumulation in a digest scope."""


# detlint: digest-path
class FlowAggregate:
    def __init__(self) -> None:
        self.total_flow = 0.0
        self.n_jobs = 0

    def add(self, flow: float) -> None:
        self.total_flow += flow  # per-add rounding: order-dependent
        self.n_jobs += 1  # int counter: fine

    def refold(self, flows) -> float:
        return sum(flows)  # left-to-right rounding
