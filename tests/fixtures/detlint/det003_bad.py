"""DET003 positive fixture: wall-clock reads in core logic."""
import time as _time
from datetime import datetime


def stamp() -> float:
    return _time.perf_counter()  # aliased import still resolves


def label() -> str:
    return datetime.now().isoformat()
