"""DET002 negative fixture: explicit seeded substreams only."""
import numpy as np


def sample(seed: int, variant: int) -> float:
    rng = np.random.default_rng([seed, variant])
    return rng.random() + rng.normal()
