"""DET006 positive fixture: object identity as sort/grouping key."""


def order(jobs: list) -> list:
    return sorted(jobs, key=id)  # allocation-order dependent


def group(jobs: list) -> dict:
    by_identity: dict = {}
    for job in jobs:
        by_identity[id(job)] = job
    return by_identity
