"""Suppression fixture: a real finding silenced with a mandatory reason."""
import time


def stamp() -> float:
    # detlint: skip=DET003(reporting-only timer in a demo; never feeds a schedule)
    return time.perf_counter()


def stamp_inline() -> float:
    return time.time()  # detlint: skip=DET003(same-line suppression form)
