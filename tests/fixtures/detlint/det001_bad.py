"""DET001 positive fixture: set iteration feeding ordering-sensitive sinks."""
import heapq


def drain(pending: set, heap: list) -> None:
    for job in pending:  # hash-order iteration pushed onto a heap
        heapq.heappush(heap, job)


def snapshot(watch):
    watch = set(watch)
    order = [jid for jid in watch]  # materializes hash order
    return order, list(watch)


def total_weight(pending: set) -> float:
    # sum() over floats is order-dependent (per-add rounding), so set
    # iteration order leaks into the result
    return sum(j.w for j in pending)
