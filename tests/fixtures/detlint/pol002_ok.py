"""POL002 negative fixture: __post_init__ canonicalization + replace()."""
import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class Spec:
    name: str
    gpus: int
    events: tuple = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(self.events))

    def renamed(self, new_name: str) -> "Spec":
        return dataclasses.replace(self, name=new_name)
