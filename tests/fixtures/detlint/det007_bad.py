"""DET007 positive fixture: undocumented bounded-cache eviction."""
from collections import OrderedDict


class Cache:
    def __init__(self, maxsize: int) -> None:
        self.maxsize = maxsize
        self._lru: OrderedDict = OrderedDict()

    def put(self, key, value) -> None:
        self._lru[key] = value
        if len(self._lru) > self.maxsize:
            self._lru.popitem(last=False)
