"""POL001 positive fixture: schedule() overrides outside the dispatch contract."""


class Policy:
    def plan_pass(self, t, cluster):
        raise NotImplementedError

    def schedule(self, t, cluster):
        return self.plan_pass(t, cluster)  # the sanctioned delegation alias


class ShadowedPolicy(Policy):
    """Overrides both; schedule() never delegates -> plan_pass is dead."""

    def plan_pass(self, t, cluster):
        return ["real allocation"]

    def schedule(self, t, cluster):
        return []


class LegacyPolicy(Policy):
    """Pre-protocol (PR 1-4) shape: only schedule() overridden."""

    def schedule(self, t, cluster):
        return []
