"""DET001 negative fixture: sorted iteration + order-insensitive uses."""
import heapq


def drain(pending: set, heap: list) -> None:
    for job in sorted(pending):
        heapq.heappush(heap, job)


def snapshot(watch):
    watch = set(watch)
    n = len(watch)  # order-insensitive consumers are fine
    total = sum(1 for _ in watch)
    return sorted(watch), n, total


def count_shards(watch):
    watch = set(watch)
    return sum(len(w) for w in watch)  # int-like sum: exact, order-free
