"""Suppression fixture: reason-less skips must fail, not silently suppress."""
import time


def stamp() -> float:
    # detlint: skip=DET003
    return time.perf_counter()


def stamp_empty() -> float:
    # detlint: skip=DET003()
    return time.time()
