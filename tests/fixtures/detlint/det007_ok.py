"""DET007 negative fixture: eviction documented with a skip reason."""
from collections import OrderedDict


class Cache:
    def __init__(self, maxsize: int) -> None:
        self.maxsize = maxsize
        self._lru: OrderedDict = OrderedDict()

    def put(self, key, value) -> None:
        self._lru[key] = value
        if len(self._lru) > self.maxsize:
            # detlint: skip=DET007(entries are pure functions of the key; recomputation is bit-identical)
            self._lru.popitem(last=False)
