"""DET004 negative fixture: enumeration wrapped in sorted()."""
import glob
import os
from pathlib import Path


def shards(root: str) -> list:
    names = sorted(os.listdir(root))
    names += sorted(glob.glob(root + "/*.jsonl"))
    names += sorted(str(p) for p in Path(root).iterdir())
    return names
