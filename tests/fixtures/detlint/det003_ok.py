"""DET003 negative fixture: virtual time threaded through."""


def stamp(t_virtual: float) -> float:
    return t_virtual


def elapsed(t0: float, t1: float) -> float:
    return t1 - t0
