"""DET004 positive fixture: unordered filesystem enumeration."""
import glob
import os
from pathlib import Path


def shards(root: str) -> list:
    names = os.listdir(root)  # filesystem order
    names += glob.glob(root + "/*.jsonl")
    names += [str(p) for p in Path(root).iterdir()]
    return names
