"""DET002 positive fixture: global-state and unseeded RNG."""
import random

import numpy as np


def sample() -> float:
    jitter = random.random()  # stdlib global RNG state
    noise = np.random.normal()  # numpy hidden global RandomState
    rng = np.random.default_rng()  # bare: OS-entropy seeded
    return jitter + noise + rng.random()
