"""POL001 negative fixture: the PR-5 dispatch contract, followed."""


class Policy:
    def plan_pass(self, t, cluster):
        raise NotImplementedError

    def schedule(self, t, cluster):
        return self.plan_pass(t, cluster)  # delegation alias: fine


class ProtocolPolicy(Policy):
    """New-style: only plan_pass overridden; schedule stays the alias."""

    def plan_pass(self, t, cluster):
        return ["allocation"]


class DelegatingPolicy(Policy):
    """Dual override is fine when schedule() delegates."""

    def plan_pass(self, t, cluster):
        return ["allocation"]

    def schedule(self, t, cluster):
        self.last_pass_at = t
        return self.plan_pass(t, cluster)
