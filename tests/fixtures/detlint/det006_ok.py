"""DET006 negative fixture: stable value-based keys."""


def order(jobs: list) -> list:
    return sorted(jobs, key=lambda j: j.job_id)


def group(jobs: list) -> dict:
    return {job.job_id: job for job in jobs}
