"""Streaming quantiles (ISSUE 9 satellite): exact-below-cap bit-identity
with the materialized percentile, P² accuracy within the documented
bound, and the streaming SimResult's tracked p50/p95/p99."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    ClusterSpec,
    STREAM_FLOW_QUANTILES,
    StreamingQuantile,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)


def _exact_percentile(values, q):
    """flow_percentile's linear-interpolation formula."""
    flows = sorted(values)
    if not flows:
        return 0.0
    if len(flows) == 1:
        return flows[0]
    pos = (q / 100.0) * (len(flows) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(flows) - 1)
    return flows[lo] + (pos - lo) * (flows[hi] - flows[lo])


@pytest.mark.parametrize("q", [0.0, 50.0, 95.0, 99.0, 100.0])
def test_exact_mode_bit_identical(q):
    rng = np.random.default_rng(7)
    data = [float(x) for x in rng.lognormal(2.0, 1.3, 300)]
    est = StreamingQuantile(q)
    for x in data:
        est.add(x)
    assert est.exact
    assert est.value() == _exact_percentile(data, q)


@pytest.mark.parametrize("sigma", [0.8, 1.6])
@pytest.mark.parametrize("q", [50.0, 95.0, 99.0])
def test_reservoir_within_documented_bound(sigma, q):
    """Heavy-tailed lognormal at 50k observations: <= 10 % relative error
    (the documented bound; typically well under 5 %)."""
    rng = np.random.default_rng(11)
    data = rng.lognormal(4.0, sigma, 50_000)
    est = StreamingQuantile(q)
    for x in data:
        est.add(float(x))
    assert not est.exact
    exact = _exact_percentile([float(x) for x in data], q)
    assert abs(est.value() - exact) / exact <= 0.10


def test_validation():
    with pytest.raises(ValueError, match="quantile"):
        StreamingQuantile(101.0)
    with pytest.raises(ValueError, match="exact_cap"):
        StreamingQuantile(99.0, exact_cap=0)
    assert StreamingQuantile(99.0).value() == 0.0  # empty stream


def _run(n_jobs, stream):
    jobs = generate_trace(
        TraceConfig(n_jobs=n_jobs, horizon=n_jobs * 12.0, seed=5)
    )
    pol = ASRPTPolicy(make_predictor("mean"), tau=2.0)
    cluster = ClusterSpec(
        num_servers=8, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    return simulate(jobs, cluster, pol, validate=False, stream=stream)


def test_streaming_simresult_small_run_exact():
    """Runs that fit the estimator buffer: streaming flow_percentile ==
    materialized, bit for bit."""
    mat = _run(200, stream=False)
    stm = _run(200, stream=True)
    for q in STREAM_FLOW_QUANTILES:
        assert stm.flow_percentile(q) == mat.flow_percentile(q)


def test_streaming_simresult_large_run_within_bound():
    """Past the buffer (8192 jobs) the reservoir estimate must stay
    within the documented 10 % bound of the exact percentile — on the
    simulator's own trending (queue ramp-up) flow distribution."""
    mat = _run(12_000, stream=False)
    stm = _run(12_000, stream=True)
    for q in STREAM_FLOW_QUANTILES:
        exact = mat.flow_percentile(q)
        assert abs(stm.flow_percentile(q) - exact) / exact <= 0.10


def test_streaming_untracked_quantile_raises():
    stm = _run(50, stream=True)
    with pytest.raises(RuntimeError, match="track only"):
        stm.flow_percentile(12.5)
