"""Scenario/ClusterEvent API (ISSUE 5): serialization, shim equivalence,
canonical event order, and elastic capacity.

Anchor properties:

* **Round trip** — ``Scenario.from_json(s.to_json()) == s`` for sampled
  scenarios (all event kinds, including ``inf`` drain windows), and a
  round-tripped scenario replays a *byte-identical* schedule.
* **Legacy shim** — ``simulate(jobs, spec, faults=, degradations=)``
  produces schedules bit-identical to ``simulate(Scenario(...),
  policy)`` (the old keywords are sugar for event construction).
* **Tie-break** — same-timestamp events on the same server apply in the
  documented canonical order, independent of input interleaving (the
  PR-5 bugfix: schedules used to depend on caller list order).
* **Elastic capacity** — ``ServerLeave(drain_timeout=0)`` is the PR-2
  fault path verbatim; ``ServerJoin`` restores capacity (class caps
  minus held GPUs), wakes settled policies, and recovers flow time,
  end to end under A-SRPT and a queue baseline.
"""
import math

import numpy as np
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    ClusterSpec,
    Degradation,
    Fault,
    Scenario,
    SchedulingPolicy,
    ServerClass,
    ServerJoin,
    ServerLeave,
    TraceConfig,
    elastic_events,
    elastic_scenario,
    generate_trace,
    make_predictor,
    scenario_from_legacy,
    simulate,
    straggler_scenario,
)
from repro.core.cluster import ClusterState
from repro.core.scenario import event_from_dict, event_sort_key
from repro.core.simulator import Allocation, Policy, Start

from conftest import make_simple_job

INF = float("inf")


def assert_identical(ra, rb):
    assert ra.schedule_digest() == rb.schedule_digest()
    assert set(ra.records) == set(rb.records)
    for jid, a in ra.records.items():
        b = rb.records[jid]
        assert (a.start, a.completion, a.alpha, a.servers, a.migrations) == (
            b.start, b.completion, b.alpha, b.servers, b.migrations
        ), jid


def _hom_cluster(n=6):
    return ClusterSpec(
        num_servers=n, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
    )


def _het_cluster():
    return ClusterSpec.heterogeneous(
        [
            ServerClass(count=3, gpus_per_server=8, b_inter=12.5e9, name="a"),
            ServerClass(count=3, gpus_per_server=8, b_inter=1.25e9, name="b"),
            ServerClass(
                count=3, gpus_per_server=4, b_inter=1.25e9, b_intra=50e9,
                name="c",
            ),
        ],
        b_intra=300e9,
    )


def _trace(seed, n_jobs=100, horizon=1500.0, max_g=16):
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=horizon,
            seed=seed,
            single_gpu_frac=0.4,
            max_gpus_per_job=max_g,
        )
    )


def _asrpt(**kw):
    return ASRPTPolicy(make_predictor("mean"), tau=2.0, **kw)


def _sample_events(rng, num_servers, horizon=1500.0):
    """All four event kinds with random same-timestamp collisions."""
    events = []
    times = [float(rng.uniform(10.0, horizon)) for _ in range(6)]
    times += times[:2]  # force same-t collisions
    for i, t in enumerate(times):
        m = int(rng.integers(0, num_servers))
        kind = int(rng.integers(0, 4))
        if kind == 0:
            events.append(Fault(t, m))
        elif kind == 1:
            events.append(
                Degradation(t, m, factor=float(rng.choice([0.0, 0.25, 0.5, 1.0])))
            )
        elif kind == 2:
            events.append(
                ServerLeave(
                    t, m,
                    drain_timeout=float(rng.choice([0.0, 60.0, INF])),
                )
            )
        else:
            events.append(ServerJoin(t, m))
    return events


# ---------------------------------------------------------------------------
# canonical event order + serialization unit tests
# ---------------------------------------------------------------------------


def test_events_stored_in_canonical_order():
    sc = Scenario(
        jobs=(make_simple_job(),),
        cluster=_hom_cluster(),
        events=(
            Degradation(10.0, 1, factor=0.5),
            Fault(10.0, 1),
            ServerLeave(10.0, 0, drain_timeout=5.0),
            ServerJoin(10.0, 1),
            Fault(5.0, 3),
        ),
    )
    # (t, server, kind-rank join<degradation<leave<fault, magnitude)
    assert sc.events == (
        Fault(5.0, 3),
        ServerLeave(10.0, 0, drain_timeout=5.0),
        ServerJoin(10.0, 1),
        Degradation(10.0, 1, factor=0.5),
        Fault(10.0, 1),
    )
    assert sorted(sc.events, key=event_sort_key) == list(sc.events)


def test_scenario_validates_event_servers():
    with pytest.raises(ValueError, match="targets server 9"):
        Scenario(
            jobs=(make_simple_job(),),
            cluster=_hom_cluster(n=4),
            events=(Fault(1.0, 9),),
        )


def test_event_validation():
    with pytest.raises(ValueError):
        Degradation(1.0, 0, factor=-0.5)
    with pytest.raises(ValueError):
        ServerLeave(1.0, 0, drain_timeout=-1.0)
    with pytest.raises(ValueError):
        Fault(-1.0, 0)
    with pytest.raises(ValueError, match="unknown event kind"):
        event_from_dict({"kind": "maintenance", "t": 1.0, "server": 0})
    with pytest.raises(ValueError, match="missing"):
        event_from_dict({"kind": "degradation", "t": 1.0, "server": 0})


def test_infinite_drain_timeout_serializes_as_null():
    ev = ServerLeave(3.0, 1, drain_timeout=INF)
    sc = Scenario(
        jobs=(make_simple_job(),), cluster=_hom_cluster(), events=(ev,)
    )
    text = sc.to_json()
    assert "Infinity" not in text
    back = Scenario.from_json(text)
    assert back.events == (ev,)
    assert math.isinf(back.events[0].drain_timeout)


def test_schema_version_enforced():
    sc = Scenario(jobs=(make_simple_job(),), cluster=_hom_cluster())
    d = sc.to_dict()
    d["schema"] = 99
    with pytest.raises(ValueError, match="unsupported scenario schema"):
        Scenario.from_dict(d)


# ---------------------------------------------------------------------------
# anchor property: JSON round trip (equality + byte-identical replay)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_scenario_json_roundtrip(seed, hetero):
    rng = np.random.default_rng(seed)
    cluster = _het_cluster() if hetero else _hom_cluster()
    sc = Scenario(
        jobs=tuple(_trace(seed, n_jobs=60, max_g=16)),
        cluster=cluster,
        events=tuple(_sample_events(rng, cluster.num_servers)),
        name=f"roundtrip-{seed}",
    )
    back = Scenario.from_json(sc.to_json())
    assert back == sc
    # and the serialization is canonical: dumping again is a fixpoint
    assert back.to_json() == sc.to_json()


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_roundtripped_scenario_replays_byte_identical(seed):
    rng = np.random.default_rng(seed)
    cluster = _hom_cluster()
    # degradations + faults only: keep every job startable (leaves could
    # strand capacity below the largest job's demand)
    events = tuple(
        Degradation(
            float(rng.uniform(50.0, 1200.0)),
            int(rng.integers(0, cluster.num_servers)),
            factor=float(rng.choice([0.0, 0.25, 0.5])),
        )
        for _ in range(3)
    )
    sc = Scenario(
        jobs=tuple(_trace(seed, n_jobs=80)), cluster=cluster, events=events
    )
    back = Scenario.from_json(sc.to_json())
    ra = simulate(sc, _asrpt(migrate=True, migration_penalty=30.0))
    rb = simulate(back, _asrpt(migrate=True, migration_penalty=30.0))
    assert_identical(ra, rb)


# ---------------------------------------------------------------------------
# anchor property: the legacy shim is bit-identical
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_legacy_shim_bit_identical(seed, use_baseline):
    rng = np.random.default_rng(seed)
    cluster = _hom_cluster()
    faults = [(float(rng.uniform(100.0, 800.0)), 0)]
    degradations = [
        (float(rng.uniform(100.0, 800.0)), 2, 0.25),
        (float(rng.uniform(100.0, 800.0)), 3, 0.5),
    ]
    jobs = _trace(seed, n_jobs=80)

    def mk():
        if use_baseline:
            return BASELINES["WCS-SubTime"](
                make_predictor("mean"), migrate=True, migration_penalty=20.0
            )
        return _asrpt(migrate=True, migration_penalty=20.0)

    legacy = simulate(
        jobs, cluster, mk(), faults=faults, degradations=degradations
    )
    sc = scenario_from_legacy(
        jobs, cluster, faults=faults, degradations=degradations
    )
    explicit = simulate(sc, mk())
    assert_identical(legacy, explicit)


def test_scenario_rejects_legacy_keywords():
    sc = Scenario(jobs=(make_simple_job(),), cluster=_hom_cluster())
    with pytest.raises(TypeError, match="legacy signature"):
        simulate(sc, _asrpt(), faults=[(1.0, 0)])
    with pytest.raises(TypeError, match="SchedulingPolicy"):
        simulate(sc, None)


# ---------------------------------------------------------------------------
# tie-break bugfix: same-timestamp same-server events are order-stable
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_same_timestamp_tiebreak_input_order_irrelevant(seed):
    rng = np.random.default_rng(seed)
    cluster = _hom_cluster()
    jobs = _trace(seed, n_jobs=60)
    t = float(rng.uniform(100.0, 800.0))
    m = int(rng.integers(0, cluster.num_servers))
    # a fault and a slowdown landing on the same server at the same
    # instant, plus a same-instant slowdown elsewhere
    events = [
        Fault(t, m),
        Degradation(t, m, factor=0.5),
        Degradation(t, (m + 1) % cluster.num_servers, factor=0.25),
    ]
    digests = set()
    for order in (events, events[::-1], [events[1], events[2], events[0]]):
        sc = Scenario(jobs=tuple(jobs), cluster=cluster, events=tuple(order))
        res = simulate(sc, _asrpt(migrate=True, migration_penalty=30.0))
        digests.add(res.schedule_digest())
    assert len(digests) == 1
    # the documented ranking: the fault wins the instant (the server is
    # down afterwards, whatever the input interleaving)
    state = ClusterState(cluster)
    for ev in sc.events:
        if isinstance(ev, Fault):
            state.mark_server_down(ev.server)
        elif isinstance(ev, Degradation):
            state.set_server_speed(ev.server, ev.factor)
    assert m in state.downed_servers


def test_legacy_keyword_interleaving_is_canonicalized(cluster):
    """faults= and degradations= hitting one (t, server) produce the same
    schedule whichever keyword order the caller used (previously the
    fault list was always applied first)."""
    jobs = _trace(3, n_jobs=50)
    t, m = 300.0, 1
    ra = simulate(
        jobs, cluster, _asrpt(), faults=[(t, m)],
        degradations=[(t, m, 0.5)],
    )
    rb = simulate(
        jobs, cluster, _asrpt(), degradations=[(t, m, 0.5), (t, m, 0.0)]
    )
    assert_identical(ra, rb)


# ---------------------------------------------------------------------------
# elastic capacity: ServerLeave
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_leave_zero_timeout_equals_fault_path(seed, migrate):
    """Acceptance: drain_timeout=0 leaves are the PR-2 fault path."""
    rng = np.random.default_rng(seed)
    cluster = _hom_cluster()
    jobs = _trace(seed, n_jobs=80)
    t = float(rng.uniform(50.0, 1200.0))
    m = int(rng.integers(0, cluster.num_servers))

    def mk():
        return _asrpt(migrate=migrate, migration_penalty=0.0)

    via_fault = simulate(jobs, cluster, mk(), faults=[(t, m)])
    sc = Scenario(
        jobs=tuple(jobs), cluster=cluster,
        events=(ServerLeave(t, m, drain_timeout=0.0),),
    )
    via_leave = simulate(sc, mk())
    assert_identical(via_fault, via_leave)


def test_graceful_drain_semantics():
    """During a drain window: no new allocations on the leaving server,
    running jobs finish in place, capacity is forfeited on release."""
    cluster = _hom_cluster(n=2)
    running = make_simple_job(job_id=0, replicas=(4,), n_iters=50, p=1.0)
    late = make_simple_job(
        job_id=1, replicas=(4,), n_iters=5, p=1.0, arrival=10.0
    )
    sc = Scenario(
        jobs=(running, late), cluster=cluster,
        events=(ServerLeave(5.0, 0, drain_timeout=INF),),
    )
    res = simulate(sc, _asrpt())
    r0, r1 = res.records[0], res.records[1]
    assert r0.start == 0.0
    # job 0 keeps its placement to completion (finish in place, un-re-timed)
    clean = simulate([running], cluster, _asrpt())
    assert r0.completion == clean.records[0].completion
    # job 1 can only use the surviving server
    assert r1.servers == (1,) or 0 not in r1.servers


def test_drain_window_offers_migration_candidates():
    """While a drain window is open, jobs on the leaving server are
    offered to plan_migrations; after the deadline they are not."""
    offers = []

    class Spy(ASRPTPolicy):
        def plan_migrations(self, t, cluster, candidates):
            offers.append((t, [r.job.job_id for r in candidates]))
            return []

    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(4,), n_iters=100, p=1.0)
    poker = make_simple_job(
        job_id=1, replicas=(1,), n_iters=1, p=0.1, arrival=20.0
    )
    sc = Scenario(
        jobs=(job, poker), cluster=cluster,
        events=(ServerLeave(5.0, 0, drain_timeout=30.0),),
    )
    simulate(sc, Spy(make_predictor("mean"), tau=2.0, migrate=True))
    watched = [t for t, jids in offers if 0 in jids]
    assert watched and all(5.0 <= t <= 35.0 for t in watched)
    # after the deadline (t=35) the job finishes in place, unwatched
    assert not [t for t, jids in offers if t > 35.0 and 0 in jids]


def test_drain_window_migration_moves_job_off_leaving_server():
    """A migration-capable policy checkpoint-restarts off a draining
    server when the fresh placement wins the race: an undegraded drain
    alone never beats the penalty (stay keeps full speed), but once the
    draining server also degrades, moving wins."""
    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(4,), n_iters=200, p=1.0)
    sc = Scenario(
        jobs=(job,), cluster=cluster,
        events=(
            ServerLeave(10.0, 0, drain_timeout=INF),
            Degradation(12.0, 0, factor=0.25),
        ),
    )
    res = simulate(
        sc, _asrpt(migrate=True, migration_penalty=10.0)
    )
    rec = res.records[0]
    assert rec.migrations == 1
    assert rec.servers == (1,)


# ---------------------------------------------------------------------------
# elastic capacity: ServerJoin
# ---------------------------------------------------------------------------


def test_join_restores_capacity_and_wakes_policy():
    """A job too big for the initial live capacity starts the moment the
    absent server joins (epoch bump wakes the settled policy)."""
    cluster = _hom_cluster(n=2)
    big = make_simple_job(job_id=0, replicas=(3, 3), n_iters=10, p=0.5)
    sc = Scenario(
        jobs=(big,), cluster=cluster,
        events=(ServerLeave(0.0, 1), ServerJoin(40.0, 1)),
    )
    res = simulate(sc, _asrpt())
    rec = res.records[0]
    assert rec.start == 40.0  # nothing but the join could start it
    assert set(rec.servers) == {0, 1}


def test_join_restores_class_capacity_minus_held():
    state = ClusterState(_hom_cluster(n=2))
    state.allocate(7, {0: np.array([2])}, counts={0: 2})
    state.mark_server_down(0)
    assert state.free[0] == 0 and state.total_free == 4
    assert state.activate_server(0)
    # 4-GPU class cap minus the 2 GPUs job 7 still holds
    assert state.free[0] == 2 and state.total_free == 6
    state.release(7)  # server active again: held GPUs return
    assert state.free[0] == 4 and state.total_free == 8
    assert not state.activate_server(0)  # no-op join


def test_join_after_leave_recovers_flow_under_both_policy_kinds():
    """Acceptance: the elastic scenario runs end to end under A-SRPT and
    a queue baseline, and joining capacity mid-trace recovers flow time
    vs the static-degraded cluster."""
    cfg = TraceConfig(
        n_jobs=120, horizon=1500.0, seed=7, single_gpu_frac=0.4,
        max_gpus_per_job=8,
    )
    cluster = _hom_cluster(n=6)
    static = elastic_scenario(
        cfg, cluster, elastic_servers=(0, 1), join_frac=None
    )
    elastic = elastic_scenario(
        cfg, cluster, elastic_servers=(0, 1), join_frac=0.3
    )
    assert elastic.events[-1] == ServerJoin(0.3 * cfg.horizon, 1)
    for mk in (
        lambda: _asrpt(),
        lambda: BASELINES["WCS-SubTime"](make_predictor("mean")),
    ):
        r_static = simulate(static, mk())
        r_elastic = simulate(elastic, mk())
        assert len(r_elastic.records) == len(static.jobs)
        assert (
            r_elastic.total_flow_time < r_static.total_flow_time
        ), type(mk()).__name__
        # joined capacity is actually used
        used = {
            m for r in r_elastic.records.values() for m in r.servers
        }
        assert {0, 1} & used


def test_join_resurrects_faulted_server():
    """A join on a *failed* slot models replacement hardware: capacity
    returns and is used again."""
    cluster = _hom_cluster(n=2)
    jobs = [
        make_simple_job(job_id=i, replicas=(4,), n_iters=10, p=0.5,
                        arrival=float(10 * i))
        for i in range(8)
    ]
    sc = Scenario(
        jobs=tuple(jobs), cluster=cluster,
        events=(Fault(5.0, 0), ServerJoin(50.0, 0)),
    )
    res = simulate(sc, _asrpt())
    used_after_join = {
        m
        for r in res.records.values()
        if r.start >= 50.0
        for m in r.servers
    }
    assert 0 in used_after_join
    # and between the fault and the join, nothing lands on server 0
    assert not any(
        0 in r.servers
        for r in res.records.values()
        if 5.0 <= r.start < 50.0
    )


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_elastic_scenario_runs_on_mixed_cluster(seed):
    """End-to-end elastic churn on a heterogeneous cluster with a
    migration-capable policy (events + drains compose with stragglers).
    """
    cluster = _het_cluster()
    jobs = _trace(seed, n_jobs=60, max_g=8)
    events = (
        ServerLeave(0.0, 0),
        Degradation(200.0, 4, factor=0.5),
        ServerJoin(400.0, 0),
        ServerLeave(600.0, 5, drain_timeout=100.0),
        Degradation(700.0, 4, factor=1.0),
    )
    sc = Scenario(jobs=tuple(jobs), cluster=cluster, events=events)
    res = simulate(sc, _asrpt(migrate=True, migration_penalty=30.0))
    assert len(res.records) == len(jobs)


# ---------------------------------------------------------------------------
# policy protocol
# ---------------------------------------------------------------------------


def test_policies_satisfy_protocol():
    assert isinstance(_asrpt(), SchedulingPolicy)
    assert isinstance(
        BASELINES["SPJF"](make_predictor("mean")), SchedulingPolicy
    )
    assert not isinstance(object(), SchedulingPolicy)


def test_on_event_hook_sees_full_timeline():
    seen = []

    class Hooked(ASRPTPolicy):
        def on_event(self, t, event, cluster):
            seen.append((t, type(event).__name__, event.server))

    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(2,), n_iters=5, p=0.5)
    events = (
        Degradation(2.0, 1, factor=0.5),
        Degradation(3.0, 1, factor=0.5),  # no-op repeat: still reported
        Fault(4.0, 1),
    )
    sc = Scenario(jobs=(job,), cluster=cluster, events=events)
    simulate(sc, Hooked(make_predictor("mean"), tau=2.0))
    assert seen == [
        (2.0, "Degradation", 1),
        (3.0, "Degradation", 1),
        (4.0, "Fault", 1),
    ]


def test_third_party_policy_via_protocol():
    """A from-scratch policy implementing the protocol (no in-tree base
    beyond ``Policy``'s defaults) runs end to end with typed results."""

    class Greedy(Policy):
        """Start everything that fits, in arrival order, on one server."""

        def __init__(self):
            self.queue = []

        def on_arrival(self, t, job):
            self.queue.append(job)

        def plan_pass(self, t, cluster):
            from repro.core import timing
            from repro.core.heavy_edge import select_servers

            starts = []
            for job in list(self.queue):
                if job.g > cluster.total_free:
                    break
                caps = select_servers(
                    cluster.free, job.g, consolidate=True,
                    spec=self.cluster_spec,
                )
                placement = {}
                left = job.g
                vid = 0
                for m, c in caps:
                    take = min(c, left)
                    vec = np.zeros(job.num_stages, dtype=np.int64)
                    for _ in range(take):
                        # fill stages round-robin replica by replica
                        s = 0
                        acc = 0
                        for si, stg in enumerate(job.stages):
                            if vid < acc + stg.k:
                                s = si
                                break
                            acc += stg.k
                        vec[s] += 1
                        vid += 1
                    placement[m] = vec
                    left -= take
                a = timing.alpha(job, placement, self.cluster_spec)
                starts.append(Allocation(job, placement, a))
                cluster.allocate(job.job_id, placement, counts=dict(caps))
                self.queue.remove(job)
            return starts

    sc = Scenario(
        jobs=tuple(
            make_simple_job(job_id=i, replicas=(2,), n_iters=5, p=0.5,
                            arrival=float(i))
            for i in range(4)
        ),
        cluster=_hom_cluster(n=2),
    )
    pol = Greedy()
    assert isinstance(pol, SchedulingPolicy)
    res = simulate(sc, pol)
    assert len(res.records) == 4


def test_legacy_schedule_alias_still_callable():
    """Pre-protocol callers used policy.schedule(t, cluster); the alias
    delegates to plan_pass."""
    pol = BASELINES["SPJF"](make_predictor("mean"))
    spec = _hom_cluster(n=2)
    pol.bind(spec)
    state = ClusterState(spec)
    pol.on_arrival(0.0, make_simple_job(job_id=0, replicas=(2,)))
    starts = pol.schedule(0.0, state)
    assert len(starts) == 1 and isinstance(starts[0], Start)


# ---------------------------------------------------------------------------
# trace-level samplers
# ---------------------------------------------------------------------------


def test_straggler_scenario_sampler_roundtrips():
    cfg = TraceConfig(n_jobs=40, horizon=800.0, seed=3, max_gpus_per_job=8)
    sc = straggler_scenario(cfg, n_stragglers=2)
    assert sc.cluster.is_heterogeneous
    assert all(isinstance(ev, Degradation) for ev in sc.events)
    assert Scenario.from_json(sc.to_json()) == sc


def test_elastic_events_validation():
    with pytest.raises(ValueError, match="precedes"):
        elastic_events([0], join_at=5.0, leave_at=10.0)
    evs = elastic_events([0, 1], join_at=None)
    assert all(isinstance(ev, ServerLeave) for ev in evs)


# ---------------------------------------------------------------------------
# review regressions: stale drain deadlines, custom events, legacy dispatch
# ---------------------------------------------------------------------------


def test_stale_drain_deadline_does_not_close_reopened_window():
    """leave -> join (cancels the drain) -> leave again: the first
    leave's deadline must not close the *second* window early — the job
    stays migration-offered until the second deadline."""
    offers = []

    class Spy(ASRPTPolicy):
        def plan_migrations(self, t, cluster, candidates):
            offers.append((t, [r.job.job_id for r in candidates]))
            return []

    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(4,), n_iters=400, p=1.0)
    pokers = tuple(
        make_simple_job(job_id=1 + i, replicas=(1,), n_iters=1, p=0.1,
                        arrival=a)
        for i, a in enumerate((150.0, 200.0, 280.0))
    )
    sc = Scenario(
        jobs=(job,) + pokers, cluster=cluster,
        events=(
            ServerLeave(10.0, 0, drain_timeout=100.0),  # deadline t=110
            ServerJoin(50.0, 0),                        # cancels the drain
            ServerLeave(60.0, 0, drain_timeout=200.0),  # deadline t=260
        ),
    )
    simulate(sc, Spy(make_predictor("mean"), tau=2.0, migrate=True))
    watched = [t for t, jids in offers if 0 in jids]
    # the second window spans (60, 260): offers inside (110, 260) prove
    # the stale t=110 deadline was dropped
    assert any(110.0 < t < 260.0 for t in watched), watched
    assert not any(t > 260.0 for t in watched), watched


def test_custom_event_kind_reaches_on_event():
    """Policy-defined ClusterEvent subclasses sort into the timeline,
    reach on_event, trigger a pass, and refuse to serialize with a clear
    error (schema v1 covers the built-ins only)."""
    from dataclasses import dataclass

    from repro.core import ClusterEvent

    @dataclass(frozen=True)
    class Maintenance(ClusterEvent):
        note: str = ""

    seen = []

    class Hooked(ASRPTPolicy):
        def on_event(self, t, event, cluster):
            seen.append((t, type(event).__name__))

    cluster = _hom_cluster(n=2)
    job = make_simple_job(job_id=0, replicas=(2,), n_iters=5, p=0.5)
    sc = Scenario(
        jobs=(job,), cluster=cluster,
        events=(Maintenance(2.0, 1, note="fan swap"), Fault(2.0, 1)),
    )
    # custom kinds rank after built-ins at one (t, server)
    assert [type(ev).__name__ for ev in sc.events] == [
        "Fault", "Maintenance"
    ]
    res = simulate(sc, Hooked(make_predictor("mean"), tau=2.0))
    assert seen == [(2.0, "Fault"), (2.0, "Maintenance")]
    assert len(res.records) == 1
    with pytest.raises(ValueError, match="policy-defined"):
        sc.to_json()


def test_pre_protocol_schedule_override_still_dispatched():
    """A PR 1-4-era subclass overriding only ``schedule`` keeps working:
    the simulator dispatches through the override (regression for the
    plan_pass rename)."""
    calls = []

    class LegacyASRPT(ASRPTPolicy):
        def schedule(self, t, cluster):  # pre-protocol override point
            calls.append(t)
            return super().schedule(t, cluster)

    jobs = _trace(2, n_jobs=30)
    cluster = _hom_cluster()
    legacy = simulate(jobs, cluster, LegacyASRPT(make_predictor("mean"), tau=2.0))
    assert calls, "override was never dispatched"
    modern = simulate(jobs, cluster, _asrpt())
    assert_identical(legacy, modern)


def test_join_cancelling_drain_prunes_migration_watch():
    """A join that cancels a drain un-risks the server: its jobs drop
    off the migration watch even while other servers stay degraded."""
    offers = []

    class Spy(ASRPTPolicy):
        def plan_migrations(self, t, cluster, candidates):
            offers.append((t, [r.job.job_id for r in candidates]))
            return []

    cluster = _hom_cluster(n=3)
    job = make_simple_job(job_id=0, replicas=(4,), n_iters=400, p=1.0)
    pokers = tuple(
        make_simple_job(job_id=1 + i, replicas=(1,), n_iters=1, p=0.1,
                        arrival=a)
        for i, a in enumerate((20.0, 40.0))
    )
    sc = Scenario(
        jobs=(job,) + pokers, cluster=cluster,
        events=(
            ServerLeave(5.0, 0, drain_timeout=INF),
            Degradation(6.0, 2, factor=0.5),  # keeps the risky set alive
            ServerJoin(10.0, 0),              # cancels the drain
        ),
    )
    simulate(sc, Spy(make_predictor("mean"), tau=2.0, migrate=True))
    # watched while draining, dropped at the join
    assert any(0 in jids for t, jids in offers if t < 10.0)
    assert not any(0 in jids for t, jids in offers if t >= 10.0), offers


def test_from_dict_rejects_unknown_fields():
    """The schema promise: typo'd fields fail loudly instead of silently
    taking defaults (a 'drain_timout' leave would otherwise become an
    immediate kill)."""
    with pytest.raises(ValueError, match="drain_timout"):
        event_from_dict(
            {"kind": "leave", "t": 5.0, "server": 1, "drain_timout": 120.0}
        )
    with pytest.raises(ValueError, match="factor"):
        event_from_dict(
            {"kind": "fault", "t": 5.0, "server": 1, "factor": 0.5}
        )
    sc = Scenario(jobs=(make_simple_job(),), cluster=_hom_cluster())
    d = sc.to_dict()
    d["extra_section"] = []
    with pytest.raises(ValueError, match="extra_section"):
        Scenario.from_dict(d)
    d = sc.to_dict()
    d["jobs"][0]["n_iter"] = 5
    with pytest.raises(ValueError, match="n_iter"):
        Scenario.from_dict(d)
    d = sc.to_dict()
    d["cluster"]["gpus"] = 4
    with pytest.raises(ValueError, match="gpus"):
        Scenario.from_dict(d)


def test_elastic_events_rejects_same_instant_join():
    # at one instant the canonical order applies the join first, so a
    # coinciding pair would strand the servers — rejected up front
    with pytest.raises(ValueError, match="coincides"):
        elastic_events([0], join_at=10.0, leave_at=10.0)


def test_legacy_simulate_without_policy_raises_cleanly():
    jobs = [make_simple_job(job_id=0, replicas=(2,))]
    with pytest.raises(TypeError, match="SchedulingPolicy"):
        simulate(jobs, _hom_cluster())
    with pytest.raises(TypeError, match="SchedulingPolicy"):
        simulate(jobs, _hom_cluster(), validate=False)


def test_scenario_form_rejects_extra_cluster_spec():
    sc = Scenario(jobs=(make_simple_job(),), cluster=_hom_cluster())
    with pytest.raises(TypeError, match="carries its own cluster"):
        simulate(sc, _hom_cluster(n=2), _asrpt())


def test_nonfinite_event_fields_rejected():
    nan = float("nan")
    with pytest.raises(ValueError, match="finite"):
        Fault(nan, 0)
    with pytest.raises(ValueError):
        Fault(INF, 0)
    with pytest.raises(ValueError, match="finite"):
        Degradation(1.0, 0, factor=nan)
    with pytest.raises(ValueError):
        ServerLeave(1.0, 0, drain_timeout=nan)
    # a NaN-time scenario file fails from_dict instead of corrupting the
    # event heap (json.loads parses NaN)
    import json as _json

    with pytest.raises(ValueError, match="finite"):
        Scenario.from_dict(_json.loads(
            '{"schema": 1, "name": "", '
            '"cluster": {"num_servers": 1, "gpus_per_server": 4, '
            '"b_inter": 1.0, "b_intra": 1.0}, "jobs": [], '
            '"events": [{"kind": "fault", "t": NaN, "server": 0}]}'
        ))
