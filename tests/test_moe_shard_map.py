"""shard_map local-dispatch MoE (§Perf iteration 4) vs the pjit oracle."""
import subprocess
import sys
import textwrap

SNIPPET = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import reduced_config
    from repro.models import moe as X
    from repro.parallel import opt_flags
    from repro.launch.mesh import make_debug_mesh

    cfg = reduced_config("qwen3-moe-30b-a3b", capacity_factor=8.0)
    mesh = make_debug_mesh(8, model=2)
    p = X.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                          jnp.float32)
    opt_flags.reset()
    with mesh:
        y_ref, _ = jax.jit(lambda p, x: X.apply_moe(p, cfg, x))(p, x)
    opt_flags.set_flags(moe_a2a=True, mesh=mesh, batch_axes="data")
    with mesh:
        y_sm, _ = jax.jit(lambda p, x: X.apply_moe(p, cfg, x))(p, x)
        # gradients flow through shard_map too
        g = jax.jit(jax.grad(lambda p, x: X.apply_moe(p, cfg, x)[0].sum()))(
            p, x
        )
    opt_flags.reset()
    err = float(jnp.max(jnp.abs(y_ref - y_sm)))
    assert err < 1e-4, err
    assert all(
        bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g)
    )
    print("MOE_SHARD_MAP_OK")
    """
)


def test_moe_shard_map_matches_pjit():
    proc = subprocess.run(
        [sys.executable, "-c", SNIPPET],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "MOE_SHARD_MAP_OK" in proc.stdout, proc.stderr[-2000:]
