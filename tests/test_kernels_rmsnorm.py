"""Fused RMSNorm Pallas kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "shape", [(4, 64), (2, 300, 512), (1, 7, 128), (3, 1000)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    scale = jnp.asarray(rng.normal(size=shape[-1:]), jnp.float32)
    out = ops.rmsnorm(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )
