"""Array-native placement engine vs the retained pure-Python reference.

The PR-3 hot path rebuilds Heavy-Edge -> alpha on dense arrays (cached
``JobGraph.dense()`` weight matrix, masked-argmax greedy, batched
three-seed refine, whole-placement ``timing.alpha_matrix``) and batches
A-SRPT's delayed-queue re-evaluation through ``FreeCapsSnapshot`` prefix
carving.  Every one of those paths may only skip or restructure work whose
outcome is provably unchanged, so placements, alphas, selections, and full
schedules must equal the reference *bit for bit* — not approximately —
on homogeneous and mixed-class specs, greedy and refined.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ClusterSpec,
    ServerClass,
    TraceConfig,
    generate_trace,
    mixed_cluster_spec,
)
from repro.core import timing
from repro.core.graph import build_job_graph
from repro.core.heavy_edge import (
    FreeCapsSnapshot,
    PlacementCache,
    heavy_edge,
    heavy_edge_reference,
    map_job,
    map_job_canonical,
    select_servers,
)

from conftest import make_simple_job


def _hom_spec(num_servers=8, gps=8):
    return ClusterSpec(
        num_servers=num_servers, gpus_per_server=gps,
        b_inter=1.25e9, b_intra=300e9,
    )


def _trace_jobs(seed, n_jobs=25, max_g=24):
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs,
            horizon=60.0 * n_jobs,
            seed=seed,
            max_gpus_per_job=max_g,
            mean_iters=50,
            session_spread=30.0,
        )
    )


def _random_caps(rng, spec, g):
    """A feasible capacity vector via select_servers on a random free state."""
    while True:
        free = {
            m: int(rng.integers(0, spec.server_gpus(m) + 1))
            for m in range(spec.num_servers)
        }
        if sum(free.values()) >= g:
            consolidate = bool(rng.integers(0, 2))
            return select_servers(free, g, consolidate=consolidate, spec=spec)


def assert_placements_equal(pa, pb):
    assert set(pa) == set(pb)
    for m in pa:
        assert np.array_equal(np.asarray(pa[m]), np.asarray(pb[m])), m


# ---------------------------------------------------------------------------
# Greedy: array heavy_edge == dict-walk reference
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_heavy_edge_matches_reference(seed):
    rng = np.random.default_rng(seed)
    specs = (_hom_spec(), mixed_cluster_spec(num_servers=7, seed=seed,
                                             n_classes=3))
    jobs = _trace_jobs(seed)
    for spec in specs:
        for job in jobs[:8]:
            caps = _random_caps(rng, spec, job.g)
            graph = build_job_graph(job)
            assert heavy_edge(graph, caps) == heavy_edge_reference(graph, caps)


def test_heavy_edge_single_gpu_servers():
    """cap == 1 slots exercise the shared min-weight-vertex branch."""
    job = make_simple_job(job_id=0, replicas=(2, 2), h_mb=64.0)
    graph = build_job_graph(job)
    caps = [(0, 1), (1, 1), (2, 1), (3, 1)]
    assert heavy_edge(graph, caps) == heavy_edge_reference(graph, caps)


def test_heavy_edge_no_edges():
    """A 1-stage 1-replica-per-stage job has an empty edge set."""
    job = make_simple_job(job_id=0, replicas=(1,), h_mb=0.0)
    graph = build_job_graph(job)
    assert heavy_edge(graph, [(0, 1)]) == heavy_edge_reference(
        graph, [(0, 1)]
    )


# ---------------------------------------------------------------------------
# alpha: vectorized == per-(server, stage) beta reference
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_alpha_matches_reference(seed):
    """Exact float equality on greedy placements, hom + mixed specs."""
    rng = np.random.default_rng(seed)
    specs = (_hom_spec(), mixed_cluster_spec(num_servers=6, seed=seed,
                                             n_classes=2))
    jobs = _trace_jobs(seed)
    for spec in specs:
        for job in jobs[:8]:
            caps = _random_caps(rng, spec, job.g)
            graph = build_job_graph(job)
            assignment = heavy_edge(graph, caps)
            placement = timing.placement_from_assignment(job, assignment)
            a_vec = timing.alpha(job, placement, spec)
            a_ref = timing.alpha_reference(job, placement, spec)
            assert a_vec == a_ref  # bitwise, not approx


def test_alpha_scalar_and_array_paths_agree():
    """Placements straddling the scalar-cells threshold agree bitwise."""
    spec = _hom_spec(num_servers=16)
    for replicas in ((4, 4), (8, 8, 8, 8), (2,) * 8, (32,)):
        job = make_simple_job(job_id=0, replicas=replicas, h_mb=128.0)
        caps = select_servers(
            {m: 8 for m in range(16)}, job.g, consolidate=True
        )
        graph = build_job_graph(job)
        placement = timing.placement_from_assignment(
            job, heavy_edge(graph, caps)
        )
        assert timing.alpha(job, placement, spec) == timing.alpha_reference(
            job, placement, spec
        )


def test_alpha_empty_placement():
    job = make_simple_job(job_id=0, replicas=(2,))
    spec = _hom_spec()
    assert timing.alpha(job, {}, spec) == 0.0


# ---------------------------------------------------------------------------
# map_job: the fused array pipeline == reference pipeline (incl. refine)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_map_job_matches_reference(seed, refine):
    rng = np.random.default_rng(seed)
    specs = (_hom_spec(), mixed_cluster_spec(num_servers=7, seed=seed,
                                             n_classes=3))
    jobs = _trace_jobs(seed)
    for spec in specs:
        for job in jobs[:6]:
            caps = _random_caps(rng, spec, job.g)
            p_ref, a_ref = map_job(job, caps, spec, refine=refine,
                                   reference=True)
            p_arr, a_arr = map_job(job, caps, spec, refine=refine)
            assert a_arr == a_ref
            assert_placements_equal(p_arr, p_ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_map_job_canonical_matches_reference_refined(seed):
    """The rank-relabeled path (what PlacementCache memoizes), refined."""
    rng = np.random.default_rng(seed)
    spec = mixed_cluster_spec(num_servers=6, seed=seed, n_classes=3)
    jobs = _trace_jobs(seed, n_jobs=15)
    for job in jobs[:6]:
        caps = _random_caps(rng, spec, job.g)
        p_ref, a_ref = map_job_canonical(job, caps, spec, refine=True,
                                         reference=True)
        p_arr, a_arr = map_job_canonical(job, caps, spec, refine=True)
        assert a_arr == a_ref
        assert_placements_equal(p_arr, p_ref)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_placement_cache_seed_reuse_across_class_layouts(seed):
    """Mixed-cluster cache misses that share (config, shape) with an
    earlier class layout reuse its seeds/refined arrays — the reused-path
    result must still equal a fresh reference evaluation."""
    rng = np.random.default_rng(seed)
    spec = mixed_cluster_spec(num_servers=8, seed=seed, n_classes=3)
    cache = PlacementCache(spec, refine=True)
    jobs = _trace_jobs(seed, n_jobs=10)
    for job in jobs[:4]:
        for _ in range(6):  # several random layouts -> shape collisions
            caps = _random_caps(rng, spec, job.g)
            p_c, a_c = cache.map_job(job, caps)
            p_ref, a_ref = map_job_canonical(job, caps, spec, refine=True,
                                             reference=True)
            assert a_c == a_ref
            assert_placements_equal(p_c, p_ref)


def test_map_job_rejects_wrong_capacity_total():
    job = make_simple_job(job_id=0, replicas=(2, 2))
    spec = _hom_spec()
    with pytest.raises(ValueError):
        map_job(job, [(0, 3)], spec)
    with pytest.raises(ValueError):
        map_job(job, [(0, 3)], spec, reference=True)


# ---------------------------------------------------------------------------
# FreeCapsSnapshot: prefix carving == select_servers, buckets == recount
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_snapshot_carving_matches_select_servers(seed):
    rng = np.random.default_rng(seed)
    specs = (_hom_spec(), mixed_cluster_spec(num_servers=9, seed=seed,
                                             n_classes=3))
    for spec in specs:
        free = {
            m: int(rng.integers(0, spec.server_gpus(m) + 1))
            for m in range(spec.num_servers)
        }
        total = sum(free.values())
        if total == 0:
            continue
        snap = FreeCapsSnapshot.consolidating(free, total, spec)
        for g in rng.integers(1, total + 1, size=12):
            g = int(g)
            assert snap.caps_for(g) == tuple(
                select_servers(free, g, consolidate=True, spec=spec)
            )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_bucketed_select_matches_counting_sort(seed):
    """ClusterState-maintained buckets == per-call counting sort."""
    from repro.core.cluster import ClusterState

    rng = np.random.default_rng(seed)
    specs = (_hom_spec(num_servers=6), mixed_cluster_spec(
        num_servers=6, seed=seed, n_classes=2))
    for spec in specs:
        cs = ClusterState(spec)
        jid = 0
        for _ in range(25):
            # random allocate/release churn to exercise bucket moves
            if cs.total_free > 0 and rng.random() < 0.7:
                g = int(rng.integers(1, cs.total_free + 1))
                caps = select_servers(
                    cs.free, g, consolidate=bool(rng.integers(0, 2)),
                    spec=spec,
                    buckets=cs.free_buckets, total_free=cs.total_free,
                )
                cs.allocate(jid, {m: np.array([c]) for m, c in caps},
                            counts=dict(caps))
                jid += 1
            elif cs._job_alloc:
                victim = next(iter(cs._job_alloc))
                cs.release(victim)
            # invariant: buckets always equal a fresh counting sort
            for consolidate in (True, False):
                for g in (1, min(4, max(1, cs.total_free))):
                    if cs.total_free < g:
                        continue
                    fast = select_servers(
                        cs.free, g, consolidate=consolidate, spec=spec,
                        buckets=cs.free_buckets, total_free=cs.total_free,
                    )
                    slow = select_servers(
                        cs.free, g, consolidate=consolidate, spec=spec
                    )
                    assert fast == slow


# ---------------------------------------------------------------------------
# Satellites: SimResult.makespan guard
# ---------------------------------------------------------------------------


def test_makespan_empty_records():
    from repro.core.simulator import SimResult

    res = SimResult()
    assert res.makespan == 0.0
    assert res.mean_jct == 0.0
