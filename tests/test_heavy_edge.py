"""Heavy-Edge GPU mapping: Fig. 2 reproduction + hypothesis properties."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

import repro.core.heavy_edge as he
from repro.core import ClusterSpec, build_job_graph
from repro.core.job import JobSpec, StageSpec
from repro.core import timing

from conftest import make_simple_job

MB = 1024.0**2


def fig2_job() -> JobSpec:
    # 3 stages x 2 replicas; S1 ring edge 20 MB, inter-stage pair edges 1 MB.
    return JobSpec(
        job_id=0,
        stages=(
            StageSpec(p_f=0.1, p_b=0.2, d_in=0.0, d_out=1 * MB, h=20 * MB, k=2),
            StageSpec(p_f=0.1, p_b=0.2, d_in=1 * MB, d_out=1 * MB, h=0.5 * MB, k=2),
            StageSpec(p_f=0.1, p_b=0.2, d_in=1 * MB, d_out=0.0, h=0.1 * MB, k=2),
        ),
        n_iters=100,
    )


class TestFig2:
    def test_graph_edges(self):
        g = build_job_graph(fig2_job())
        # S1 intra-stage RAR pair: 2*(k-1)/k*h = 20 MB
        assert g.edges[((0, 0), (0, 1))] == pytest.approx(20 * MB)
        # inter-stage pair: 2*d_out/k_next = 1 MB
        assert g.edges[((0, 0), (1, 0))] == pytest.approx(1 * MB)
        assert g.edges[((1, 0), (2, 1))] == pytest.approx(1 * MB)

    def test_mapping_matches_paper(self):
        """Paper Fig. 2: S1+S2 pairs on the 4-GPU server, S3 split."""
        g = build_job_graph(fig2_job())
        assign = he.heavy_edge(g, [(0, 4), (1, 1), (2, 1)])
        assert assign[(0, 0)] == assign[(0, 1)] == 0
        assert assign[(1, 0)] == assign[(1, 1)] == 0
        assert {assign[(2, 0)], assign[(2, 1)]} == {1, 2}

    def test_matches_ilp_optimum(self):
        from repro.core.ilp import exact_min_cut

        g = build_job_graph(fig2_job())
        assign = he.heavy_edge(g, [(0, 4), (1, 1), (2, 1)])
        _, opt_cut = exact_min_cut(g, [(0, 4), (1, 1), (2, 1)])
        assert g.cut_weight(assign) == pytest.approx(opt_cut)


@st.composite
def job_and_caps(draw):
    n_stages = draw(st.integers(1, 3))
    replicas = tuple(draw(st.integers(1, 4)) for _ in range(n_stages))
    job = make_simple_job(
        replicas=replicas,
        p=draw(st.floats(0.01, 1.0)),
        act_mb=draw(st.floats(0.1, 64.0)),
        h_mb=draw(st.floats(0.1, 512.0)),
        allreduce=draw(st.sampled_from(["rar", "tar"])),
    )
    g_total = job.g
    # random capacity split summing to g_total
    n_servers = draw(st.integers(1, g_total))
    cuts = sorted(
        draw(
            st.lists(
                st.integers(1, g_total - 1),
                max_size=n_servers - 1,
                unique=True,
            )
        )
    ) if g_total > 1 else []
    sizes = [b - a for a, b in zip([0] + cuts, cuts + [g_total])]
    caps = [(m, s) for m, s in enumerate(sizes) if s > 0]
    return job, caps


class TestHeavyEdgeProperties:
    @settings(max_examples=60, deadline=None)
    @given(job_and_caps())
    def test_valid_partition(self, jc):
        job, caps = jc
        g = build_job_graph(job)
        assign = he.heavy_edge(g, caps)
        # every replica assigned exactly once
        assert set(assign) == set(g.vertices)
        # capacity respected exactly
        from collections import Counter

        counts = Counter(assign.values())
        for m, c in caps:
            assert counts.get(m, 0) == c

    @settings(max_examples=30, deadline=None)
    @given(job_and_caps())
    def test_deterministic(self, jc):
        job, caps = jc
        g = build_job_graph(job)
        assert he.heavy_edge(g, caps) == he.heavy_edge(g, caps)

    # Statistical sanity property: greedy can lose to the random-assignment
    # mean on adversarial draws (it's a heuristic for an NP-complete
    # problem), so this test is derandomized — a fixed, representative
    # example corpus rather than a fresh fuzz each run.
    @settings(max_examples=30, deadline=None, derandomize=True)
    @given(job_and_caps(), st.integers(0, 2**31 - 1))
    def test_no_worse_than_random(self, jc, seed):
        """Greedy cut <= 1.1 x mean random-assignment cut (sanity)."""
        job, caps = jc
        g = build_job_graph(job)
        if not g.edges:
            return
        assign = he.heavy_edge(g, caps)
        rng = np.random.default_rng(seed)
        cuts = []
        slots = [m for m, c in caps for _ in range(c)]
        for _ in range(8):
            perm = rng.permutation(len(slots))
            rand_assign = {
                v: slots[perm[i]] for i, v in enumerate(g.vertices)
            }
            cuts.append(g.cut_weight(rand_assign))
        # statistical sanity with slack: greedy is a heuristic, allow 10%
        assert g.cut_weight(assign) <= np.mean(cuts) * 1.10 + 1e-6


class TestAlphaBounds:
    @settings(max_examples=40, deadline=None)
    @given(job_and_caps())
    def test_alpha_min_le_alpha_max(self, jc):
        job, _ = jc
        cluster = ClusterSpec(
            num_servers=16, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
        )
        a_max = timing.alpha_max(job, cluster)
        a_min = he.alpha_min_estimate(job, cluster)
        assert a_min <= a_max + 1e-9

    def test_select_servers_modes(self):
        free = {0: 2, 1: 8, 2: 5, 3: 0}
        consolidated = he.select_servers(free, 10, consolidate=True)
        assert consolidated[0] == (1, 8)  # most available first
        frag = he.select_servers(free, 3, consolidate=False)
        assert frag[0] == (0, 2)  # least available (>0) first
        with pytest.raises(ValueError):
            he.select_servers(free, 99, consolidate=True)
