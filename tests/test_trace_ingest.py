"""Datacenter-trace CSV ingestion (ISSUE 6 tentpole a).

Round-trip CSV -> JobSpec -> Scenario JSON v1, the documented
malformed-row policy (bad timestamps, zero-GPU rows, out-of-order
submits), alias resolution, recurrence interning, and the committed
sample fixture under tests/golden/.
"""
import json

import pytest

pytestmark = pytest.mark.sched

from repro.core import (
    ClusterSpec,
    IngestStats,
    JsonlJobs,
    Scenario,
    TraceSchemaError,
    ingest_scenario,
    iter_trace_csv,
    load_trace_csv,
    simulate,
    trace_jobs_source,
)
from repro.core.asrpt import ASRPTPolicy
from repro.core.predictor import make_predictor
from repro.core.profiles import PAPER_MODELS
from repro.core.scenario import jobs_from_dicts, jobs_to_dicts

SAMPLE = "tests/golden/sample_trace.csv"

HEADER = "submit_time,num_gpus,duration,user,model,group\n"


def _write(tmp_path, body, header=HEADER, name="t.csv"):
    p = tmp_path / name
    p.write_text(header + body)
    return p


def _spec(n=8):
    return ClusterSpec(
        num_servers=n, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )


# ---------------------------------------------------------------------------
# happy path + round-trip
# ---------------------------------------------------------------------------


def test_sample_fixture_parses_clean():
    st = IngestStats()
    jobs = load_trace_csv(SAMPLE, stats=st)
    assert st.n_rows == st.n_jobs == len(jobs) == 30
    assert st.n_skipped == 0
    assert jobs[0].arrival == 0.0 and st.last_submit == 1140.0
    assert all(j.g >= 1 for j in jobs)
    assert all(j.n_iters >= 1 for j in jobs)
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)
    assert [j.job_id for j in jobs] == list(range(30))


def test_round_trip_csv_jobspec_scenario_json():
    scn = ingest_scenario(SAMPLE, _spec())
    rt = Scenario.from_json(scn.to_json())
    assert rt == scn
    # and the bare jobs array round-trips through the frozen-trace format
    jobs = load_trace_csv(SAMPLE)
    assert jobs_from_dicts(jobs_to_dicts(jobs)) == jobs


def test_lazy_matches_eager_on_sorted_input():
    lazy = list(iter_trace_csv(SAMPLE))
    eager = load_trace_csv(SAMPLE)
    assert lazy == eager


def test_trace_jobs_source_is_replayable_and_simulates():
    src = trace_jobs_source(SAMPLE)
    assert len(list(src)) == 30
    assert len(list(src)) == 30  # re-opens the file: second pass works
    pol = lambda: ASRPTPolicy(make_predictor("mean"))
    stream = simulate(Scenario(jobs=src, cluster=_spec()), pol())
    eager = simulate(ingest_scenario(SAMPLE, _spec()), pol())
    assert stream.schedule_digest() == eager.schedule_digest()
    assert stream.records is None  # stream source defaults to streaming


def test_known_model_column_is_respected():
    jobs = load_trace_csv(SAMPLE)
    by_model = {j.job_id: j.model_name for j in jobs}
    # row 2 of the fixture tags bert_large explicitly
    assert by_model[1] == "bert_large"
    assert all(m in PAPER_MODELS for m in by_model.values())


def test_iterations_column_wins_over_duration(tmp_path):
    p = _write(
        tmp_path,
        "0.0,1,1800,alice,resnet152,,77\n",
        header="submit_time,num_gpus,duration,user,model,group,iterations\n",
    )
    (job,) = load_trace_csv(p)
    assert job.n_iters == 77


def test_duration_divided_by_single_device_iter_time(tmp_path):
    p = _write(tmp_path, "0.0,1,1800,alice,resnet152,\n")
    (job,) = load_trace_csv(p)
    assert job.n_iters == round(1800 / PAPER_MODELS["resnet152"].iter_time_1dev)


def test_recurrence_interning(tmp_path):
    p = _write(
        tmp_path,
        "0.0,2,100,dave,,sweep\n"
        "1.0,2,100,dave,,sweep\n"
        "2.0,2,100,erin,bert_large,\n"
        "3.0,2,100,erin,bert_large,\n"
        "4.0,4,100,erin,bert_large,\n",
    )
    jobs = load_trace_csv(p)
    # explicit group tag: same group, same (hash-assigned) model
    assert jobs[0].group_id == jobs[1].group_id
    assert jobs[0].model_name == jobs[1].model_name
    # fallback key (user, model, gpus): rows 3+4 recur, row 5 differs (g)
    assert jobs[2].group_id == jobs[3].group_id != jobs[4].group_id
    assert jobs[2].user_id == jobs[3].user_id == jobs[4].user_id


def test_iso_timestamps_normalize_to_relative_seconds(tmp_path):
    p = _write(
        tmp_path,
        "2017-10-03 14:00:00,1,600,alice,resnet152,\n"
        "2017-10-03 14:05:30,1,600,bob,resnet152,\n",
    )
    jobs = load_trace_csv(p)
    assert [j.arrival for j in jobs] == [0.0, 330.0]


def test_header_aliases_resolve(tmp_path):
    p = _write(
        tmp_path,
        "5.0,4,120\n",
        header="submitted_time,plan_gpu,run_time\n",
    )
    (job,) = load_trace_csv(p)
    assert job.arrival == 5.0 and job.g == 4


# ---------------------------------------------------------------------------
# malformed rows: fail loud, or skip-and-count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "row, needle",
    [
        ("not-a-time,1,600,a,,\n", "neither a float"),
        ("-5.0,1,600,a,,\n", "negative or non-finite"),
        ("nan,1,600,a,,\n", "negative or non-finite"),
        ("0.0,0,600,a,,\n", "positive integer"),
        ("0.0,-2,600,a,,\n", "positive integer"),
        ("0.0,1.5,600,a,,\n", "positive integer"),
        ("0.0,x,600,a,,\n", "not a number"),
        ("0.0,1,,a,,\n", "neither iterations nor duration"),
        ("0.0,1,-600,a,,\n", "not positive finite"),
        ("0.0,1,inf,a,,\n", "not positive finite"),
        ("0.0,1,600,a,no_such_model,\n", "not a known profile"),
        (",1,600,a,,\n", "submit_time is blank"),
    ],
)
def test_malformed_row_raises_with_location(tmp_path, row, needle):
    p = _write(tmp_path, "0.0,1,600,ok,,\n" + row)
    with pytest.raises(TraceSchemaError) as exc:
        load_trace_csv(p)
    msg = str(exc.value)
    assert needle in msg
    assert f"{p}:3:" in msg  # names file and line


def test_skip_policy_counts_and_continues(tmp_path):
    p = _write(
        tmp_path,
        "0.0,1,600,a,,\n"
        "1.0,0,600,a,,\n"  # zero-GPU: malformed
        "2.0,1,bad,a,,\n"  # bad duration: malformed
        "3.0,1,600,a,,\n",
    )
    st = IngestStats()
    jobs = load_trace_csv(p, on_error="skip", stats=st)
    assert len(jobs) == 2
    assert st.n_rows == 4 and st.n_jobs == 2 and st.n_skipped == 2
    assert st.skipped_lines == [3, 4]


def test_missing_required_column_is_header_error(tmp_path):
    p = _write(tmp_path, "1,600\n", header="num_gpus,duration\n")
    with pytest.raises(TraceSchemaError, match="missing required"):
        list(iter_trace_csv(p))
    p2 = _write(tmp_path, "0.0,1\n", header="submit_time,num_gpus\n",
                name="t2.csv")
    with pytest.raises(TraceSchemaError, match="duration"):
        list(iter_trace_csv(p2))


def test_empty_file_is_schema_error(tmp_path):
    p = tmp_path / "e.csv"
    p.write_text("")
    with pytest.raises(TraceSchemaError, match="empty file"):
        list(iter_trace_csv(p))


def test_header_error_raises_even_under_skip(tmp_path):
    p = _write(tmp_path, "1,600\n", header="num_gpus,duration\n")
    with pytest.raises(TraceSchemaError):
        list(iter_trace_csv(p, on_error="skip"))


# ---------------------------------------------------------------------------
# out-of-order submits: a file-level property, not a row defect
# ---------------------------------------------------------------------------


def test_out_of_order_lazy_raises_eager_sorts(tmp_path):
    p = _write(
        tmp_path,
        "10.0,1,600,a,,\n"
        "5.0,1,600,b,,\n",
    )
    with pytest.raises(TraceSchemaError, match="out-of-order submit"):
        list(iter_trace_csv(p))
    jobs = load_trace_csv(p)  # eager path sorts
    assert [j.arrival for j in jobs] == [5.0, 10.0]
    assert [j.job_id for j in jobs] == [0, 1]  # ids reassigned in order


def test_out_of_order_raises_even_under_skip_policy(tmp_path):
    p = _write(tmp_path, "10.0,1,600,a,,\n5.0,1,600,b,,\n")
    with pytest.raises(TraceSchemaError, match="out-of-order"):
        list(iter_trace_csv(p, on_error="skip"))


# ---------------------------------------------------------------------------
# CLI + JSONL re-shard
# ---------------------------------------------------------------------------


def test_cli_convert_jsonl_round_trips(tmp_path):
    from repro.core.trace_ingest import _main

    out = tmp_path / "shard.jsonl"
    assert _main(["convert", SAMPLE, "--jsonl", str(out)]) == 0
    shard = list(JsonlJobs(out))
    assert shard == list(iter_trace_csv(SAMPLE))


def test_cli_convert_scenario_validates_against_schema_v1(tmp_path):
    from repro.core.trace_ingest import _main

    out = tmp_path / "scn.json"
    assert _main(
        ["convert", SAMPLE, "--scenario", str(out),
         "--servers", "8", "--gpus-per-server", "8"]
    ) == 0
    d = json.loads(out.read_text())
    assert d["schema"] == 1
    scn = Scenario.from_dict(d)
    assert len(scn.jobs) == 30
