"""Streaming == materialized (ISSUE 6 tentpole c).

The streaming result backend folds each completed ``JobRecord`` into
incremental aggregates (Shewchuk partials for the sums, a commutative
sha256 accumulator for the digest) instead of keeping the record dict;
the arrival heap is fed from a lazy iterator instead of pre-loaded.
Every test here pins the contract that the two backends are
*bit-identical*: same ``schedule_digest``, same exact flow-time /
completion-time / makespan floats — on all 10 golden scenarios and on
random scenarios with faults, stragglers, and elastic join/leave.
"""
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    BASELINES,
    IterJobs,
    JsonlJobs,
    Scenario,
    TraceConfig,
    elastic_scenario,
    generate_trace,
    jobs_to_jsonl,
    make_predictor,
    simulate,
    straggler_scenario,
)
from test_golden import SCENARIOS, load_jobs

POLICY_NAMES = sorted(["A-SRPT", "SPJF", "WCS-Duration"])


def _policy(name):
    if name == "A-SRPT":
        return ASRPTPolicy(make_predictor("mean"), tau=2.0)
    return BASELINES[name](make_predictor("mean"))


def assert_equivalent(mat, stm):
    """Materialized result `mat` vs streaming result `stm`: the full
    bit-identical contract."""
    assert mat.records is not None and stm.records is None
    assert stm.n_jobs == len(mat.records)
    assert stm.schedule_digest() == mat.schedule_digest()
    assert stm.total_flow_time == mat.total_flow_time
    assert stm.total_completion_time == mat.total_completion_time
    assert stm.makespan == mat.makespan
    assert stm.mean_jct == mat.mean_jct
    assert stm.peak_queue_depth == mat.peak_queue_depth
    assert stm.n_migrations == mat.n_migrations
    assert stm.n_events == mat.n_events


# ---------------------------------------------------------------------------
# all 10 golden scenarios
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden_jobs():
    return load_jobs()


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_streaming_matches_materialized_on_goldens(name, golden_jobs):
    cluster_fn, policy_fn, kwargs = SCENARIOS[name]
    mat = simulate(golden_jobs, cluster_fn(), policy_fn(), **kwargs)
    stm = simulate(
        golden_jobs, cluster_fn(), policy_fn(), stream=True, **kwargs
    )
    assert_equivalent(mat, stm)


def test_lazy_source_matches_tuple_source_on_golden(golden_jobs):
    """Same schedule whether arrivals are pre-loaded from a tuple or
    pulled one at a time from a JobStream."""
    cluster_fn, policy_fn, kwargs = SCENARIOS["A-SRPT (migrate) @het+straggler"]
    mat = simulate(golden_jobs, cluster_fn(), policy_fn(), **kwargs)
    src = IterJobs(lambda: iter(golden_jobs), name="golden")
    stm = simulate(src, cluster_fn(), policy_fn(), **kwargs)
    assert_equivalent(mat, stm)


def test_jsonl_shard_source_matches_on_golden(tmp_path, golden_jobs):
    cluster_fn, policy_fn, kwargs = SCENARIOS["A-SRPT @het+fault"]
    shard = tmp_path / "golden.jsonl"
    assert jobs_to_jsonl(golden_jobs, shard) == len(golden_jobs)
    mat = simulate(golden_jobs, cluster_fn(), policy_fn(), **kwargs)
    stm = simulate(JsonlJobs(shard), cluster_fn(), policy_fn(), **kwargs)
    assert_equivalent(mat, stm)


# ---------------------------------------------------------------------------
# property: random scenarios incl. faults / stragglers / elastic events
# ---------------------------------------------------------------------------


def _stream_of(scenario: Scenario) -> Scenario:
    """The same scenario with a lazy jobs source."""
    jobs = scenario.jobs
    return Scenario(
        jobs=IterJobs(lambda: iter(jobs), name="prop"),
        cluster=scenario.cluster,
        events=scenario.events,
        name=scenario.name,
    )


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 10_000),
    st.sampled_from(POLICY_NAMES),
    st.sampled_from(["straggler", "elastic"]),
)
def test_streaming_equivalence_random_scenarios(seed, pname, kind):
    cfg = TraceConfig(
        n_jobs=60, horizon=900.0, seed=seed, max_gpus_per_job=8
    )
    if kind == "straggler":
        scenario = straggler_scenario(cfg, event_seed=seed + 1)
    else:
        scenario = elastic_scenario(cfg)
    mat = simulate(scenario, _policy(pname))
    stm = simulate(_stream_of(scenario), _policy(pname))
    assert_equivalent(mat, stm)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_streaming_equivalence_with_migration_under_faults(seed):
    jobs = generate_trace(
        TraceConfig(n_jobs=50, horizon=600.0, seed=seed, max_gpus_per_job=8)
    )
    cluster_fn, _, _ = SCENARIOS["A-SRPT @het"]
    faults = [(150.0, 0), (300.0, 5)]
    stragglers = [(100.0, 2, 0.25)]

    def pol():
        return ASRPTPolicy(
            make_predictor("mean"), tau=2.0,
            migrate=True, migration_penalty=20.0,
        )

    mat = simulate(
        jobs, cluster_fn(), pol(),
        faults=faults, degradations=stragglers,
    )
    stm = simulate(
        IterJobs(lambda: iter(jobs)), cluster_fn(), pol(),
        faults=faults, degradations=stragglers,
    )
    assert_equivalent(mat, stm)


# ---------------------------------------------------------------------------
# backend selection + stream misuse fail loud
# ---------------------------------------------------------------------------


def test_stream_default_tracks_jobs_source(golden_jobs):
    cluster_fn, policy_fn, _ = SCENARIOS["A-SRPT @hom"]
    assert simulate(golden_jobs, cluster_fn(), policy_fn()).records \
        is not None
    src = IterJobs(lambda: iter(golden_jobs))
    assert simulate(src, cluster_fn(), policy_fn()).records is None


def test_materialized_view_over_stream_source(golden_jobs):
    """stream=False forces the record dict even from a lazy source."""
    cluster_fn, policy_fn, _ = SCENARIOS["A-SRPT @hom"]
    mat = simulate(golden_jobs, cluster_fn(), policy_fn())
    via_stream_src = simulate(
        IterJobs(lambda: iter(golden_jobs)), cluster_fn(), policy_fn(),
        stream=False,
    )
    assert via_stream_src.records is not None
    assert via_stream_src.schedule_digest() == mat.schedule_digest()


def test_streaming_result_has_no_records_api(golden_jobs):
    cluster_fn, policy_fn, _ = SCENARIOS["A-SRPT @hom"]
    res = simulate(golden_jobs, cluster_fn(), policy_fn(), stream=True)
    assert res.records is None
    assert res.n_jobs == len(golden_jobs)
    assert res.mean_jct > 0.0 and res.makespan > 0.0


def test_out_of_order_stream_fails_loud(golden_jobs):
    cluster_fn, policy_fn, _ = SCENARIOS["A-SRPT @hom"]
    bad = [golden_jobs[5], golden_jobs[3]]  # arrival order regression
    src = IterJobs(lambda: iter(bad))
    with pytest.raises(ValueError, match="out of time order"):
        simulate(src, cluster_fn(), policy_fn())


def test_single_shot_iterjobs_second_pass_fails_loud(golden_jobs):
    src = IterJobs(iter(golden_jobs))  # bare iterator: single-shot
    assert sum(1 for _ in src) == len(golden_jobs)
    with pytest.raises(RuntimeError, match="single-shot"):
        iter(src)


def test_scenario_stream_refuses_to_serialize(golden_jobs):
    cluster_fn, _, _ = SCENARIOS["A-SRPT @hom"]
    scn = Scenario(
        jobs=IterJobs(lambda: iter(golden_jobs)), cluster=cluster_fn()
    )
    with pytest.raises(TypeError, match="materialize"):
        scn.to_dict()
    mat = scn.materialize()
    assert isinstance(mat.jobs, tuple) and len(mat.jobs) == len(golden_jobs)
    assert mat.to_dict()["jobs"]  # tuple-backed copy serializes
