"""Virtual single-machine SRPT: optimality + bookkeeping properties."""
import itertools

import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core.srpt import VirtualSRPT, srpt_total_completion


def brute_force_nonpreemptive(jobs):
    """Best total completion over all non-preemptive orderings."""
    best = float("inf")
    for perm in itertools.permutations(jobs):
        t, total = 0.0, 0.0
        for jid, r, w in perm:
            t = max(t, r) + w
            total += t
        best = min(best, total)
    return best


jobs_strategy = st.lists(
    st.tuples(
        st.floats(0.0, 20.0),  # arrival
        st.floats(0.01, 10.0),  # work
    ),
    min_size=1,
    max_size=6,
)


class TestSRPTOptimality:
    @settings(max_examples=80, deadline=None)
    @given(jobs_strategy)
    def test_beats_all_nonpreemptive_orders(self, raw):
        jobs = [(i, r, w) for i, (r, w) in enumerate(raw)]
        total, _ = srpt_total_completion(jobs)
        assert total <= brute_force_nonpreemptive(jobs) + 1e-6

    @settings(max_examples=60, deadline=None)
    @given(jobs_strategy)
    def test_completion_bounds(self, raw):
        jobs = [(i, r, w) for i, (r, w) in enumerate(raw)]
        _, completions = srpt_total_completion(jobs)
        total_work = sum(w for _, _, w in jobs)
        for jid, r, w in jobs:
            c = completions[jid]
            assert c >= r + w - 1e-9  # can't finish before work done
            assert c <= max(r_ for _, r_, _ in jobs) + total_work + 1e-9

    def test_preemption_helps(self):
        # long job at t=0, short at t=1: SRPT preempts
        total, comp = srpt_total_completion([(0, 0.0, 10.0), (1, 1.0, 1.0)])
        assert comp[1] == pytest.approx(2.0)  # short done at 2
        assert comp[0] == pytest.approx(11.0)
        # non-preemptive best: 10 + 11 = 21 > 13
        assert total == pytest.approx(13.0)


class TestVirtualMachine:
    def test_zero_work_completes_instantly(self):
        vm = VirtualSRPT()
        vm.arrive(5.0, 1, 0.0)
        done = vm.advance(5.0)
        assert done == [(5.0, 1)]

    def test_incremental_matches_offline(self):
        jobs = [(0, 0.0, 3.0), (1, 1.0, 1.0), (2, 1.5, 0.5)]
        _, offline = srpt_total_completion(jobs)
        vm = VirtualSRPT()
        seen = {}
        events = sorted(jobs, key=lambda j: j[1])
        for jid, r, w in events:
            vm.arrive(r, jid, w)
        for t in [1.0, 2.0, 3.0, 10.0]:
            for ct, jid in vm.advance(t):
                seen[jid] = ct
        assert seen == pytest.approx(offline)

    def test_next_completion_time(self):
        vm = VirtualSRPT()
        vm.arrive(0.0, 0, 2.0)
        assert vm.next_completion_time() == pytest.approx(2.0)
        vm.arrive(1.0, 1, 0.5)  # preempts
        assert vm.next_completion_time() == pytest.approx(1.5)
