"""Per-architecture smoke tests: REDUCED same-family configs, one forward
and one optimizer step on CPU, asserting shapes and finiteness.
(Full configs are exercised only via the dry-run — no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models import Model
from repro.train.data import make_batch
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def _batch(cfg, B=2, S=64):
    return {
        k: jnp.asarray(v)
        for k, v in make_batch(cfg, B, S, step=0, seed=0).items()
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, AdamWConfig(total_steps=10)))
    batch = _batch(cfg)
    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.opt.step) == 1
    # params actually changed
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        state.params, new_state.params,
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_shapes(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, B=2, S=64)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(metrics["n_tokens"]) > 0


def test_loss_decreases_on_repeated_batch():
    """Overfit one batch for a few steps: loss must drop (end-to-end sanity
    of grads + optimizer across the whole stack)."""
    cfg = reduced_config("deepseek-7b")
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(
        make_train_step(model, AdamWConfig(lr_peak=3e-3, warmup_steps=1,
                                           total_steps=1000))
    )
    batch = _batch(cfg, B=4, S=32)
    losses = []
    for _ in range(12):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_full_param_counts_match_spec():
    """Full configs (abstract shapes only) land near their nameplate sizes."""
    expected = {
        "qwen3-32b": 33e9,
        "deepseek-7b": 7e9,
        "granite-34b": 34e9,
        "h2o-danube-3-4b": 4e9,
        "llava-next-mistral-7b": 7.3e9,
        "mamba2-370m": 0.37e9,
        "jamba-1.5-large-398b": 398e9,
        "qwen3-moe-30b-a3b": 30.5e9,
        "hubert-xlarge": 0.96e9,
        "moonshot-v1-16b-a3b": 28e9,  # 48L as assigned (HF model uses 27L)
    }
    for arch, want in expected.items():
        cfg = get_config(arch)
        specs = Model(cfg).param_specs()
        total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(specs))
        assert abs(total - want) / want < 0.12, (arch, total, want)


def test_microbatch_accumulation_matches_single():
    cfg = reduced_config("deepseek-7b")
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=4, S=32)
    s1, m1 = jax.jit(make_train_step(model, AdamWConfig()))(state, batch)
    state2 = init_train_state(model, jax.random.PRNGKey(0))
    s2, m2 = jax.jit(
        make_train_step(model, AdamWConfig(), num_microbatches=2)
    )(state2, batch)
    # same data -> nearly identical update (fp accumulation order differs)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        s1.params, s2.params,
    )
    assert max(jax.tree.leaves(d)) < 5e-3
