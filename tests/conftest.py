"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 placeholder devices."""
import pytest

from repro.core import ClusterSpec
from repro.core.job import JobSpec, StageSpec


@pytest.fixture
def cluster() -> ClusterSpec:
    # paper's simulation settings: 8-GPU servers, 10 Gbps NIC, 300 GB/s intra
    return ClusterSpec(
        num_servers=10, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )


def make_simple_job(
    job_id=0,
    replicas=(2, 2),
    p=0.1,
    act_mb=4.0,
    h_mb=64.0,
    n_iters=10,
    arrival=0.0,
    allreduce="rar",
    group_id=-1,
):
    MB = 1024.0**2
    stages = []
    S = len(replicas)
    for s, k in enumerate(replicas):
        stages.append(
            StageSpec(
                p_f=p / 3,
                p_b=2 * p / 3,
                d_in=(replicas[s - 1] * act_mb * MB / k) if s > 0 else 0.0,
                d_out=act_mb * MB if s < S - 1 else 0.0,
                h=h_mb * MB,
                k=k,
            )
        )
    return JobSpec(
        job_id=job_id,
        stages=tuple(stages),
        n_iters=n_iters,
        arrival=arrival,
        allreduce=allreduce,
        group_id=group_id,
    )
