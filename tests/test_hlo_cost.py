"""Loop-aware HLO cost analyzer vs unrolled ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _scan_matmuls(L, D=256, B=64):
    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    return jax.jit(f).lower(ws, x).compile(), 2 * B * D * D * L


@pytest.mark.parametrize("L", [1, 4, 16])
def test_scan_flops_scale_with_trip_count(L):
    compiled, expected = _scan_matmuls(L)
    mc = hlo_cost.analyze(compiled.as_text())
    assert expected <= mc.flops <= expected * 1.1


def test_matches_unrolled():
    D, B, L = 128, 32, 6

    def f(ws, x, unroll):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws, unroll=unroll)
        return h.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    c_loop = jax.jit(lambda w, y: f(w, y, 1)).lower(ws, x).compile()
    c_flat = jax.jit(lambda w, y: f(w, y, True)).lower(ws, x).compile()
    m_loop = hlo_cost.analyze(c_loop.as_text())
    m_flat = hlo_cost.analyze(c_flat.as_text())
    assert m_loop.flops == pytest.approx(m_flat.flops, rel=0.05)


def test_nested_scans():
    D = 128

    def g(ws, x):
        def outer(h, w):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=4)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h.sum()

    ws = jax.ShapeDtypeStruct((3, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((32, D), jnp.float32)
    c = jax.jit(g).lower(ws, x).compile()
    mc = hlo_cost.analyze(c.as_text())
    expected = 2 * 32 * D * D * 12  # 3 outer x 4 inner
    assert expected <= mc.flops <= expected * 1.15


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    mc = hlo_cost.analyze(c.as_text())
    expected = 2 * 4 * 64 * 32 * 16
    assert mc.flops == pytest.approx(expected, rel=0.2)


def test_collectives_counted_per_iteration():
    import numpy as np
    if len(jax.devices()) < 2:
        pytest.skip("needs multiple host devices")
