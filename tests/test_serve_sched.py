"""Serving workloads on the event stream (ISSUE 9).

Covers the scheduling half of the serving stack: the schema-v2
``RequestStream`` (strict round-trip serialization), the serving
metrics (``slo_attainment``, request-latency percentiles on the
bounded estimators, training interference), SLO-bound scale-ups
preempting comm-heavy training jobs end to end, and — the safety rail
for everything that already works — byte-identity of all ten golden
schedules, which carry no request streams and therefore must not see
the serve lane at all.  The batched-serving *engine* correctness sweep
lives in tests/test_serve_batched.py; the CI gate regime in
benchmarks/sched_scale.py (``--serve``).
"""
import json
import pathlib

import pytest

pytestmark = pytest.mark.sched

from repro.core import (  # noqa: E402
    ASRPTPolicy,
    ClusterSpec,
    RequestStream,
    Scenario,
    TraceConfig,
    generate_trace,
    make_predictor,
    request_stream_from_dict,
    request_stream_to_dict,
    simulate,
)
from repro.core.simulator import SERVE_LAT_QUANTILES, SimResult  # noqa: E402
from repro.serve.latency import (  # noqa: E402
    BatchLatencyModel,
    DEFAULT_SERVE_MODEL,
)

# pytest inserts the tests dir on sys.path (no tests/__init__.py)
from test_golden import SCENARIOS, load_jobs, run_scenario  # noqa: E402

sched_scale = pytest.importorskip(
    "benchmarks.sched_scale",
    reason="benchmarks namespace package needs the repo root on sys.path",
)


def _stream(**kw):
    base = dict(stream_id=0, rate=100.0, duration=60.0, slo=0.5)
    base.update(kw)
    return RequestStream(**base)


def _cluster():
    return ClusterSpec(
        num_servers=3, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )


def _pol():
    return ASRPTPolicy(make_predictor("mean"), tau=2.0, refine_mapping=False)


# ---------------------------------------------------------------------------
# schema: round-trip + strict deserialization
# ---------------------------------------------------------------------------


def test_request_stream_roundtrip():
    rs = _stream(
        start=12.5, diurnal_amplitude=0.4, diurnal_period=3600.0,
        phase=0.3, gpus=4, max_replicas=3, max_batch=16,
        svc_base=0.02, svc_per_req=0.002, seed=7,
    )
    assert request_stream_from_dict(request_stream_to_dict(rs)) == rs


def test_request_stream_dict_is_json_stable():
    d = request_stream_to_dict(_stream())
    assert request_stream_from_dict(json.loads(json.dumps(d))) == _stream()


def test_request_stream_rejects_unknown_kind():
    d = request_stream_to_dict(_stream())
    d["kind"] = "mystery-stream"
    with pytest.raises(ValueError, match="unknown request-stream kind"):
        request_stream_from_dict(d)


def test_request_stream_rejects_unknown_field():
    d = request_stream_to_dict(_stream())
    d["qps_target"] = 10.0
    with pytest.raises(ValueError, match="qps_target"):
        request_stream_from_dict(d)


def test_request_stream_rejects_missing_required():
    d = request_stream_to_dict(_stream())
    del d["slo"]
    with pytest.raises(ValueError, match="slo"):
        request_stream_from_dict(d)


def test_scenario_with_streams_serializes_as_schema_2():
    sc = Scenario(jobs=(), cluster=_cluster(), request_streams=(_stream(),))
    d = sc.to_dict()
    assert d["schema"] == 2
    assert len(d["request_streams"]) == 1
    assert Scenario.from_dict(d) == sc


def test_request_free_scenario_stays_schema_1():
    """No streams -> the document is byte-compatible with every schema-1
    reader: version 1, no request_streams key at all."""
    d = Scenario(jobs=(), cluster=_cluster()).to_dict()
    assert d["schema"] == 1
    assert "request_streams" not in d


def test_streams_under_schema_1_rejected():
    d = Scenario(
        jobs=(), cluster=_cluster(), request_streams=(_stream(),)
    ).to_dict()
    d["schema"] = 1
    with pytest.raises(ValueError, match="schema 2"):
        Scenario.from_dict(d)


def test_duplicate_stream_ids_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        Scenario(
            jobs=(), cluster=_cluster(),
            request_streams=(_stream(), _stream(rate=5.0)),
        )


def test_replica_must_fit_one_server():
    with pytest.raises(ValueError, match="largest server"):
        Scenario(
            jobs=(), cluster=_cluster(),
            request_streams=(_stream(gpus=9),),
        )


def test_default_service_curve_is_the_committed_calibration():
    rs = _stream()
    assert rs.svc_base == DEFAULT_SERVE_MODEL.batch_base
    assert rs.svc_per_req == DEFAULT_SERVE_MODEL.batch_per_req
    b = rs.max_batch
    assert rs.service_time(b) == pytest.approx(
        DEFAULT_SERVE_MODEL.service_time(b)
    )


def test_latency_model_validation():
    with pytest.raises(ValueError, match="per_req"):
        BatchLatencyModel(base=0.1, per_req=0.0)
    with pytest.raises(ValueError, match="base"):
        BatchLatencyModel(base=-1.0, per_req=0.1)
    with pytest.raises(ValueError, match="batch"):
        DEFAULT_SERVE_MODEL.step_time(0)


def test_arrivals_replayable_and_bounded():
    rs = _stream(
        start=10.0, duration=50.0, diurnal_amplitude=0.8,
        diurnal_period=50.0, seed=3,
    )
    a1 = list(rs.arrivals())
    a2 = list(rs.arrivals())
    assert a1 == a2, "arrival draws must replay bit-identically"
    assert all(rs.start <= t < rs.end for t in a1)
    assert a1 == sorted(a1)


def test_arrival_rate_matches_mean():
    """Thinning must deliver the configured mean rate (the sinusoid
    averages out over whole periods)."""
    rs = _stream(rate=100.0, duration=500.0, diurnal_amplitude=0.5,
                 diurnal_period=100.0, seed=0)
    n = sum(1 for _ in rs.arrivals())
    assert 0.9 * 100.0 * 500.0 <= n <= 1.1 * 100.0 * 500.0


# ---------------------------------------------------------------------------
# serving metrics: unit tests on the SimResult aggregates
# ---------------------------------------------------------------------------


def test_slo_and_latency_metrics_fold_exactly():
    res = SimResult()
    lats = [0.01 * (i % 7) + 0.001 * i for i in range(200)]
    for lat in lats:
        res._fold_request(lat, slo=0.1)
    assert res.n_requests == 200
    assert res.slo_attainment == sum(1 for x in lats if x <= 0.1) / 200
    assert res.mean_request_latency == pytest.approx(
        sum(lats) / len(lats)
    )
    # below the 8192-sample buffer the estimators are exact: identical
    # to numpy's linear-interpolation percentile over the latencies
    np = pytest.importorskip("numpy")
    for q in SERVE_LAT_QUANTILES:
        assert res.request_latency_percentile(q) == pytest.approx(
            float(np.percentile(np.asarray(lats), q))
        )


def test_empty_serving_lane_violates_nothing():
    res = SimResult()
    assert res.slo_attainment == 1.0
    assert res.request_latency_percentile(99.0) == 0.0
    assert res.mean_request_latency == 0.0


def test_untracked_request_quantile_raises():
    res = SimResult()
    res._fold_request(0.05, slo=0.1)
    with pytest.raises(RuntimeError, match="not tracked"):
        res.request_latency_percentile(42.0)


# ---------------------------------------------------------------------------
# co-scheduling end to end
# ---------------------------------------------------------------------------


def _trace(n_jobs, horizon, seed=5):
    return generate_trace(
        TraceConfig(
            n_jobs=n_jobs, horizon=horizon, seed=seed,
            single_gpu_frac=0.1, max_gpus_per_job=16,
        )
    )


def test_requests_served_alongside_training():
    """A lightly-loaded co-schedule: every request meets its SLO, every
    training job completes, and the training schedule is *unchanged* by
    a stream that fits in slack capacity."""
    jobs = _trace(12, 300.0)
    rs = _stream(rate=50.0, duration=100.0, start=5.0, gpus=2,
                 max_replicas=1, seed=1)
    base = simulate(
        Scenario(jobs=jobs, cluster=_cluster()), _pol(), validate=False
    )
    mixed = simulate(
        Scenario(jobs=jobs, cluster=_cluster(), request_streams=(rs,)),
        _pol(), validate=False,
    )
    assert mixed.n_requests > 4000
    assert mixed.slo_attainment == 1.0
    assert mixed.n_jobs == base.n_jobs == 12
    assert 0 < mixed.request_latency_percentile(99.0) < rs.slo
    assert (
        mixed.request_latency_percentile(50.0)
        <= mixed.request_latency_percentile(99.0)
    )


def test_slo_bound_requests_preempt_training():
    """The tentpole e2e: on a saturated 3-server cluster a near-capacity
    stream (full-server replicas) must preempt comm-heavy training
    allocations to scale up — and every preempted job checkpoint-restarts
    and still completes."""
    jobs = _trace(50, 400.0)
    rs = RequestStream(
        stream_id=0, rate=320.0, duration=150.0, slo=0.2,
        start=5.0, gpus=8, max_replicas=2, max_batch=8, seed=1,
    )
    res = simulate(
        Scenario(jobs=jobs, cluster=_cluster(), request_streams=(rs,)),
        _pol(), validate=False,
    )
    assert res.n_preemptions > 0
    # each preemption checkpoint-restarts exactly one training job
    assert (
        sum(r.migrations for r in res.records.values()) == res.n_preemptions
    )
    # every training job still completed (simulate would raise otherwise;
    # assert anyway — the records are the contract)
    assert res.n_jobs == 50
    assert all(r.completion > r.arrival for r in res.records.values())
    # and the serving lane held its SLO while preempting
    assert res.n_requests > 40_000
    assert res.slo_attainment >= 0.99


def test_streaming_serve_metrics_match_materialized():
    """stream=True folds records away; every serving aggregate and the
    schedule digest must come out bit-identical to the materialized
    run."""
    jobs = _trace(25, 200.0)
    rs = _stream(rate=100.0, duration=80.0, start=5.0, gpus=4,
                 max_replicas=2, seed=2)

    def sc():
        return Scenario(
            jobs=jobs, cluster=_cluster(), request_streams=(rs,)
        )

    mat = simulate(sc(), _pol(), validate=False)
    stm = simulate(sc(), _pol(), validate=False, stream=True)
    assert stm.records is None
    assert stm.schedule_digest() == mat.schedule_digest()
    assert stm.n_requests == mat.n_requests
    assert stm.n_slo_met == mat.n_slo_met
    assert stm.n_preemptions == mat.n_preemptions
    assert stm.mean_request_latency == mat.mean_request_latency
    for q in SERVE_LAT_QUANTILES:
        assert stm.request_latency_percentile(q) == (
            mat.request_latency_percentile(q)
        )


# ---------------------------------------------------------------------------
# the safety rail: request-free scenarios replay byte-identically
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden_jobs():
    return load_jobs()


@pytest.fixture(scope="module")
def golden_expected():
    p = pathlib.Path(__file__).resolve().parent / "golden" / "expected.json"
    return json.loads(p.read_text())


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_request_free_goldens_byte_identical(
    name, golden_jobs, golden_expected
):
    """All ten golden schedules carry no request streams: the serve lane
    must never arm, and every digest/flow/depth/migration count must
    stay byte-for-byte at its committed fixture."""
    got = run_scenario(name, golden_jobs)
    want = golden_expected[name]
    assert got["sha256"] == want["sha256"], (
        f"serve-lane integration drifted the request-free schedule "
        f"{name!r}"
    )
    assert got["total_flow"] == want["total_flow"], name
    assert got["peak_depth"] == want["peak_depth"], name
    assert got["n_migrations"] == want["n_migrations"], name


# ---------------------------------------------------------------------------
# the CI gate: committed baseline + regression checker
# ---------------------------------------------------------------------------


def _baseline():
    p = (
        pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks" / "BENCH_serve_baseline.json"
    )
    return json.loads(p.read_text())


def test_committed_serve_baseline_matches_ci_regime():
    """The committed baseline must be regenerable by the CI command
    (`--serve`): same regime constants, all three gated metrics
    present, and the SLO floor actually met."""
    data = _baseline()
    assert data["bench"] == "sched_scale_serve"
    assert data["n_jobs"] == sched_scale.SERVE_JOBS
    assert data["slo_gate"] == sched_scale.SERVE_SLO_GATE
    assert len(data["sha256"]) == 64
    m = data["metrics"]
    assert set(m) == {
        "slo_attainment", "p99_request_latency_s", "train_interference"
    }
    assert m["slo_attainment"] >= sched_scale.SERVE_SLO_GATE
    assert 0 < m["p99_request_latency_s"] <= sched_scale.SERVE_SLO
    assert m["train_interference"] >= 1.0
    assert data["n_requests"] > 500_000


def test_check_serve_regression_clean_pass():
    data = _baseline()
    errors, warnings, notes = sched_scale.check_serve_regression(data, data)
    assert errors == [] and warnings == []
    assert notes


def test_check_serve_regression_slo_floor_is_absolute():
    data = _baseline()
    bad = json.loads(json.dumps(data))
    bad["metrics"]["slo_attainment"] = 0.9
    errors, _, _ = sched_scale.check_serve_regression(bad, data)
    assert any("floor" in e for e in errors)
    # ... even when the baseline itself already drifted low
    errors, _, _ = sched_scale.check_serve_regression(bad, bad)
    assert any("floor" in e for e in errors)


def test_check_serve_regression_sha_mismatch_errors():
    data = _baseline()
    cur = json.loads(json.dumps(data))
    cur["sha256"] = "0" * 64
    errors, _, _ = sched_scale.check_serve_regression(cur, data)
    assert any("sha256" in e for e in errors)


def test_check_serve_regression_drift_warns():
    data = _baseline()
    cur = json.loads(json.dumps(data))
    cur["metrics"]["p99_request_latency_s"] *= 1.5
    cur["sha256"] = data["sha256"]
    errors, warnings, _ = sched_scale.check_serve_regression(cur, data)
    assert errors == []
    assert any("p99_request_latency_s" in w for w in warnings)
