"""Timing model (paper Eqs. 4-7): hand-computed cases + invariants."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched

from repro.core import ClusterSpec, alpha, alpha_max, beta
from repro.core import timing
from repro.core.job import JobSpec, StageSpec

from conftest import make_simple_job

MB = 1024.0**2


@pytest.fixture
def small_cluster():
    return ClusterSpec(
        num_servers=4, gpus_per_server=4, b_inter=1e9, b_intra=100e9
    )


class TestComp:
    def test_single_stage_single_gpu(self, small_cluster):
        job = JobSpec(
            job_id=0,
            stages=(StageSpec(p_f=0.1, p_b=0.2, d_in=0, d_out=0, h=0, k=1),),
            n_iters=5,
        )
        placement = {0: np.array([1])}
        # no comm, no allreduce: alpha = p_f + p_b
        assert alpha(job, placement, small_cluster) == pytest.approx(0.3)

    def test_allreduce_colocated_vs_split(self, small_cluster):
        """Eq. 6: co-located replicas sync over B_intra, split over NIC."""
        h = 100 * MB
        job = JobSpec(
            job_id=0,
            stages=(StageSpec(p_f=0.0, p_b=0.0, d_in=0, d_out=0, h=h, k=2),),
            n_iters=1,
        )
        data = 2 * (2 - 1) / 2 * h  # = h
        co = alpha(job, {0: np.array([2])}, small_cluster)
        assert co == pytest.approx(data / small_cluster.b_intra)
        split = alpha(
            job, {0: np.array([1]), 1: np.array([1])}, small_cluster
        )
        # NIC share = (1/4) * b_inter
        assert split == pytest.approx(
            data / (small_cluster.b_inter / 4)
        )
        assert split > co

    def test_inter_stage_comm_remote_vs_local(self, small_cluster):
        """Eq. 5: co-locating consecutive stages avoids NIC traffic."""
        act = 10 * MB
        job = JobSpec(
            job_id=0,
            stages=(
                StageSpec(p_f=0.1, p_b=0.1, d_in=0, d_out=act, h=0, k=1),
                StageSpec(p_f=0.1, p_b=0.1, d_in=act, d_out=0, h=0, k=1),
            ),
            n_iters=1,
        )
        both = alpha(job, {0: np.array([1, 1])}, small_cluster)
        split = alpha(
            job, {0: np.array([1, 0]), 1: np.array([0, 1])}, small_cluster
        )
        assert both < split
        # split: stage 0 sends 2*act over (1/4)*b_inter
        expected_comm = 2 * act / (small_cluster.b_inter / 4)
        assert split == pytest.approx(0.2 + expected_comm)

    def test_beta_zero_when_absent(self, small_cluster):
        job = make_simple_job(replicas=(2, 2))
        x = np.array([0, 2])
        assert beta(job, x, 0, small_cluster) == 0.0

    def test_alpha_max_upper_bounds_spread(self, small_cluster):
        """alpha_max equals alpha of the fully spread placement."""
        job = make_simple_job(replicas=(2, 2), act_mb=8, h_mb=128)
        placement = {m: np.zeros(2, dtype=int) for m in range(4)}
        placement[0][0] = 1
        placement[1][0] = 1
        placement[2][1] = 1
        placement[3][1] = 1
        spread = alpha(job, placement, small_cluster)
        # alpha_max assumes worst NIC share 1/g, the spread placement gets
        # the same share; values must agree
        assert alpha_max(job, small_cluster) == pytest.approx(spread)

    def test_validate_placement(self, small_cluster):
        job = make_simple_job(replicas=(2, 1))
        with pytest.raises(ValueError):
            timing.validate_placement(job, {0: np.array([1, 1])})
        timing.validate_placement(
            job, {0: np.array([2, 0]), 1: np.array([0, 1])}
        )


class TestAlphaSync:
    def test_sync_at_least_async_with_single_microbatch_overhead(self):
        """GPipe fill/drain: alpha_sync >= async bottleneck; converges to
        comp+comm bottleneck + AR as microbatches grow."""
        from repro.core.timing import alpha_sync
        from repro.core import ClusterSpec, alpha
        import numpy as np

        cluster = ClusterSpec(
            num_servers=4, gpus_per_server=4, b_inter=1e9, b_intra=100e9
        )
        job = make_simple_job(replicas=(2, 2), act_mb=8, h_mb=64)
        placement = {0: np.array([2, 2])}
        a_async = alpha(job, placement, cluster)
        a_sync_1 = alpha_sync(job, placement, cluster, n_microbatches=1)
        a_sync_32 = alpha_sync(job, placement, cluster, n_microbatches=32)
        assert a_sync_1 >= a_sync_32 > 0
        # with many microbatches sync approaches the async bottleneck scale
        assert a_sync_32 <= a_sync_1
        assert a_sync_1 >= a_async * 0.5  # same order of magnitude
