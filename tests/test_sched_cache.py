"""Placement cache: cached A-SRPT must be *bit-identical* to uncached.

The incremental engine (settled-epoch gate, caps-equality skip, canonical
memoized Heavy-Edge mapping) is only allowed to skip work whose outcome is
provably unchanged — so the full SimResult (per-job start, completion,
alpha, servers) must match the exhaustive re-evaluation engine exactly,
not approximately.
"""
import pytest

pytestmark = pytest.mark.sched

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ASRPTPolicy,
    ClusterSpec,
    TraceConfig,
    generate_trace,
    make_predictor,
    simulate,
)
from repro.core.heavy_edge import PlacementCache
from repro.core.cluster import ClusterState

import numpy as np

from conftest import make_simple_job


def _simulate_pair(jobs, cluster, refine=False, tau=2.0, predictor="mean"):
    results = []
    for cache in (True, False):
        pol = ASRPTPolicy(
            make_predictor(predictor),
            tau=tau,
            refine_mapping=refine,
            placement_cache=cache,
        )
        results.append(simulate(jobs, cluster, pol))
    return results


def assert_identical(ra, rb):
    assert set(ra.records) == set(rb.records)
    for jid, a in ra.records.items():
        b = rb.records[jid]
        assert a.start == b.start, jid
        assert a.completion == b.completion, jid
        assert a.alpha == b.alpha, jid
        assert a.servers == b.servers, jid


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_cached_equals_uncached_random_traces(seed):
    cluster = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = generate_trace(
        TraceConfig(
            n_jobs=40,
            horizon=2400.0,
            seed=seed,
            max_gpus_per_job=16,
            mean_iters=60,
            session_spread=30.0,
        )
    )
    ra, rb = _simulate_pair(jobs, cluster)
    assert_identical(ra, rb)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_cached_equals_uncached_refined_mapping(seed):
    """The refined (local-search) mapping mode must cache identically too."""
    cluster = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = generate_trace(
        TraceConfig(
            n_jobs=30,
            horizon=1800.0,
            seed=seed,
            max_gpus_per_job=16,
            mean_iters=60,
            session_spread=30.0,
        )
    )
    ra, rb = _simulate_pair(jobs, cluster, refine=True)
    assert_identical(ra, rb)


def test_cached_equals_uncached_comm_heavy_delays():
    """Delayed comm-heavy jobs exercise the step-2 skip logic directly."""
    cluster = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    jobs = []
    jid = 0
    for i in range(6):  # fragmenting fillers
        jobs.append(
            make_simple_job(
                job_id=jid, replicas=(1,), p=1.0, h_mb=0.1,
                n_iters=40 + 13 * i, arrival=0.3 * i,
            )
        )
        jid += 1
    for i in range(4):  # comm-heavy jobs that face fragmented capacity
        jobs.append(
            make_simple_job(
                job_id=jid, replicas=(8,), p=0.05, h_mb=2048.0,
                n_iters=10, arrival=1.0 + 0.5 * i, group_id=1,
            )
        )
        jid += 1
    ra, rb = _simulate_pair(jobs, cluster, tau=5.0, predictor="perfect")
    assert_identical(ra, rb)


# ---------------------------------------------------------------------------
# PlacementCache unit behaviour
# ---------------------------------------------------------------------------


def test_placement_cache_canonical_relabeling():
    """Same capacity shape on different servers: one miss, relabeled hits."""
    cluster = ClusterSpec(
        num_servers=8, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    job = make_simple_job(job_id=0, replicas=(4, 4), h_mb=64.0)
    cache = PlacementCache(cluster)
    p1, a1 = cache.map_job(job, [(0, 8)])
    p2, a2 = cache.map_job(job, [(5, 8)])
    assert cache.misses == 1 and cache.hits == 1
    assert a1 == a2
    assert set(p1) == {0} and set(p2) == {5}
    assert np.array_equal(p1[0], p2[5])
    # split shape is a distinct key
    p3, a3 = cache.map_job(job, [(2, 4), (6, 4)])
    assert cache.misses == 2
    assert set(p3) == {2, 6}


def test_placement_cache_matches_direct_map_job():
    from repro.core.heavy_edge import map_job

    cluster = ClusterSpec(
        num_servers=4, gpus_per_server=8, b_inter=1.25e9, b_intra=300e9
    )
    job = make_simple_job(job_id=0, replicas=(2, 2), h_mb=64.0)
    caps = [(1, 2), (3, 2)]
    cache = PlacementCache(cluster)
    placement_c, alpha_c = cache.map_job(job, caps)
    placement_d, alpha_d = map_job(job, caps, cluster)
    assert alpha_c == pytest.approx(alpha_d)
    # canonical relabeling preserves the per-server stage vectors
    assert {m: tuple(v) for m, v in placement_c.items()} == {
        m: tuple(v) for m, v in placement_d.items()
    }


def test_cluster_epoch_tracking():
    spec = ClusterSpec(
        num_servers=2, gpus_per_server=4, b_inter=1e9, b_intra=1e10
    )
    cs = ClusterState(spec)
    e0 = cs.epoch
    cs.allocate(1, {0: np.array([2])})
    assert cs.epoch == e0 + 1
    assert cs.total_free == 6
    cs.release(1)
    assert cs.epoch == e0 + 2
    assert cs.total_free == 8
    cs.mark_server_down(0)
    assert cs.total_free == 4 and cs.epoch == e0 + 3
