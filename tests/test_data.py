"""Synthetic data pipeline: determinism, shift, shards, cursor."""
import numpy as np

from repro.configs import reduced_config
from repro.train.data import DataLoader, make_batch


def test_deterministic_per_step():
    cfg = reduced_config("deepseek-7b")
    a = make_batch(cfg, 4, 32, step=7, seed=1)
    b = make_batch(cfg, 4, 32, step=7, seed=1)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = make_batch(cfg, 4, 32, step=8, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = reduced_config("deepseek-7b")
    # tokens/labels come from one (B, S+1) draw: labels[t] == tokens[t+1]
    b = make_batch(cfg, 2, 16, step=0, seed=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_disjoint():
    cfg = reduced_config("deepseek-7b")
    s0 = make_batch(cfg, 8, 16, step=0, seed=0, shard=0, n_shards=2)
    s1 = make_batch(cfg, 8, 16, step=0, seed=0, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_audio_and_vlm_families():
    a = reduced_config("hubert-xlarge")
    b = make_batch(a, 2, 32, step=0)
    assert b["frames"].shape == (2, 32, a.frontend_dim)
    assert ((b["labels"] == -1) | (b["labels"] < a.vocab_size)).all()
    assert (b["labels"] >= 0).sum() > 0  # some masked targets exist
    v = reduced_config("llava-next-mistral-7b")
    bv = make_batch(v, 2, 32, step=0)
    assert bv["patch_embeds"].shape == (2, v.vlm_img_tokens, v.frontend_dim)
    assert bv["tokens"].shape == (2, 32 - v.vlm_img_tokens)


def test_loader_cursor_roundtrip():
    cfg = reduced_config("deepseek-7b")
    l1 = DataLoader(cfg, 2, 16, seed=3)
    for _ in range(5):
        l1.next()
    saved = l1.state()
    want = l1.next()
    l2 = DataLoader(cfg, 2, 16, seed=0)
    l2.restore(saved)
    got = l2.next()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
