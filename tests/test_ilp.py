"""Exact min-cut placement (B&B) vs Heavy-Edge (Table II relationship)."""
import numpy as np
import pytest

pytestmark = pytest.mark.sched
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests fall back to seeded sampling
    from _hypothesis_fallback import given, settings, st

import repro.core.heavy_edge as he
from repro.core import build_job_graph
from repro.core.graph import JobGraph
from repro.core.ilp import exact_min_cut

from conftest import make_simple_job


@st.composite
def random_graph_and_caps(draw):
    n = draw(st.integers(2, 8))
    vertices = [(0, i) for i in range(n)]
    edges = {}
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                edges[((0, i), (0, j))] = draw(st.floats(0.1, 100.0))
    n_parts = draw(st.integers(1, min(3, n)))
    # random sizes summing to n
    sizes = [1] * n_parts
    for _ in range(n - n_parts):
        sizes[draw(st.integers(0, n_parts - 1))] += 1
    caps = [(m, s) for m, s in enumerate(sizes)]
    return JobGraph(vertices, edges), caps


class TestExactMinCut:
    @settings(max_examples=50, deadline=None)
    @given(random_graph_and_caps())
    def test_not_worse_than_heavy_edge(self, gc):
        graph, caps = gc
        opt_assign, opt_cut = exact_min_cut(graph, caps)
        heur = he.heavy_edge(graph, caps)
        assert opt_cut <= graph.cut_weight(heur) + 1e-9
        # optimum assignment is itself feasible
        assert graph.cut_weight(opt_assign) == pytest.approx(opt_cut)
        from collections import Counter

        counts = Counter(opt_assign.values())
        for m, c in caps:
            assert counts.get(m, 0) == c

    def test_two_cliques(self):
        """Two heavy cliques + weak bridge: optimum cuts the bridge."""
        vertices = [(0, i) for i in range(4)]
        edges = {
            ((0, 0), (0, 1)): 100.0,
            ((0, 2), (0, 3)): 100.0,
            ((0, 1), (0, 2)): 1.0,
        }
        g = JobGraph(vertices, edges)
        assign, cut = exact_min_cut(g, [(0, 2), (1, 2)])
        assert cut == pytest.approx(1.0)
        assert assign[(0, 0)] == assign[(0, 1)]
        assert assign[(0, 2)] == assign[(0, 3)]

    def test_heavy_edge_near_optimal_pitt(self):
        """Paper Table II compares per-iteration training time (PITT), not
        raw cut weight: Heavy-Edge's PITT is within a few % of the ILP
        placement's PITT on pipeline jobs."""
        from repro.core import ClusterSpec, timing

        cluster = ClusterSpec(
            num_servers=8, gpus_per_server=4, b_inter=1.25e9, b_intra=300e9
        )
        rng = np.random.default_rng(0)
        ratios = []
        for trial in range(10):
            replicas = tuple(
                int(rng.integers(1, 4)) for _ in range(int(rng.integers(1, 4)))
            )
            job = make_simple_job(
                replicas=replicas,
                act_mb=float(rng.uniform(1, 32)),
                h_mb=float(rng.uniform(16, 512)),
            )
            g = build_job_graph(job)
            total = job.g
            n_full, rem = divmod(total, 4)
            caps = [(m, 4) for m in range(n_full)]
            if rem:
                caps.append((n_full, rem))
            opt_assign, _ = exact_min_cut(g, caps)
            a_opt = timing.alpha(
                job, timing.placement_from_assignment(job, opt_assign), cluster
            )
            a_he = timing.alpha(
                job,
                timing.placement_from_assignment(
                    job, he.heavy_edge(g, caps)
                ),
                cluster,
            )
            _, a_ref = he.map_job(job, caps, cluster, refine=True)
            ratios.append((a_he / a_opt, a_ref / a_opt))
        greedy = [r[0] for r in ratios]
        refined = [r[1] for r in ratios]
        # paper's greedy: near-optimal on most instances but unbounded in
        # the worst case (NP-complete problem); the beyond-paper local
        # search closes those gaps.
        assert np.median(greedy) < 1.05
        assert np.mean(refined) < 1.05
        assert max(refined) <= max(greedy) + 1e-9
